//! YCSB-style workloads (A–F) against a replicated OCF cluster —
//! the cloud-serving benchmark the paper cites as [6].
//!
//! ```bash
//! cargo run --release --example ycsb [ops_per_workload]
//! ```

use ocf::cluster::{Cluster, ReplicationConfig};
use ocf::metrics::Histogram;
use ocf::store::{FlushPolicy, NodeConfig};
use ocf::workload::ycsb::Preset;
use std::time::Instant;

fn main() {
    let ops: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);

    println!("| workload | ops/s | p50 ns | p99 ns | short-circuit % |");
    println!("|---|---|---|---|---|");
    for preset in Preset::all() {
        let mut cluster = Cluster::new(
            3,
            64,
            NodeConfig {
                flush: FlushPolicy::small(50_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 2,
                ..ReplicationConfig::default()
            },
        );
        // load phase: 10k keys so reads have something to hit
        for k in 0..10_000u64 {
            cluster.put(k).unwrap();
        }
        let mut gen = preset.generator(100_000, 0x4C5B);
        let mut lat = Histogram::new();
        let t0 = Instant::now();
        for _ in 0..ops {
            let op = gen.next_op();
            let o0 = Instant::now();
            let _ = cluster.apply(op);
            lat.record(o0.elapsed().as_nanos() as u64);
        }
        let dt = t0.elapsed().as_secs_f64();
        let sc: u64 = (0..cluster.node_count())
            .map(|i| cluster.node(i).stats.filter_short_circuits())
            .sum();
        let gets: u64 = (0..cluster.node_count())
            .map(|i| cluster.node(i).stats.gets())
            .sum();
        println!(
            "| {} | {} | {} | {} | {:.1} |",
            preset.name(),
            ocf::util::fmt_rate(ops as f64 / dt),
            lat.quantile(0.5),
            lat.quantile(0.99),
            100.0 * sc as f64 / gets.max(1) as f64,
        );
    }
}

//! E7 companion: the paper's §I.B cartesian-product query on a
//! simulated 3-node data-center, with and without membership filters.
//!
//! ```bash
//! cargo run --release --example distributed_query [set_size]
//! ```

use ocf::cluster::{CartesianQuery, Cluster, Coordinator, ReplicationConfig};
use ocf::store::{FlushPolicy, FlushReason, NodeConfig, StorageNode};
use std::time::Instant;

fn main() {
    let set_size: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // --- a 3-node cluster holding T, U and V --------------------------
    let mut cluster = Cluster::new(
        3,
        64,
        NodeConfig {
            flush: FlushPolicy::small(100_000),
            ..NodeConfig::default()
        },
        ReplicationConfig::none(),
    );
    let t: Vec<u64> = (0..set_size as u64).collect();
    let u: Vec<u64> = (10_000..10_000 + set_size as u64).collect();
    for &k in t.iter().chain(&u) {
        cluster.put(k).unwrap();
    }
    println!(
        "cluster loaded: {} keys over {} nodes; per-node ops so far: {:?}",
        2 * set_size,
        cluster.node_count(),
        cluster.stats.per_node_ops
    );

    // --- V's node: bulk data + a few planted (t,u) matches ------------
    let mut v_node = StorageNode::new(NodeConfig {
        flush: FlushPolicy::small(100_000),
        ..NodeConfig::default()
    });
    let planted = 12usize;
    for i in 0..planted {
        v_node
            .put(CartesianQuery::pair_key(t[i], u[i]))
            .unwrap();
    }
    for k in 0..50_000u64 {
        v_node.put((1 << 50) + k).unwrap();
    }
    v_node.flush(FlushReason::MemtableKeys);

    // --- the coordinated query -----------------------------------------
    let query = CartesianQuery {
        t,
        u,
        probe_key: CartesianQuery::pair_key,
    };
    let t0 = Instant::now();
    let stats = Coordinator::execute(&query, &mut v_node);
    let dt = t0.elapsed();
    println!(
        "\nT×U⋈V: {} pairs probed in {:.1} ms ({:.2} Mprobe/s)",
        stats.pairs_generated,
        dt.as_secs_f64() * 1e3,
        stats.pairs_generated as f64 / dt.as_secs_f64() / 1e6,
    );
    println!(
        "matches={} | filter-pruned={} ({:.2}%) | storage probes={}",
        stats.matches,
        stats.v_filter_pruned,
        100.0 * stats.v_filter_pruned as f64 / stats.pairs_generated as f64,
        stats.v_probes,
    );
    assert!(stats.matches as usize >= planted);
    println!(
        "\npaper §I.B: 'the number of look-ups on the node containing V is much \
         greater' — the node filter absorbed {:.1}% of them before storage.",
        100.0 * stats.v_filter_pruned as f64 / stats.pairs_generated as f64
    );
}

//! Shard-scaling demo: the concurrent OCF front-end under bursty
//! multi-threaded load (E9 companion).
//!
//! ```bash
//! cargo run --release --example sharded_throughput [ops_per_thread]
//! ```
//!
//! A fixed pool of writer threads drives square-wave burst traffic
//! (insert storms alternating with delete storms) through the batched
//! APIs at 1/2/4/8 shards. One shard serializes the pool on a single
//! lock stripe; more shards let disjoint batch groups proceed
//! concurrently — throughput should roughly double by 4 shards.

use ocf::exp::sharded::{default_threads, run_arm};
use ocf::filter::{OcfConfig, ShardedOcf};

fn main() {
    let ops_per_thread: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    let threads = default_threads();

    println!("sharded OCF scaling — {threads} threads × {ops_per_thread} ops, burst workload\n");
    println!("{:>7} {:>12} {:>9} {:>10} {:>9}", "shards", "ops", "secs", "Mops/s", "speedup");
    let mut base = 0.0;
    for shards in [1usize, 2, 4, 8] {
        let r = run_arm(shards, threads, ops_per_thread, 1024);
        let mops = r.ops_per_sec() / 1e6;
        if shards == 1 {
            base = r.ops_per_sec();
        }
        let speedup = if base > 0.0 { r.ops_per_sec() / base } else { 0.0 };
        println!(
            "{:>7} {:>12} {:>9.3} {:>10.2} {:>8.2}x",
            shards, r.ops, r.secs, mops, speedup
        );
    }

    // And the state the front-end converges to under a quick burst:
    let f = ShardedOcf::with_shards(
        4,
        OcfConfig {
            initial_capacity: 4096,
            ..OcfConfig::default()
        },
    );
    let keys: Vec<u64> = (0..50_000).collect();
    for chunk in keys.chunks(1024) {
        for r in f.insert_batch(chunk) {
            r.unwrap();
        }
    }
    let s = f.stats();
    println!(
        "\n4-shard filter after 50k batched inserts: len={} occupancy={:.2} \
         resizes={} memory={} (shard lens {:?})",
        f.len(),
        f.occupancy(),
        s.resizes(),
        ocf::util::fmt_bytes(f.memory_bytes()),
        f.shard_lens(),
    );
}

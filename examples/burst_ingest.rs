//! E6 companion: a Cassandra-like storage node under bursty ingest —
//! fixed-capacity filter (premature flushes) vs OCF (burst tolerant).
//!
//! ```bash
//! cargo run --release --example burst_ingest [ops]
//! ```

use ocf::exp::{burst, Scale};
use ocf::filter::{MembershipFilter, Mode, OcfConfig};
use ocf::store::{FlushPolicy, NodeConfig, StorageNode};
use ocf::workload::{BurstGenerator, Op};

fn main() {
    let ops: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    // Narrated single-node run with phase-by-phase reporting.
    let mut node = StorageNode::new(NodeConfig {
        filter: OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 4096,
            ..OcfConfig::default()
        }
        .into(),
        flush: FlushPolicy::small(ops),
        ..NodeConfig::default()
    });
    let mut gen = BurstGenerator::square_wave(ops / 8, 1 << 24, 0xB1157);
    let mut phase = gen.current_phase();
    println!("phase change -> {phase}");
    for _ in 0..ops {
        let Some(op) = gen.next_op() else { break };
        if gen.current_phase() != phase {
            phase = gen.current_phase();
            println!(
                "phase change -> {phase:13} | live={:7} filter cap={:8} occ={:.2} resizes={}",
                node.live_keys(),
                node.filter().capacity(),
                node.filter().occupancy(),
                node.filter().stats().resizes(),
            );
        }
        match op {
            Op::Insert(k) => {
                let _ = node.put(k);
            }
            Op::Lookup(k) => {
                let _ = node.get(k);
            }
            Op::Delete(k) => {
                let _ = node.delete(k);
            }
        }
    }
    println!(
        "\nOCF node: flushes={} premature={} filter-memory={}",
        node.stats.flushes,
        node.stats.flushes_premature,
        ocf::util::fmt_bytes(node.filter_memory_bytes()),
    );

    // Then the full two-arm comparison (E6).
    println!("{}", burst::run(Scale(ops as f64 / 400_000.0)));
}

//! Quickstart: the OCF public API in ~60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ocf::filter::{MembershipFilter, Mode, Ocf, OcfConfig};

fn main() {
    // 1. Build an OCF in the congestion-aware (EOF) mode. The paper
    //    recommends capacity = 2× the expected items, but OCF resizes
    //    itself, so a rough guess is fine.
    let mut filter = Ocf::new(OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 8192,
        fp_bits: 16,
        ..OcfConfig::default()
    });

    // 2. Insert far more keys than the initial capacity: the EOF
    //    controller grows the filter as the burst develops.
    for key in 0..100_000u64 {
        filter.insert(key).expect("OCF absorbs bursts by resizing");
    }
    println!(
        "after 100k inserts: len={} capacity={} occupancy={:.2} resizes={} (α={:.3})",
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        filter.stats().resizes(),
        filter.alpha().unwrap(),
    );

    // 3. Membership tests: no false negatives, ~2^-16 false positives.
    assert!(filter.contains(42));
    assert!(filter.contains(99_999));
    let false_positives = (1_000_000..1_100_000u64)
        .filter(|&k| filter.contains(k))
        .count();
    println!("false positives on 100k held-out keys: {false_positives}");

    // 4. Verified deletes: removing a key you never inserted is
    //    rejected (the traditional filter would silently damage a
    //    resident key's fingerprint here — paper §IV).
    assert!(filter.delete(42));
    assert!(!filter.delete(424_242_424), "absent keys are rejected");

    // 5. Delete storms shrink the filter back down.
    for key in 0..90_000u64 {
        filter.delete(key);
    }
    println!(
        "after delete storm: len={} capacity={} occupancy={:.2} (shrinks={})",
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        filter.stats().resizes_shrink,
    );
    println!("quickstart OK");
}

//! Quickstart: the OCF public API in ~60 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ocf::filter::{
    BatchedFilter, FilterBuilder, MembershipFilter, Mode, Ocf, OcfConfig, ProbeSession,
};

fn main() {
    // 1. Build an OCF in the congestion-aware (EOF) mode. The paper
    //    recommends capacity = 2× the expected items, but OCF resizes
    //    itself, so a rough guess is fine.
    let mut filter = Ocf::new(OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 8192,
        fp_bits: 16,
        ..OcfConfig::default()
    });

    // 2. Insert far more keys than the initial capacity: the EOF
    //    controller grows the filter as the burst develops.
    for key in 0..100_000u64 {
        filter.insert(key).expect("OCF absorbs bursts by resizing");
    }
    println!(
        "after 100k inserts: len={} capacity={} occupancy={:.2} resizes={} (α={:.3})",
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        filter.stats().resizes(),
        filter.alpha().unwrap(),
    );

    // 3. Membership tests: no false negatives, ~2^-16 false positives.
    assert!(filter.contains(42));
    assert!(filter.contains(99_999));
    let false_positives = (1_000_000..1_100_000u64)
        .filter(|&k| filter.contains(k))
        .count();
    println!("false positives on 100k held-out keys: {false_positives}");

    // 4. Verified deletes: removing a key you never inserted is
    //    rejected (the traditional filter would silently damage a
    //    resident key's fingerprint here — paper §IV).
    assert!(filter.delete(42));
    assert!(!filter.delete(424_242_424), "absent keys are rejected");

    // 5. Delete storms shrink the filter back down.
    for key in 0..90_000u64 {
        filter.delete(key);
    }
    println!(
        "after delete storm: len={} capacity={} occupancy={:.2} (shrinks={})",
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        filter.stats().resizes_shrink,
    );

    // 6. Filter API v2: the batched trait surface with a reusable
    //    ProbeSession — zero allocations per call once warm; the
    //    engine-backed filters run the prefetch-pipelined probes.
    let mut session = ProbeSession::new();
    let keys: Vec<u64> = (500_000..508_192u64).collect();
    let mut results = Vec::new();
    filter.insert_batch_into(&keys, &mut session, &mut results);
    assert!(results.iter().all(|r| r.is_ok()));
    let mut hits = Vec::new();
    filter.contains_batch_into(&keys, &mut session, &mut hits);
    assert!(hits.iter().all(|&h| h), "no false negatives, batched");
    let mut deleted = Vec::new();
    filter.delete_batch_into(&keys, &mut session, &mut deleted);
    assert!(deleted.iter().all(|&d| d), "verified batched deletes");

    // 7. Any backend by name via the unified builder — here a bloom
    //    baseline, which gets the same batched APIs from the trait's
    //    scalar defaults (and can be driven through `dyn`).
    let mut baseline = FilterBuilder::named("bloom")
        .expect("known backend")
        .with_initial_capacity(10_000)
        .build()
        .expect("valid config");
    for r in baseline.insert_batch(&(0..10_000u64).collect::<Vec<_>>()) {
        r.unwrap();
    }
    println!(
        "builder[{}]: len={} memory={} (batch APIs for free)",
        baseline.name(),
        baseline.len(),
        ocf::util::fmt_bytes(baseline.memory_bytes()),
    );
    println!("quickstart OK");
}

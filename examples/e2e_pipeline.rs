//! **The end-to-end driver** (DESIGN.md E9): proves all three layers
//! compose on a real workload.
//!
//! ```text
//!  YCSB-A workload (1M ops)                         [L3 workload gen]
//!    → dynamic batcher + backpressure               [L3 pipeline]
//!    → AOT Pallas/JAX hash artifact via PJRT        [L1/L2 via runtime]
//!    → OCF filter (EOF controller) + storage node   [L3 store]
//! ```
//!
//! Prints the headline metrics recorded in EXPERIMENTS.md §E9:
//! sustained ops/s, batch p50/p99, resize count, filter memory — and
//! *verifies* the XLA and native hash paths produce identical filter
//! state (the cross-language contract, end to end).
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline [ops]
//! ```

use ocf::filter::{MembershipFilter, Mode, Ocf, OcfConfig};
use ocf::pipeline::{BatchPolicy, IngestPipeline};
use ocf::runtime::{ExecutorKind, HashExecutor, PjrtEngine};
use ocf::workload::ycsb::Preset;
use std::sync::Arc;
use std::time::Duration;

fn run_arm(label: &str, executor: HashExecutor, ops: usize) -> (Ocf, f64) {
    let mut filter = Ocf::new(OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 8192,
        ..OcfConfig::default()
    });
    let mut pipeline = IngestPipeline::new(
        BatchPolicy {
            max_batch: 1024,
            max_delay: Duration::from_micros(500),
        },
        executor,
    );
    let mut gen = Preset::A.generator(1 << 22, 0xE2E_0CF);
    // executor-hashed path: the XLA artifact (when loaded) hashes each
    // batch once; the triples drive the filter directly
    let report = pipeline.run_hashed((0..ops).map(|_| gen.next_op()), &mut filter);
    println!(
        "[{label:>6}] {} | filter: len={} cap={} occ={:.2} resizes={} mem={}",
        report.render(),
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        filter.stats().resizes(),
        ocf::util::fmt_bytes(filter.memory_bytes()),
    );
    (filter, report.ops_per_sec())
}

fn main() {
    let ops: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);

    println!("e2e_pipeline: YCSB-A, {ops} ops, batch=1024\n");

    // --- native arm ---------------------------------------------------
    let hasher = Ocf::new(OcfConfig::default()).hasher();
    let (native_filter, native_ops) = run_arm("native", HashExecutor::native(hasher), ops);

    // --- XLA arm (the three-layer path) -------------------------------
    match PjrtEngine::load_dir("artifacts") {
        Ok(Some(engine)) => {
            let engine = Arc::new(engine);
            println!(
                "\nPJRT engine up: platform={} artifacts={:?}",
                engine.platform(),
                engine.artifact_names()
            );
            let exec = HashExecutor::with_engine(engine, hasher);
            assert_eq!(exec.kind(), ExecutorKind::Xla);
            let (xla_filter, xla_ops) = run_arm("xla", exec, ops);

            // cross-language contract, end to end: identical filter state
            assert_eq!(native_filter.len(), xla_filter.len());
            assert_eq!(native_filter.capacity(), xla_filter.capacity());
            let mut checked = 0;
            for k in (0..(1u64 << 22)).step_by(4097) {
                assert_eq!(
                    native_filter.contains(k),
                    xla_filter.contains(k),
                    "membership divergence at key {k}"
                );
                checked += 1;
            }
            println!(
                "\nCROSS-LANGUAGE CHECK OK: native and XLA arms agree on \
                 {checked} probes (len={} capacity={}).",
                xla_filter.len(),
                xla_filter.capacity()
            );
            println!(
                "headline: native {} vs xla {} (per-batch PJRT dispatch overhead \
                 dominates on CPU; see EXPERIMENTS.md §E9)",
                ocf::util::fmt_rate(native_ops),
                ocf::util::fmt_rate(xla_ops),
            );
        }
        Ok(None) => {
            println!(
                "\nNOTE: artifacts/ not built — XLA arm skipped. \
                 Run `make artifacts` for the full three-layer path."
            );
            println!("headline: native {}", ocf::util::fmt_rate(native_ops));
        }
        Err(e) => panic!("artifact load error: {e}"),
    }
    println!("\ne2e_pipeline OK");
}

"""Layer-2 model composition: fused hash_and_probe vs staged reference."""

import numpy as np

from compile import model
from compile.kernels import ref

MASK64 = (1 << 64) - 1
SLOTS = ref.SLOTS


def build_frozen_table(keys, seed, fp_mask, nbuckets):
    """Insert keys into a plain python cuckoo table (primary bucket only,
    falling back to alt, no eviction — enough for a read-path test)."""
    table = np.zeros(nbuckets * SLOTS, dtype=np.uint32)
    fp, idx, fph = ref.hash_batch_ref(keys, np.uint64(seed), np.uint32(fp_mask))
    fp, idx, fph = np.asarray(fp), np.asarray(idx), np.asarray(fph)
    placed = 0
    for f, ih, hh in zip(fp, idx, fph):
        i1 = int(ih) & (nbuckets - 1)
        i2 = (i1 ^ int(hh)) & (nbuckets - 1)
        done = False
        for b in (i1, i2):
            for s in range(SLOTS):
                if table[b * SLOTS + s] == 0:
                    table[b * SLOTS + s] = f
                    done = True
                    break
            if done:
                break
        placed += done
    return table, placed


def test_hash_and_probe_finds_inserted_keys():
    rng = np.random.default_rng(42)
    nbuckets, n = 1024, 256
    seed, fp_mask = 0xA5A5, 0xFFFF
    keys = rng.integers(0, MASK64, size=n, dtype=np.uint64)
    table, placed = build_frozen_table(keys, seed, fp_mask, nbuckets)
    assert placed == n  # low load: everything places without eviction

    present, fp, i1, i2 = model.hash_and_probe(
        keys,
        np.array([seed], dtype=np.uint64),
        np.array([fp_mask], dtype=np.uint32),
        table,
        np.array([nbuckets - 1], dtype=np.uint32),
    )
    assert (np.asarray(present) == 1).all()
    # triple must equal the reference hash
    wfp, widx, wfph = ref.hash_batch_ref(keys, np.uint64(seed), np.uint32(fp_mask))
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(wfp))
    wi1 = np.asarray(widx) & np.uint32(nbuckets - 1)
    wi2 = (wi1 ^ np.asarray(wfph)) & np.uint32(nbuckets - 1)
    np.testing.assert_array_equal(np.asarray(i1), wi1)
    np.testing.assert_array_equal(np.asarray(i2), wi2)


def test_hash_and_probe_absent_keys_mostly_absent():
    """Held-out keys must miss except for fingerprint collisions; with a
    16-bit fp and 1k buckets the FP rate must be well under 5%."""
    rng = np.random.default_rng(43)
    nbuckets = 1024
    seed, fp_mask = 0xBEEF, 0xFFFF
    ins = rng.integers(0, MASK64 // 2, size=256, dtype=np.uint64)
    out = rng.integers(MASK64 // 2 + 1, MASK64, size=1024, dtype=np.uint64)
    table, _ = build_frozen_table(ins, seed, fp_mask, nbuckets)
    present, *_ = model.hash_and_probe(
        out,
        np.array([seed], dtype=np.uint64),
        np.array([fp_mask], dtype=np.uint32),
        table,
        np.array([nbuckets - 1], dtype=np.uint32),
    )
    fp_rate = float(np.asarray(present).mean())
    assert fp_rate < 0.05


def test_probe_batch_tuple_wrapper():
    """model.probe_batch returns a 1-tuple (AOT return_tuple contract)."""
    table = np.zeros(64 * SLOTS, dtype=np.uint32)
    q = np.zeros(64, dtype=np.uint32)
    out = model.probe_batch(table, q, q, q)
    assert isinstance(out, tuple) and len(out) == 1

"""Pallas hash kernel vs pure-jnp oracle, plus known-answer vectors.

The known-answer constants double as the cross-language contract: the
same vectors are asserted in rust/src/filter/fingerprint.rs unit tests.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hash_kernel import hash_batch_pallas

MASK64 = (1 << 64) - 1
MASK32 = (1 << 32) - 1


# ---------------------------------------------------------------- python-int
# plain-integer model of the hash, independent of jax/numpy — a third
# implementation to triangulate the other two.
def py_mix64(z: int) -> int:
    z = (z + ref.GOLDEN_GAMMA) & MASK64
    z = ((z ^ (z >> 30)) * ref.MIX64_M1) & MASK64
    z = ((z ^ (z >> 27)) * ref.MIX64_M2) & MASK64
    return (z ^ (z >> 31)) & MASK64


def py_mix32(z: int) -> int:
    z = ((z ^ (z >> 16)) * ref.MIX32_M1) & MASK32
    z = ((z ^ (z >> 13)) * ref.MIX32_M2) & MASK32
    return (z ^ (z >> 16)) & MASK32


def py_hash_key(key: int, seed: int, fp_mask: int):
    h = py_mix64(key ^ seed)
    raw = (h >> 32) & fp_mask
    fp = 1 if raw == 0 else raw
    return fp, h & MASK32, py_mix32(fp)


# ------------------------------------------------------------- known answers
def test_mix64_splitmix_vector():
    # first output of SplitMix64 seeded with 0 — the canonical vector
    assert py_mix64(0) == 0xE220A8397B1DCDAF
    assert int(ref.mix64(np.uint64(0))) == 0xE220A8397B1DCDAF


def test_mix64_more_vectors():
    # SplitMix64 stream seeded 0: state_i = i * gamma
    expected = [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4, 0x06C45D188009454F]
    for i, want in enumerate(expected):
        state = (i * ref.GOLDEN_GAMMA) & MASK64
        assert py_mix64(state) == want
        assert int(ref.mix64(np.uint64(state))) == want


def test_mix32_murmur_vector():
    # fmix32 avalanche of small ints, computed from the reference formula
    assert py_mix32(0) == 0
    assert int(ref.mix32(np.uint32(1))) == py_mix32(1)
    assert int(ref.mix32(np.uint32(0xDEADBEEF))) == py_mix32(0xDEADBEEF)


def test_hash_batch_ref_matches_python_ints():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, MASK64, size=64, dtype=np.uint64)
    seed, fp_mask = 0x5EED, 0xFFFF
    fp, idx, fph = ref.hash_batch_ref(keys, np.uint64(seed), np.uint32(fp_mask))
    for k, f, i, h in zip(keys.tolist(), np.asarray(fp), np.asarray(idx), np.asarray(fph)):
        pf, pi, ph = py_hash_key(k, seed, fp_mask)
        assert (int(f), int(i), int(h)) == (pf, pi, ph)


def test_zero_fingerprint_remapped():
    # find a key whose raw fp is 0 for a tiny mask, check remap to 1
    seed, fp_mask = 0, 0x1
    keys = np.arange(0, 4096, dtype=np.uint64)
    fp, _, _ = ref.hash_batch_ref(keys, np.uint64(seed), np.uint32(fp_mask))
    fp = np.asarray(fp)
    raw = [(py_mix64(int(k)) >> 32) & fp_mask for k in keys]
    assert any(r == 0 for r in raw), "test needs at least one zero raw fp"
    assert (fp >= 1).all() and (fp <= 1).all()  # mask 0x1 → everything is 1


# --------------------------------------------------------- pallas-vs-ref
@pytest.mark.parametrize("block", [256, 1024])
@pytest.mark.parametrize("nblocks", [1, 2, 4])
def test_pallas_matches_ref_shapes(block, nblocks):
    rng = np.random.default_rng(block + nblocks)
    n = block * nblocks
    keys = rng.integers(0, MASK64, size=n, dtype=np.uint64)
    seed = np.uint64(rng.integers(0, MASK64, dtype=np.uint64))
    mask = np.uint32(0xFFFF)
    want = ref.hash_batch_ref(keys, seed, mask)
    got = hash_batch_pallas(keys, np.array([seed]), np.array([mask]), block=block)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=MASK64),
    fp_bits=st.sampled_from([4, 8, 12, 16, 24, 32]),
    data=st.data(),
)
def test_pallas_matches_ref_hypothesis(seed, fp_bits, data):
    """Hypothesis sweep: random seeds, fingerprint widths, key batches."""
    n = data.draw(st.sampled_from([64, 128, 256]))
    keys = np.array(
        data.draw(
            st.lists(
                st.integers(min_value=0, max_value=MASK64), min_size=n, max_size=n
            )
        ),
        dtype=np.uint64,
    )
    fp_mask = np.uint32((1 << fp_bits) - 1 if fp_bits < 32 else MASK32)
    want = ref.hash_batch_ref(keys, np.uint64(seed), fp_mask)
    got = hash_batch_pallas(
        keys, np.array([seed], dtype=np.uint64), np.array([fp_mask]), block=64
    )
    for w, g in zip(want, got):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_pallas_rejects_ragged_batch():
    keys = np.zeros(100, dtype=np.uint64)
    with pytest.raises(ValueError, match="not a multiple"):
        hash_batch_pallas(
            keys, np.zeros(1, np.uint64), np.full(1, 0xFFFF, np.uint32), block=64
        )


def test_seed_changes_everything():
    keys = np.arange(1024, dtype=np.uint64)
    a = ref.hash_batch_ref(keys, np.uint64(1), np.uint32(0xFFFF))
    b = ref.hash_batch_ref(keys, np.uint64(2), np.uint32(0xFFFF))
    # different seeds must decorrelate fingerprints almost everywhere
    same = (np.asarray(a[0]) == np.asarray(b[0])).mean()
    assert same < 0.05

"""AOT lowering sanity: HLO text artifacts parse-ready for the rust side."""

import os

import numpy as np
import pytest

from compile import aot


def test_lower_hash_emits_entry():
    text = aot.lower_hash(256)
    assert "ENTRY" in text
    assert "u64[256]" in text  # key batch shape survives lowering
    assert "u32[256]" in text  # outputs


def test_lower_probe_emits_entry():
    text = aot.lower_probe(64, 64)
    assert "ENTRY" in text
    assert f"u32[{64 * aot.SLOTS}]" in text


def test_lower_hash_probe_emits_entry():
    text = aot.lower_hash_probe(64, 64)
    assert "ENTRY" in text


def test_lowered_hash_has_no_custom_calls():
    """interpret=True must lower pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    text = aot.lower_hash(256)
    assert "custom-call" not in text.lower()


def test_emit_to_tmpdir(tmp_path, monkeypatch):
    """End-to-end: aot.main writes artifacts + manifests."""
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path)]
    )
    # shrink the workload for test speed
    monkeypatch.setattr(aot, "HASH_BATCH_SIZES", (256,))
    monkeypatch.setattr(aot, "PROBE_NBUCKETS", 64)
    monkeypatch.setattr(aot, "PROBE_BATCH", 64)
    aot.main()
    files = sorted(os.listdir(tmp_path))
    assert "hash_b256.hlo.txt" in files
    assert "probe_nb64_b64.hlo.txt" in files
    assert "hash_probe_nb64_b64.hlo.txt" in files
    assert "manifest.txt" in files and "manifest.json" in files
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 3
    for line in manifest:
        fields = dict(kv.split("=", 1) for kv in line.split(";"))
        assert {"file", "kind", "batch", "outputs"} <= set(fields)
        assert (tmp_path / fields["file"]).exists()


def test_out_accepts_legacy_file_path(tmp_path, monkeypatch):
    """Makefile used to pass artifacts/model.hlo.txt — dir is derived."""
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path / "model.hlo.txt")]
    )
    monkeypatch.setattr(aot, "HASH_BATCH_SIZES", (256,))
    monkeypatch.setattr(aot, "PROBE_NBUCKETS", 64)
    monkeypatch.setattr(aot, "PROBE_BATCH", 64)
    aot.main()
    assert (tmp_path / "manifest.txt").exists()


def test_numeric_roundtrip_through_lowered_fn():
    """Executing the jitted (pre-lowering) fn equals the oracle — the
    same computation the artifact freezes."""
    from compile import model
    from compile.kernels import ref

    rng = np.random.default_rng(5)
    keys = rng.integers(0, (1 << 64) - 1, size=256, dtype=np.uint64)
    seed = np.array([123456789], dtype=np.uint64)
    mask = np.array([0xFFFF], dtype=np.uint32)
    got = model.hash_batch(keys, seed, mask)
    want = ref.hash_batch_ref(keys, seed[0], mask[0])
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

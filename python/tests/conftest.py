"""Shared fixtures: make `compile` importable and force x64 first."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import compile  # noqa: E402,F401  (sets jax_enable_x64 before any jax use)

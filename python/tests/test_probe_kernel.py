"""Pallas probe kernel vs pure-jnp oracle + semantic properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.probe_kernel import probe_batch_pallas

SLOTS = ref.SLOTS
MASK32 = (1 << 32) - 1


def random_table(rng, nbuckets, fill=0.5):
    """Bucket table with ~fill of slots occupied by nonzero fingerprints."""
    t = rng.integers(1, 1 << 16, size=nbuckets * SLOTS, dtype=np.uint32)
    empty = rng.random(nbuckets * SLOTS) > fill
    t[empty] = 0
    return t


@pytest.mark.parametrize("nbuckets", [8, 64, 1024])
def test_probe_matches_ref(nbuckets):
    rng = np.random.default_rng(nbuckets)
    table = random_table(rng, nbuckets)
    n = 256
    fp = rng.integers(1, 1 << 16, size=n, dtype=np.uint32)
    i1 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    i2 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    want = np.asarray(ref.probe_batch_ref(table, fp, i1, i2))
    got = np.asarray(probe_batch_pallas(table, fp, i1, i2, block=64))
    np.testing.assert_array_equal(want, got)


def test_planted_fingerprints_found():
    """Every fingerprint planted in bucket i1 or i2 must be reported present."""
    rng = np.random.default_rng(3)
    nbuckets, n = 128, 64
    table = np.zeros(nbuckets * SLOTS, dtype=np.uint32)
    fp = rng.integers(1, 1 << 16, size=n, dtype=np.uint32)
    i1 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    i2 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    for q in range(n):
        # plant into the first free slot of either candidate bucket so
        # plants never overwrite each other (deterministic seed keeps
        # both buckets from ever being full at n=64, nbuckets=128)
        planted = False
        for bucket in (int(i1[q]), int(i2[q])):
            for slot in range(SLOTS):
                if table[bucket * SLOTS + slot] == 0:
                    table[bucket * SLOTS + slot] = fp[q]
                    planted = True
                    break
            if planted:
                break
        assert planted
    got = np.asarray(probe_batch_pallas(table, fp, i1, i2, block=64))
    assert (got == 1).all()


def test_empty_table_all_absent():
    nbuckets, n = 64, 128
    table = np.zeros(nbuckets * SLOTS, dtype=np.uint32)
    fp = np.full(n, 7, dtype=np.uint32)
    idx = np.zeros(n, dtype=np.uint32)
    got = np.asarray(probe_batch_pallas(table, fp, idx, idx, block=64))
    assert (got == 0).all()


def test_zero_fingerprint_never_matches_by_contract():
    """fp=0 is reserved EMPTY; the hash path never emits it (remap to 1),
    so a 0 query would match empty slots — assert the hash upholds the
    contract instead of the probe guarding it."""
    rng = np.random.default_rng(11)
    keys = rng.integers(0, (1 << 64) - 1, size=4096, dtype=np.uint64)
    fp, _, _ = ref.hash_batch_ref(keys, np.uint64(0), np.uint32(0xF))
    assert (np.asarray(fp) != 0).all()


@settings(max_examples=20, deadline=None)
@given(
    nbuckets=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**32 - 1),
)
def test_probe_hypothesis(nbuckets, seed):
    rng = np.random.default_rng(seed)
    table = random_table(rng, nbuckets, fill=float(rng.random()))
    n = 64
    fp = rng.integers(1, 1 << 12, size=n, dtype=np.uint32)
    i1 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    i2 = rng.integers(0, nbuckets, size=n, dtype=np.uint32)
    want = np.asarray(ref.probe_batch_ref(table, fp, i1, i2))
    got = np.asarray(probe_batch_pallas(table, fp, i1, i2, block=64))
    np.testing.assert_array_equal(want, got)


def test_probe_rejects_ragged():
    table = np.zeros(64 * SLOTS, dtype=np.uint32)
    q = np.zeros(100, dtype=np.uint32)
    with pytest.raises(ValueError, match="not a multiple"):
        probe_batch_pallas(table, q, q, q, block=64)

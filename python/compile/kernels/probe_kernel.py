"""Layer-1 Pallas kernel: batched bucket probe (membership test).

Probes a *frozen* bucket table — the serialized form of an immutable
filter (e.g. the per-SSTable filter written at flush time, whose
capacity never changes again) — with a batch of pre-hashed queries.

TPU mapping: the table is small enough to pin in VMEM for the whole
grid (nbuckets × 4 slots × 4 B; 256 KiB at nbuckets=2^14), queries
stream through in 1-D tiles.  Each grid step gathers the two candidate
buckets per query and reduces the 4-way slot compare with ``any`` —
pure VPU work.

``interpret=True`` as everywhere (see hash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SLOTS

U32 = jnp.uint32

DEFAULT_QUERY_BLOCK = 1024


def _probe_tile_kernel(table_ref, fp_ref, i1_ref, i2_ref, out_ref):
    """One tile of queries against the whole (VMEM-resident) table."""
    table = table_ref[...].reshape(-1, SLOTS)
    fp = fp_ref[...]
    i1 = i1_ref[...].astype(jnp.int32)
    i2 = i2_ref[...].astype(jnp.int32)
    b1 = table[i1]  # [block, SLOTS] gather
    b2 = table[i2]
    hit = jnp.any(b1 == fp[:, None], axis=1) | jnp.any(b2 == fp[:, None], axis=1)
    out_ref[...] = hit.astype(U32)


@functools.partial(jax.jit, static_argnames=("block",))
def probe_batch_pallas(table, fp, i1, i2, *, block: int = DEFAULT_QUERY_BLOCK):
    """Pallas-tiled batched membership probe.

    Args:
      table: ``u32[nbuckets * SLOTS]`` frozen bucket table (row-major).
      fp:    ``u32[B]`` query fingerprints.
      i1:    ``u32[B]`` primary bucket indices (already masked).
      i2:    ``u32[B]`` alternate bucket indices (already masked).
      block: queries per grid step; ``B`` must be a multiple.

    Returns:
      ``u32[B]`` of 0/1 membership verdicts.
    """
    table = jnp.asarray(table, U32)
    fp = jnp.asarray(fp, U32)
    i1 = jnp.asarray(i1, U32)
    i2 = jnp.asarray(i2, U32)
    n = fp.shape[0]
    block = min(block, n)  # small batches become a single tile
    if n % block != 0:
        raise ValueError(f"batch {n} not a multiple of block {block}")
    if table.shape[0] % SLOTS != 0:
        raise ValueError("table length must be a multiple of SLOTS")
    grid = (n // block,)
    table_spec = pl.BlockSpec(table.shape, lambda i: (0,))  # whole table, every step
    tile_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _probe_tile_kernel,
        grid=grid,
        in_specs=[table_spec, tile_spec, tile_spec, tile_spec],
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((n,), U32),
        interpret=True,
    )(table, fp, i1, i2)

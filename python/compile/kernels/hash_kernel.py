"""Layer-1 Pallas kernel: batched fingerprint hashing.

The ingest hot-spot of the OCF pipeline: for every key in a batch,
compute the partial-key-cuckoo triple ``(fp, idx_hash, fp_hash)``
(see ``ref.hash_batch_ref`` for the exact specification).

TPU mapping (DESIGN.md §Hardware-Adaptation): keys stream through VMEM
in 1-D tiles of ``block`` keys via ``BlockSpec``; the body is pure VPU
element-wise integer work (adds/mults/shifts/xors) — there is no MXU
work in this paper's hot path, so the roofline is the VPU/HBM one.
VMEM per grid step: block × (8 B in + 3 × 4 B out) = 20 B/key →
20 KiB at block=1024, far under the ~16 MiB VMEM budget; double
buffering of in/out tiles still fits hundreds of blocks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret-mode lowers the kernel to plain HLO so
the same artifact runs on the rust CPU client (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import GOLDEN_GAMMA, MIX32_M1, MIX32_M2, MIX64_M1, MIX64_M2

U64 = jnp.uint64
U32 = jnp.uint32

DEFAULT_BLOCK = 1024


def _hash_tile_kernel(seed_ref, mask_ref, keys_ref, fp_ref, idx_ref, fph_ref):
    """Kernel body: one VMEM tile of keys -> three u32 tiles.

    The mix chains are written out inline (rather than calling ref.mix64)
    so the kernel stays self-contained and the VPU sees one straight-line
    dependency chain per lane.
    """
    seed = seed_ref[0]
    mask = mask_ref[0]
    z = keys_ref[...] ^ seed
    # -- mix64 (SplitMix64 next()) --
    z = z + U64(GOLDEN_GAMMA)
    z = (z ^ (z >> U64(30))) * U64(MIX64_M1)
    z = (z ^ (z >> U64(27))) * U64(MIX64_M2)
    h = z ^ (z >> U64(31))
    # -- split into fingerprint + primary-index hash --
    raw_fp = (h >> U64(32)).astype(U32) & mask
    fp = jnp.where(raw_fp == U32(0), U32(1), raw_fp)
    idx = (h & U64(0xFFFFFFFF)).astype(U32)
    # -- mix32 (murmur3 fmix32) of the fingerprint --
    w = fp
    w = (w ^ (w >> U32(16))) * U32(MIX32_M1)
    w = (w ^ (w >> U32(13))) * U32(MIX32_M2)
    fph = w ^ (w >> U32(16))
    fp_ref[...] = fp
    idx_ref[...] = idx
    fph_ref[...] = fph


@functools.partial(jax.jit, static_argnames=("block",))
def hash_batch_pallas(keys, seed, fp_mask, *, block: int = DEFAULT_BLOCK):
    """Pallas-tiled fingerprint pipeline.

    Args:
      keys:    ``u64[B]`` batch; ``B`` must be a multiple of ``block``
               (the rust batcher pads to the artifact's batch size).
      seed:    ``u64[1]`` per-filter seed (kept whole in every tile).
      fp_mask: ``u32[1]`` fingerprint mask ``(1 << fp_bits) - 1``.
      block:   tile length (keys per grid step).

    Returns:
      ``(fp, idx_hash, fp_hash)``, each ``u32[B]``.
    """
    keys = jnp.asarray(keys, U64)
    seed = jnp.asarray(seed, U64).reshape((1,))
    fp_mask = jnp.asarray(fp_mask, U32).reshape((1,))
    n = keys.shape[0]
    block = min(block, n)  # small batches become a single tile
    if n % block != 0:
        raise ValueError(f"batch {n} not a multiple of block {block}")
    grid = (n // block,)
    out_shape = [
        jax.ShapeDtypeStruct((n,), U32),
        jax.ShapeDtypeStruct((n,), U32),
        jax.ShapeDtypeStruct((n,), U32),
    ]
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    tile_spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _hash_tile_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, tile_spec],
        out_specs=[tile_spec, tile_spec, tile_spec],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(seed, fp_mask, keys)

"""Layer-1 Pallas kernels for the OCF fingerprint pipeline.

* ``hash_kernel``  — splitmix64 fingerprint/index hashing over key tiles.
* ``probe_kernel`` — batched 4-slot bucket membership probe.
* ``ref``          — pure-jnp oracle both kernels are verified against.
"""

from . import ref  # noqa: F401
from .hash_kernel import hash_batch_pallas  # noqa: F401
from .probe_kernel import probe_batch_pallas  # noqa: F401

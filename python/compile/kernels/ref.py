"""Pure-jnp reference oracle for the OCF fingerprint pipeline.

These are the *specification* implementations the Pallas kernels are
checked against at build time (pytest), and the bit-exact twins of the
rust fallback path in ``rust/src/filter/fingerprint.rs``.  Any change
here MUST be mirrored there (and vice versa) — the integration test
``rust/tests/runtime_integration.rs`` asserts rust == XLA on random keys.

Hash family
-----------
* ``mix64`` — the splitmix64 finalizer with the golden-gamma pre-add,
  i.e. exactly one ``next()`` step of SplitMix64 seeded with the key:
  ``mix64(0) == 0xE220A8397B1DCDAF`` (the well-known first SplitMix64
  output).
* ``mix32`` — the murmur3 32-bit finalizer (fmix32), used to derive the
  alternate-bucket displacement from a fingerprint alone (partial-key
  cuckoo hashing: ``i2 = i1 ^ mix32(fp)``).

All arithmetic is wrapping/unsigned; jax must run with x64 enabled
(``python/compile/__init__.py`` enforces this).
"""

from __future__ import annotations

import jax.numpy as jnp

# SplitMix64 constants (Steele, Lea & Flood 2014).
GOLDEN_GAMMA = 0x9E3779B97F4A7C15
MIX64_M1 = 0xBF58476D1CE4E5B9
MIX64_M2 = 0x94D049BB133111EB

# murmur3 fmix32 constants.
MIX32_M1 = 0x85EBCA6B
MIX32_M2 = 0xC2B2AE35

U64 = jnp.uint64
U32 = jnp.uint32

# Bucket width is frozen at 4 slots (paper §II.B: "recommended value for
# bucket size is 4") for the serialized/immutable probe path.
SLOTS = 4


def mix64(z):
    """SplitMix64 next(): wrapping u64 avalanche of ``z``."""
    z = jnp.asarray(z, U64)
    z = z + U64(GOLDEN_GAMMA)
    z = (z ^ (z >> U64(30))) * U64(MIX64_M1)
    z = (z ^ (z >> U64(27))) * U64(MIX64_M2)
    return z ^ (z >> U64(31))


def mix32(z):
    """murmur3 fmix32: wrapping u32 avalanche of ``z``."""
    z = jnp.asarray(z, U32)
    z = (z ^ (z >> U32(16))) * U32(MIX32_M1)
    z = (z ^ (z >> U32(13))) * U32(MIX32_M2)
    return z ^ (z >> U32(16))


def hash_batch_ref(keys, seed, fp_mask):
    """Fingerprint pipeline over a batch of u64 keys.

    Returns ``(fp, idx_hash, fp_hash)`` — all ``u32[B]``:

    * ``fp``       — fingerprint: high 32 bits of ``mix64(key ^ seed)``
                     masked to ``fp_mask``; 0 is reserved for EMPTY so a
                     zero fingerprint is remapped to 1.
    * ``idx_hash`` — low 32 bits of the same hash; the caller masks it
                     with ``nbuckets - 1`` to get the primary bucket.
    * ``fp_hash``  — ``mix32(fp)``; the caller computes the alternate
                     bucket as ``(i1 ^ fp_hash) & (nbuckets - 1)``.

    ``seed`` is a u64 scalar (per-filter seed); ``fp_mask`` a u32 scalar
    (``(1 << fp_bits) - 1``).  Bit-exact twin of
    ``rust/src/filter/fingerprint.rs::hash_key``.
    """
    keys = jnp.asarray(keys, U64)
    h = mix64(keys ^ jnp.asarray(seed, U64))
    raw_fp = (h >> U64(32)).astype(U32) & jnp.asarray(fp_mask, U32)
    fp = jnp.where(raw_fp == U32(0), U32(1), raw_fp)
    idx_hash = (h & U64(0xFFFFFFFF)).astype(U32)
    fp_hash = mix32(fp)
    return fp, idx_hash, fp_hash


def probe_batch_ref(table, fp, i1, i2):
    """Batched membership probe against a frozen bucket table.

    ``table`` is ``u32[nbuckets * SLOTS]`` (row-major buckets), the
    serialized form of an immutable (e.g. flushed-SSTable) filter.
    ``fp/i1/i2`` are ``u32[B]`` (indices already masked to the table).
    Returns ``u32[B]`` of 0/1: whether the fingerprint is present in
    either candidate bucket.
    """
    table = jnp.asarray(table, U32)
    fp = jnp.asarray(fp, U32)
    i1 = jnp.asarray(i1, U32).astype(jnp.int32)
    i2 = jnp.asarray(i2, U32).astype(jnp.int32)
    t = table.reshape(-1, SLOTS)
    b1 = t[i1]  # [B, SLOTS]
    b2 = t[i2]
    hit = jnp.any(b1 == fp[:, None], axis=1) | jnp.any(b2 == fp[:, None], axis=1)
    return hit.astype(U32)


def alt_index_ref(i, fp_hash, nbuckets):
    """Alternate bucket: ``(i ^ mix32(fp)) & (nbuckets-1)`` (power-of-two)."""
    return (jnp.asarray(i, U32) ^ jnp.asarray(fp_hash, U32)) & U32(nbuckets - 1)

"""OCF compile path (build-time only; never imported at runtime).

Layer 1 (Pallas kernels) and Layer 2 (JAX model) live here; ``aot.py``
lowers them once to HLO text under ``artifacts/`` for the rust runtime.

x64 MUST be enabled before any jax array is created: the hash pipeline
is u64 end-to-end and must be bit-exact with the rust implementation.
"""

import jax

jax.config.update("jax_enable_x64", True)

"""Layer-2 JAX model: the OCF batched fingerprint pipeline.

This is the compute graph the rust coordinator executes on its hot
path (via the AOT HLO artifacts).  It composes the Layer-1 Pallas
kernels into the three entry points the runtime loads:

* ``hash_batch``   — key batch → (fp, idx_hash, fp_hash); used by the
                     ingest batcher for every insert/lookup/delete batch.
* ``probe_batch``  — pre-hashed queries × frozen table → membership;
                     used for batched reads against immutable SSTable
                     filters.
* ``hash_and_probe`` — fused hash+probe for the read path against one
                     frozen table: one round trip instead of two.

Shapes are static per artifact (PJRT AOT requirement); the rust batcher
pads the tail batch with duplicate keys and trims the outputs.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.hash_kernel import hash_batch_pallas
from .kernels.probe_kernel import probe_batch_pallas

U64 = jnp.uint64
U32 = jnp.uint32


def hash_batch(keys, seed, fp_mask):
    """Fingerprint pipeline over ``u64[B]`` keys (see kernels.ref)."""
    return hash_batch_pallas(keys, seed, fp_mask)


def probe_batch(table, fp, i1, i2):
    """Membership of pre-hashed queries in a frozen bucket table."""
    return (probe_batch_pallas(table, fp, i1, i2),)


def hash_and_probe(keys, seed, fp_mask, table, nbuckets_mask):
    """Fused read path: hash keys, derive both bucket indices for the
    frozen table (power-of-two sized, ``nbuckets_mask = nbuckets-1``),
    probe, and also return the triple so the caller can reuse it for
    memtable-side checks.

    Returns ``(present, fp, i1, i2)``.
    """
    fp, idx_hash, fp_hash = hash_batch_pallas(keys, seed, fp_mask)
    mask = jnp.asarray(nbuckets_mask, U32).reshape(())
    i1 = idx_hash & mask
    i2 = (i1 ^ fp_hash) & mask
    present = probe_batch_pallas(table, fp, i1, i2)
    return present, fp, i1, i2

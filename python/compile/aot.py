"""AOT lowering: JAX/Pallas model → HLO *text* artifacts for rust/PJRT.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts`` (incremental: the Makefile only reruns
this when a compile-path source changed).  Outputs:

  artifacts/
    hash_b{256,1024,4096}.hlo.txt          hash_batch at 3 batch sizes
    probe_nb16384_b1024.hlo.txt            frozen-table probe
    hash_probe_nb16384_b1024.hlo.txt       fused read path
    manifest.txt                           one `k=v;...` line per artifact
                                           (parsed by rust/src/runtime/artifacts.rs)
    manifest.json                          same, for humans/tools

Python never runs on the request path: the rust binary is self-contained
once these files exist.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

# allow `python -m compile.aot` from python/ and `python aot.py` from compile/
if __package__ in (None, ""):  # pragma: no cover
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    import compile  # noqa: F401  (sets jax_enable_x64)
    from compile import model
else:
    from . import model

from jax._src.lib import xla_client as xc

U64 = jnp.uint64
U32 = jnp.uint32

HASH_BATCH_SIZES = (256, 1024, 4096)
PROBE_NBUCKETS = 16384  # frozen-table artifact size (SSTable filters)
PROBE_BATCH = 1024
SLOTS = 4


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_hash(batch: int) -> str:
    """hash_batch: (u64[B] keys, u64[1] seed, u32[1] fp_mask) -> 3×u32[B]."""
    lowered = jax.jit(model.hash_batch).lower(
        _spec((batch,), U64), _spec((1,), U64), _spec((1,), U32)
    )
    return to_hlo_text(lowered)


def lower_probe(nbuckets: int, batch: int) -> str:
    """probe_batch: (u32[nb*4] table, u32[B] fp, u32[B] i1, u32[B] i2) -> u32[B]."""
    lowered = jax.jit(model.probe_batch).lower(
        _spec((nbuckets * SLOTS,), U32),
        _spec((batch,), U32),
        _spec((batch,), U32),
        _spec((batch,), U32),
    )
    return to_hlo_text(lowered)


def lower_hash_probe(nbuckets: int, batch: int) -> str:
    """hash_and_probe: fused read path against one frozen table."""
    lowered = jax.jit(model.hash_and_probe).lower(
        _spec((batch,), U64),
        _spec((1,), U64),
        _spec((1,), U32),
        _spec((nbuckets * SLOTS,), U32),
        _spec((1,), U32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
        help="artifacts output directory (or a path inside it)",
    )
    args = ap.parse_args()
    out_dir = args.out
    # Makefile historically passed artifacts/model.hlo.txt; accept a file
    # path and use its directory.
    if out_dir.endswith(".txt"):
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    entries = []

    def emit(name: str, text: str, **meta) -> None:
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append({"file": name, "sha256_16": digest, **meta})
        print(f"wrote {name} ({len(text)} chars)")

    for b in HASH_BATCH_SIZES:
        emit(
            f"hash_b{b}.hlo.txt",
            lower_hash(b),
            kind="hash",
            batch=b,
            outputs=3,
        )
    emit(
        f"probe_nb{PROBE_NBUCKETS}_b{PROBE_BATCH}.hlo.txt",
        lower_probe(PROBE_NBUCKETS, PROBE_BATCH),
        kind="probe",
        batch=PROBE_BATCH,
        nbuckets=PROBE_NBUCKETS,
        outputs=1,
    )
    emit(
        f"hash_probe_nb{PROBE_NBUCKETS}_b{PROBE_BATCH}.hlo.txt",
        lower_hash_probe(PROBE_NBUCKETS, PROBE_BATCH),
        kind="hash_probe",
        batch=PROBE_BATCH,
        nbuckets=PROBE_NBUCKETS,
        outputs=4,
    )

    # manifest.txt: trivially parseable `k=v;k=v` lines for the rust side.
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        for e in entries:
            f.write(";".join(f"{k}={v}" for k, v in e.items()) + "\n")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(entries, f, indent=2)
    print(f"manifest: {len(entries)} artifacts in {out_dir}")


if __name__ == "__main__":
    main()

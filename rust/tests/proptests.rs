//! Property-based tests on coordinator/filter invariants (see
//! `ocf::testutil::prop` — the in-crate property harness).
//!
//! The invariants (DESIGN.md, `filter::ocf` docs):
//!  P1  no false negatives: every inserted, undeleted key is contained;
//!  P2  `len()` equals the number of distinct live keys;
//!  P3  occupancy after every op stays ≤ safe_load;
//!  P4  verified deletes of absent keys change nothing;
//!  P5  pipeline batching is semantically transparent;
//!  P6  KeyStore behaves as a set under arbitrary op sequences;
//!  P7  frozen-filter serialization preserves membership answers;
//!  P8  router replication: every acked write is readable;
//!  P9  failure atomicity: under Static-mode full pressure, every op
//!      (including failed inserts) leaves `len()`, the resident
//!      fingerprint count and the keystore mutually consistent;
//!  P10 the sharded front-end is semantically transparent vs plain OCF
//!      and safe under concurrent disjoint writers;
//!  P11 the batched probe engine (`contains_batch`/`insert_batch`) is
//!      bit-identical to scalar op loops for both table backends,
//!      across non-power-of-two sizes and fingerprint widths 4..=32;
//!  P12 the Filter API v2 contract: for EVERY `BatchedFilter` backend
//!      the builder can name (both bucket tables, non-pow2 sizes), the
//!      engine-overridden batch impls are bit-identical to the default
//!      scalar trait impls, `dyn` dispatch included — and a
//!      bloom-backed `StorageNode::get_batch` equals its scalar `get`
//!      loop end-to-end;
//!  P13 the pooled ingest engine is accounting-transparent: for
//!      arbitrary op mixes, batch sizes, worker counts 1..=8, queue
//!      depths and chunk grains, `run_pooled` over a `ShardedOcf`
//!      produces a report count-identical (incl. lookup hits) to
//!      `run_sharded` with identical filter end-state, and `run_pooled`
//!      over a `MutexFilter`-wrapped OCF matches the scalar `run`'s op
//!      counts, hits (static sizing: layout is interleaving-proof) and
//!      exact end-state.
//!  P14 every available `ProbeKernel` (scalar, SWAR, SSE2, AVX2/NEON
//!      where detected) is observationally identical: kernel-level
//!      primitives agree with the scalar reference on presence,
//!      first-match lane and insert-slot choice on raw buckets of both
//!      tables across fp widths 4..=32 and non-pow2 sizes, and whole
//!      filters built per kernel stay bit-identical (`to_frozen`)
//!      through arbitrary insert/contains/delete batches.
//!  P15 the persistent frozen tier is probe-transparent: a frozen
//!      snapshot written through the v1 on-disk format and reopened
//!      (heap-decoded, and mmap-backed where supported) answers every
//!      probe identically to the in-memory table it came from — for
//!      both bucket-table backends, fp widths 4..=32 and
//!      non-power-of-two sizes — and the reopened words are
//!      bit-identical to the written ones.
//!  P16 WAL replay is idempotent and order-preserving: after a crash at
//!      any injected fault point, recovery reconstructs exactly the
//!      durable prefix (modulo the one in-flight op), twice over;
//!  P17 adaptive fingerprints never cost a false negative: under random
//!      op mixes interleaved with FP-report storms (absent *and*
//!      resident keys hammered through `report_false_positive`), every
//!      live key stays visible on both the scalar and batched probe
//!      paths, remapped keys stay delete-able, and the sidecar drains
//!      to zero once the filter empties — for both bucket tables and
//!      the full selector/extension-width grid.
//!  P18 the chaos layer is deterministic and the ring rebalance is
//!      minimal: (a) a chaos-sweep run is a pure function of its seed —
//!      same `(seed, ops, fault_rate)` reproduces bit-identical answers
//!      and counters; (b) adding one node to an `n`-node ring moves
//!      only the keys the new node captures (primary changes iff the
//!      new node is the new primary, replica-set growth ⊆ {new node},
//!      at most one old replica displaced per key), with the moved
//!      fraction near 1/(n+1) — and node removal is the exact mirror.
//!  P19 live membership is deterministic and conserving: (a) a chaos
//!      run with a mid-schedule node join and node leave is a pure
//!      function of its seed — bit-identical answers, counters, and
//!      per-node state; (b) the transfer conservation law holds on
//!      every run: each captured key is streamed exactly once or
//!      superseded by a newer direct write, never silently dropped
//!      (`keys_captured == keys_streamed + keys_superseded`), and the
//!      hint life-cycle extends exactly by the retired count
//!      (`queued == replayed + superseded + dropped + retired`).

use ocf::cluster::{Cluster, HashRing, ReplicationConfig};
use ocf::filter::{
    AdaptiveConfig, AdaptiveOcf, BatchedFilter, BucketTable, CuckooFilter, CuckooParams,
    FilterBuilder, FilterError, FilterFeedback, FlatTable, MembershipFilter, Mode, MutexFilter,
    Ocf, OcfConfig, PackedTable, ShardedOcf, VictimPolicy,
};
use ocf::pipeline::{BatchPolicy, IngestPipeline, PoolConfig};
use ocf::runtime::HashExecutor;
use ocf::store::{FlushPolicy, NodeConfig, StorageNode};
use ocf::testutil::prop::{prop_check, Gen};
use ocf::testutil::{run_one_membership_schedule, run_one_schedule};
use ocf::workload::Op;
use std::collections::HashSet;

/// A random op sequence plus the mode to run it under.
#[derive(Debug, Clone)]
struct OpCase {
    mode: Mode,
    ops: Vec<Op>,
}

fn gen_case(g: &mut Gen, max_ops: usize, keyspace: u64) -> OpCase {
    let mode = *g.choose(&[Mode::Pre, Mode::Eof]);
    let n = g.usize_in(10, max_ops);
    let mut live: Vec<u64> = Vec::new();
    let ops = g.vec(n, |g| {
        let r = g.f64();
        if r < 0.55 || live.is_empty() {
            let k = g.u64_below(keyspace);
            live.push(k);
            Op::Insert(k)
        } else if r < 0.8 {
            Op::Lookup(g.u64_below(keyspace))
        } else {
            let i = g.usize_in(0, live.len() - 1);
            Op::Delete(live.swap_remove(i))
        }
    });
    OpCase { mode, ops }
}

fn model_apply(ops: &[Op]) -> HashSet<u64> {
    let mut live = HashSet::new();
    for op in ops {
        match op {
            Op::Insert(k) => {
                live.insert(*k);
            }
            Op::Delete(k) => {
                live.remove(k);
            }
            Op::Lookup(_) => {}
        }
    }
    live
}

#[test]
fn p1_p2_p3_no_false_negatives_len_and_load() {
    prop_check(
        "ocf-invariants",
        60,
        |g| gen_case(g, 3000, 1 << 14),
        |case| {
            let mut f = Ocf::new(OcfConfig {
                mode: case.mode,
                initial_capacity: 1024,
                min_capacity: 256,
                ..OcfConfig::default()
            });
            for op in &case.ops {
                match op {
                    Op::Insert(k) => {
                        if f.insert(*k).is_err() {
                            return false;
                        }
                    }
                    Op::Lookup(k) => {
                        let _ = f.contains(*k);
                    }
                    Op::Delete(k) => {
                        f.delete(*k);
                    }
                }
                // P3
                if f.occupancy() > f.config().safe_load + 1e-9 {
                    return false;
                }
            }
            let live = model_apply(&case.ops);
            // P2
            if f.len() != live.len() {
                return false;
            }
            // P1
            live.iter().all(|&k| f.contains(k))
        },
    );
}

#[test]
fn p4_absent_deletes_are_inert() {
    prop_check(
        "absent-delete-inert",
        40,
        |g| {
            let nkeys = g.usize_in(50, 500);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 30));
            let hostile = g.vec(200, |g| (1u64 << 40) + g.u64_below(1 << 20));
            (keys, hostile)
        },
        |(keys, hostile)| {
            let mut f = Ocf::new(OcfConfig {
                initial_capacity: 1024,
                ..OcfConfig::default()
            });
            for &k in keys {
                f.insert(k).unwrap();
            }
            let before: Vec<bool> = keys.iter().map(|&k| f.contains(k)).collect();
            for &h in hostile {
                if f.delete(h) {
                    return false; // verified delete must reject
                }
            }
            let after: Vec<bool> = keys.iter().map(|&k| f.contains(k)).collect();
            before == after && f.len() == {
                let s: HashSet<_> = keys.iter().collect();
                s.len()
            }
        },
    );
}

#[test]
fn p5_pipeline_transparent() {
    prop_check(
        "pipeline-transparent",
        25,
        |g| {
            let case = gen_case(g, 2000, 1 << 12);
            let batch = *g.choose(&[1usize, 7, 64, 333, 1024]);
            (case, batch)
        },
        |(case, batch)| {
            let cfg = OcfConfig {
                mode: case.mode,
                initial_capacity: 1024,
                ..OcfConfig::default()
            };
            let mut direct = Ocf::new(cfg);
            for op in &case.ops {
                match op {
                    Op::Insert(k) => {
                        let _ = direct.insert(*k);
                    }
                    Op::Lookup(k) => {
                        let _ = direct.contains(*k);
                    }
                    Op::Delete(k) => {
                        direct.delete(*k);
                    }
                }
            }
            let mut piped = Ocf::new(cfg);
            let mut p = IngestPipeline::new(
                BatchPolicy {
                    max_batch: *batch,
                    max_delay: std::time::Duration::from_secs(10),
                },
                HashExecutor::native(piped.hasher()),
            );
            p.run(case.ops.iter().copied(), &mut piped);
            if direct.len() != piped.len() {
                return false;
            }
            // membership answers identical across a probe sample
            (0..(1u64 << 12)).step_by(61).all(|k| direct.contains(k) == piped.contains(k))
        },
    );
}

#[test]
fn p6_keystore_is_a_set() {
    use ocf::filter::KeyStore;
    prop_check(
        "keystore-set-semantics",
        40,
        |g| {
            let n = g.usize_in(10, 2000);
            g.vec(n, |g| {
                let k = g.u64_below(300); // tight keyspace → collisions
                match g.usize_in(0, 2) {
                    0 => Op::Insert(k),
                    1 => Op::Delete(k),
                    _ => Op::Lookup(k),
                }
            })
        },
        |ops| {
            let mut ks = KeyStore::new();
            let mut model = HashSet::new();
            for op in ops {
                match op {
                    Op::Insert(k) => {
                        if ks.insert(*k) != model.insert(*k) {
                            return false;
                        }
                    }
                    Op::Delete(k) => {
                        if ks.remove(*k) != model.remove(k) {
                            return false;
                        }
                    }
                    Op::Lookup(k) => {
                        if ks.contains(*k) != model.contains(k) {
                            return false;
                        }
                    }
                }
            }
            ks.len() == model.len() && ks.iter().collect::<HashSet<_>>() == model
        },
    );
}

#[test]
fn p7_frozen_filter_preserves_answers() {
    use ocf::runtime::ProbeExecutor;
    prop_check(
        "frozen-roundtrip",
        30,
        |g| {
            let n = g.usize_in(10, 3000);
            g.vec(n, |g| g.u64())
        },
        |keys| {
            use ocf::filter::{CuckooFilter, CuckooParams, FlatTable};
            // frozen tables are always pow2-bucketed (xor index mapping
            // baked into the serialized layout) — match that here
            let capacity = (keys.len() * 4).next_power_of_two();
            let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
                capacity,
                ..CuckooParams::default()
            });
            for &k in keys {
                if f.insert(k).is_err() {
                    return true; // astronomically unlikely at 4×; skip
                }
            }
            let table = f.to_frozen();
            let h = f.hasher();
            let probes: Vec<u64> = keys.iter().copied().chain(0..500).collect();
            let triples: Vec<_> = probes.iter().map(|&k| h.hash_key(k)).collect();
            let frozen = ProbeExecutor::probe_native(&table, f.nbuckets(), &triples);
            probes
                .iter()
                .zip(frozen)
                .all(|(&k, hit)| hit == f.contains(k))
        },
    );
}

#[test]
fn p9_full_pressure_keeps_filter_and_keystore_consistent() {
    prop_check(
        "full-pressure-atomicity",
        40,
        |g| {
            // tight keyspace + tiny static filter → guaranteed Full
            // pressure with interleaved deletes and duplicate inserts
            let n = g.usize_in(200, 2500);
            let keyspace = g.u64_below(2000) + 200;
            g.vec(n, |g| {
                let k = g.u64_below(keyspace);
                if g.f64() < 0.7 {
                    Op::Insert(k)
                } else {
                    Op::Delete(k)
                }
            })
        },
        |ops| {
            let mut f = Ocf::new(OcfConfig {
                mode: Mode::Static,
                initial_capacity: 512,
                min_capacity: 256,
                ..OcfConfig::default()
            });
            let mut model = HashSet::new();
            for op in ops {
                match op {
                    Op::Insert(k) => match f.insert(*k) {
                        Ok(()) => {
                            model.insert(*k);
                        }
                        Err(_) => {
                            // failed insert must be a true no-op (a key
                            // already present can never fail — duplicate
                            // inserts return Ok before touching the table)
                            if model.contains(k) || f.contains_exact(*k) {
                                return false;
                            }
                        }
                    },
                    Op::Delete(k) => {
                        if f.delete(*k) != model.remove(k) {
                            return false;
                        }
                    }
                    Op::Lookup(_) => {}
                }
                // the P9 triple-equality after EVERY op
                if f.len() != model.len()
                    || f.len() != f.keystore_len()
                    || f.len() != f.fingerprint_count()
                {
                    return false;
                }
            }
            // P1 for survivors + keystore agreement on a sample
            model.iter().all(|&k| f.contains(k) && f.contains_exact(k))
                && (0..500u64).all(|k| f.contains_exact(k) == model.contains(&k))
        },
    );
}

#[test]
fn p10_sharded_matches_plain_ocf() {
    prop_check(
        "sharded-transparent",
        20,
        |g| {
            let shards = *g.choose(&[1usize, 2, 4, 8]);
            let case = gen_case(g, 2000, 1 << 12);
            (shards, case)
        },
        |(shards, case)| {
            let cfg = OcfConfig {
                mode: case.mode,
                initial_capacity: 2048,
                ..OcfConfig::default()
            };
            let sharded = ShardedOcf::with_shards(*shards, cfg);
            let mut model = HashSet::new();
            for op in &case.ops {
                match op {
                    Op::Insert(k) => {
                        if sharded.insert_one(*k).is_ok() {
                            model.insert(*k);
                        }
                    }
                    Op::Lookup(k) => {
                        // probabilistic filter: a model-present key must hit
                        if model.contains(k) && !sharded.contains_one(*k) {
                            return false;
                        }
                    }
                    Op::Delete(k) => {
                        if sharded.delete_one(*k) != model.remove(k) {
                            return false;
                        }
                    }
                }
            }
            if sharded.len() != model.len() {
                return false;
            }
            let keys: Vec<u64> = model.iter().copied().collect();
            sharded.contains_batch(&keys).iter().all(|&b| b)
        },
    );
}

#[test]
fn p10_sharded_concurrent_disjoint_writers() {
    let filter = ShardedOcf::with_shards(
        8,
        OcfConfig {
            initial_capacity: 4096,
            ..OcfConfig::default()
        },
    );
    let nthreads = 6u64;
    let per = 20_000u64;
    std::thread::scope(|s| {
        for t in 0..nthreads {
            let filter = &filter;
            s.spawn(move || {
                // disjoint range per thread; mixed batched ops
                let lo = t * per;
                let keys: Vec<u64> = (lo..lo + per).collect();
                for chunk in keys.chunks(1024) {
                    for r in filter.insert_batch(chunk) {
                        r.unwrap();
                    }
                }
                // delete the first half of this thread's range
                let dels: Vec<u64> = (lo..lo + per / 2).collect();
                for (i, ok) in filter.delete_batch(&dels).iter().copied().enumerate() {
                    assert!(ok, "thread {t}: delete of {} rejected", dels[i]);
                }
            });
        }
    });
    // cross-check the merged state from the main thread
    assert_eq!(filter.len(), (nthreads * per / 2) as usize);
    for t in 0..nthreads {
        let lo = t * per;
        let dead: Vec<u64> = (lo..lo + per / 2).collect();
        let live: Vec<u64> = (lo + per / 2..lo + per).collect();
        assert!(
            filter.contains_batch(&live).iter().all(|&b| b),
            "thread {t}: lost live keys"
        );
        let dead_hits = dead
            .iter()
            .filter(|&&k| filter.contains_exact(k))
            .count();
        assert_eq!(dead_hits, 0, "thread {t}: deleted keys resurrected");
    }
    let stats = filter.stats();
    assert_eq!(stats.inserts, nthreads * per);
    assert_eq!(stats.deletes, nthreads * per / 2);
}

#[test]
fn p8_replicated_writes_readable() {
    prop_check(
        "replicated-write-read",
        15,
        |g| {
            let nodes = g.usize_in(1, 6);
            let rf = g.usize_in(1, 3);
            let nkeys = g.usize_in(10, 800);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 32));
            (nodes, rf, keys)
        },
        |(nodes, rf, keys)| {
            let mut c = Cluster::new(
                *nodes,
                32,
                NodeConfig {
                    flush: FlushPolicy::small(10_000),
                    ..NodeConfig::default()
                },
                ReplicationConfig {
                    rf: *rf,
                    ..ReplicationConfig::default()
                },
            );
            for &k in keys {
                if c.put(k).is_err() {
                    return false;
                }
            }
            keys.iter().all(|&k| c.get(k).unwrap_or(false))
        },
    );
}

/// P11 case: a filter geometry + key/probe sets for the differential
/// batched-vs-scalar check.
#[derive(Debug, Clone)]
struct BatchCase {
    capacity: usize,
    fp_bits: u32,
    keys: Vec<u64>,
    probes: Vec<u64>,
}

fn gen_batch_case(g: &mut Gen) -> BatchCase {
    // deliberately includes non-power-of-two capacities so the Lemire
    // index + mod-subtract alt mapping paths are covered
    let capacity = *g.choose(&[192usize, 256, 500, 1000, 1024, 3000, 4096, 4100]);
    let fp_bits = g.usize_in(4, 32) as u32;
    let nkeys = g.usize_in(1, capacity); // up to saturation
    let keys = g.vec(nkeys, |g| g.u64_below(1 << 20));
    let nprobes = g.usize_in(1, 2000);
    let probes = g.vec(nprobes, |g| g.u64_below(1 << 21)); // ~half absent
    BatchCase {
        capacity,
        fp_bits,
        keys,
        probes,
    }
}

fn p11_check<T: BucketTable>(case: &BatchCase) -> bool {
    let params = CuckooParams {
        capacity: case.capacity,
        fp_bits: case.fp_bits,
        victim_policy: VictimPolicy::Rollback,
        ..CuckooParams::default()
    };
    let mut batched = CuckooFilter::<T>::new(params);
    let mut scalar = CuckooFilter::<T>::new(params);
    // insert_batch vs scalar insert loop: same accept/reject pattern,
    // bit-identical tables (same eviction RNG draws in the same order)
    let rb = batched.insert_batch(&case.keys);
    for (i, &k) in case.keys.iter().enumerate() {
        if rb[i].is_ok() != scalar.insert(k).is_ok() {
            return false;
        }
    }
    if batched.to_frozen() != scalar.to_frozen() || batched.len() != scalar.len() {
        return false;
    }
    // contains_batch vs scalar contains loop, positionally aligned
    let got = batched.contains_batch(&case.probes);
    if got.len() != case.probes.len() {
        return false;
    }
    case.probes
        .iter()
        .zip(&got)
        .all(|(&k, &b)| b == scalar.contains(k))
}

#[test]
fn p11_batched_probe_engine_matches_scalar() {
    prop_check(
        "batched-vs-scalar-flat",
        40,
        |g| gen_batch_case(g),
        p11_check::<FlatTable>,
    );
    prop_check(
        "batched-vs-scalar-packed",
        40,
        |g| gen_batch_case(g),
        p11_check::<PackedTable>,
    );
}

/// The P12 reference arm: expose ONLY the default (scalar)
/// `BatchedFilter` implementations for any backend, hiding whatever
/// engine overrides the inner filter has.
#[derive(Debug)]
struct DefaultBatch<F>(F);

impl<F: MembershipFilter> FilterFeedback for DefaultBatch<F> {
    fn report_false_positive(&self, key: u64) -> bool {
        self.0.report_false_positive(key)
    }
}

impl<F: MembershipFilter> MembershipFilter for DefaultBatch<F> {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        self.0.insert(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.0.contains(key)
    }
    fn delete(&mut self, key: u64) -> bool {
        self.0.delete(key)
    }
    fn len(&self) -> usize {
        self.0.len()
    }
    fn capacity(&self) -> usize {
        self.0.capacity()
    }
    fn memory_bytes(&self) -> usize {
        self.0.memory_bytes()
    }
    fn name(&self) -> &'static str {
        self.0.name()
    }
}

// No overrides: every batch method is the trait's scalar default.
impl<F: MembershipFilter> BatchedFilter for DefaultBatch<F> {}

/// P12 case: a backend name + geometry + op sets.
#[derive(Debug, Clone)]
struct V2Case {
    backend: &'static str,
    capacity: usize,
    fp_bits: u32,
    shards: usize,
    keys: Vec<u64>,
    probes: Vec<u64>,
    deletes: Vec<u64>,
}

fn gen_v2_case(g: &mut Gen) -> V2Case {
    let backend = *g.choose(&[
        "ocf-eof",
        "ocf-pre",
        "ocf-static",
        "sharded",
        "cuckoo",
        "cuckoo-packed",
        "bloom",
        "counting-bloom",
        "scalable-bloom",
        "adaptive",
        "adaptive-packed",
    ]);
    // non-power-of-two capacities exercise the Lemire index +
    // mod-subtract alt mapping inside the engine-backed backends
    let capacity = *g.choose(&[500usize, 1000, 1024, 3000, 4096, 4100]);
    let fp_bits = g.usize_in(4, 32) as u32;
    let nkeys = g.usize_in(1, 1500);
    let keys = g.vec(nkeys, |g| g.u64_below(1 << 20));
    let probes = g.vec(g.usize_in(1, 1500), |g| g.u64_below(1 << 21));
    let deletes = g.vec(g.usize_in(1, 500), |g| g.u64_below(1 << 20));
    V2Case {
        backend,
        capacity,
        fp_bits,
        shards: if backend == "sharded" {
            *g.choose(&[2usize, 4, 8])
        } else {
            1
        },
        keys,
        probes,
        deletes,
    }
}

fn v2_builder(case: &V2Case) -> FilterBuilder {
    let mut b = FilterBuilder::named(case.backend).unwrap();
    b.shards = case.shards.max(b.shards);
    b.ocf.initial_capacity = case.capacity;
    b.ocf.fp_bits = case.fp_bits;
    b
}

#[test]
fn p12_engine_batch_impls_match_default_scalar_impls() {
    prop_check(
        "v2-engine-vs-default",
        40,
        gen_v2_case,
        |case| {
            let builder = v2_builder(case);
            // engine arm: the backend's real BatchedFilter impl,
            // driven through `dyn` (object safety included in the pin)
            let mut engine = builder.build().unwrap();
            // reference arm: identical backend, default scalar impls
            let mut default = DefaultBatch(builder.build().unwrap());

            let ra = engine.insert_batch(&case.keys);
            let rb = default.insert_batch(&case.keys);
            if ra != rb || engine.len() != default.len() {
                return false;
            }
            if engine.contains_batch(&case.probes) != default.contains_batch(&case.probes) {
                return false;
            }
            let da = engine.delete_batch(&case.deletes);
            let db = default.delete_batch(&case.deletes);
            if da != db || engine.len() != default.len() {
                return false;
            }
            engine.contains_batch(&case.probes) == default.contains_batch(&case.probes)
        },
    );
}

#[test]
fn p12_bloom_backed_node_get_batch_matches_scalar() {
    prop_check(
        "v2-bloom-node-batch",
        20,
        |g| {
            let nkeys = g.usize_in(10, 2000);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 16));
            let dels = g.vec(g.usize_in(1, 300), |g| g.u64_below(1 << 16));
            let probes = g.vec(g.usize_in(1, 2000), |g| g.u64_below(1 << 17));
            (keys, dels, probes)
        },
        |(keys, dels, probes)| {
            let mut node = StorageNode::new(NodeConfig {
                filter: FilterBuilder::named("bloom")
                    .unwrap()
                    .with_initial_capacity(1 << 16),
                flush: FlushPolicy::small(500),
                ..NodeConfig::default()
            });
            for &k in keys {
                if node.put(k).is_err() {
                    return false;
                }
            }
            let mut model: HashSet<u64> = keys.iter().copied().collect();
            for &k in dels {
                if node.delete(k) != model.remove(&k) {
                    return false;
                }
            }
            // batched reads (default scalar batch impls on bloom) must
            // equal the scalar read loop AND the exact model
            let batched = node.get_batch(probes);
            probes.iter().zip(&batched).all(|(&k, &b)| {
                b == node.get(k) && (!model.contains(&k) || b)
            }) && node.live_keys() == model.len()
        },
    );
}

#[test]
fn p12_every_backend_drives_a_node_by_name() {
    // dyn object-safety smoke across the whole builder name table:
    // StorageNode (boxed BatchedFilter) + a mixed workload per backend
    for name in ocf::filter::FilterBackend::NAMES {
        let mut node = StorageNode::new(NodeConfig {
            filter: FilterBuilder::named(name)
                .unwrap()
                .with_initial_capacity(16_384),
            flush: FlushPolicy::small(1_500),
            ..NodeConfig::default()
        });
        let mut model = HashSet::new();
        for k in 0..4000u64 {
            node.put(k).unwrap_or_else(|e| panic!("{name}: put {k}: {e}"));
            model.insert(k);
        }
        for k in (0..4000u64).step_by(3) {
            assert_eq!(node.delete(k), model.remove(&k), "{name}: delete {k}");
        }
        assert_eq!(node.live_keys(), model.len(), "{name}");
        // Survivor visibility is guaranteed for EVERY backend: the node
        // never forwards deletes to a filter that cannot verify them
        // exactly, so probabilistic backends go stale instead of
        // growing false negatives.
        for &k in model.iter().take(500) {
            assert!(node.get(k), "{name}: lost {k}");
        }
        let absent: Vec<u64> = (9_000_000..9_000_500).collect();
        assert!(
            node.get_batch(&absent).iter().all(|&b| !b),
            "{name}: absent keys visible"
        );
    }
}

#[test]
fn p11_ocf_batch_apis_match_scalar() {
    // the OCF-level batch surface (resize policies in the loop) must
    // stay transparent too
    prop_check(
        "ocf-batch-vs-scalar",
        25,
        |g| {
            let mode = *g.choose(&[Mode::Pre, Mode::Eof, Mode::Static]);
            let nkeys = g.usize_in(10, 4000);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 16));
            let probes = g.vec(1000, |g| g.u64_below(1 << 17));
            (mode, keys, probes)
        },
        |(mode, keys, probes)| {
            let cfg = OcfConfig {
                mode: *mode,
                initial_capacity: 1024,
                min_capacity: 256,
                ..OcfConfig::default()
            };
            let mut a = Ocf::new(cfg);
            let mut b = Ocf::new(cfg);
            let ra = a.insert_batch(keys);
            for (i, &k) in keys.iter().enumerate() {
                if ra[i].is_ok() != b.insert(k).is_ok() {
                    return false;
                }
            }
            if a.len() != b.len() || a.capacity() != b.capacity() || a.to_frozen() != b.to_frozen()
            {
                return false;
            }
            let got = a.contains_batch(probes);
            probes.iter().zip(&got).all(|(&k, &g2)| g2 == b.contains(k))
        },
    );
}

/// A P14 case: a table geometry plus op/probe sets for the per-kernel
/// differential (fp widths 4..=32, non-pow2 bucket counts).
#[derive(Debug, Clone)]
struct KernelCase {
    capacity: usize,
    fp_bits: u32,
    keys: Vec<u64>,
    probes: Vec<u64>,
    deletes: Vec<u64>,
}

fn gen_kernel_case(g: &mut Gen) -> KernelCase {
    let capacity = *g.choose(&[192usize, 500, 1000, 1024, 3000, 4100]);
    let fp_bits = g.usize_in(4, 32) as u32;
    let nkeys = g.usize_in(1, capacity);
    KernelCase {
        capacity,
        fp_bits,
        keys: g.vec(nkeys, |g| g.u64_below(1 << 20)),
        probes: g.vec(g.usize_in(1, 1500), |g| g.u64_below(1 << 21)),
        deletes: g.vec(g.usize_in(1, 500), |g| g.u64_below(1 << 20)),
    }
}

/// Filter-level half of P14: for each available kernel, a filter built
/// with it must stay bit-identical to the scalar-kernel twin through
/// the whole batched op surface (same accept/reject pattern, same
/// eviction walks — i.e. identical insert-slot choices — same answers).
fn p14_filter_check<T: BucketTable>(case: &KernelCase) -> bool {
    use ocf::filter::kernel;
    let params = CuckooParams {
        capacity: case.capacity,
        fp_bits: case.fp_bits,
        victim_policy: VictimPolicy::Rollback,
        ..CuckooParams::default()
    };
    let mut reference = CuckooFilter::<T>::with_kernel(params, &kernel::SCALAR);
    let r_ins = reference.insert_batch(&case.keys);
    let r_con = reference.contains_batch(&case.probes);
    let r_del = reference.delete_batch(&case.deletes);
    let r_frozen = reference.to_frozen();
    for k in kernel::available() {
        let mut f = CuckooFilter::<T>::with_kernel(params, k);
        let ins = f.insert_batch(&case.keys);
        if ins.len() != r_ins.len()
            || ins.iter().zip(&r_ins).any(|(a, b)| a.is_ok() != b.is_ok())
        {
            return false;
        }
        if f.contains_batch(&case.probes) != r_con {
            return false;
        }
        if f.delete_batch(&case.deletes) != r_del {
            return false;
        }
        if f.to_frozen() != r_frozen || f.len() != reference.len() {
            return false;
        }
    }
    true
}

/// Primitive-level half of P14: every kernel's raw bucket scans agree
/// with the scalar reference on presence, first-match lane and
/// insert-slot choice, against live bucket contents of both tables.
fn p14_primitive_check(case: &KernelCase) -> bool {
    use ocf::filter::kernel::{self, SCALAR};
    use ocf::filter::SLOTS;
    let params = CuckooParams {
        capacity: case.capacity,
        fp_bits: case.fp_bits,
        victim_policy: VictimPolicy::Rollback,
        ..CuckooParams::default()
    };
    // Populate one flat + one packed table with the same keys (the
    // filters insert identically across table backends by P11).
    let mut flat = CuckooFilter::<FlatTable>::with_kernel(params, &SCALAR);
    let mut packed = CuckooFilter::<PackedTable>::with_kernel(params, &SCALAR);
    for &k in &case.keys {
        let _ = flat.insert(k);
        let _ = packed.insert(k);
    }
    let ft = flat.table();
    let pt = packed.table();
    let (lane_lsb, lane_msb) = pt.swar_consts();
    let hasher = flat.hasher();
    let nb = flat.nbuckets();
    for &p in &case.probes {
        let t = hasher.hash_key(p);
        let b1 = ocf::filter::Hasher::primary_index(t, nb);
        let b2 = ocf::filter::Hasher::alt_index(b1, t.fp, nb);
        let lanes1 = ft.bucket_lanes(b1);
        let lanes2 = ft.bucket_lanes(b2);
        let bits1 = pt.bucket_bits(b1);
        let want = SCALAR.flat_mask(&lanes1, t.fp);
        let want_slot = SCALAR.flat_insert_slot(&lanes1);
        let want_find = if want != 0 {
            Some(want.trailing_zeros() as usize)
        } else {
            None
        };
        let want_pm = SCALAR.packed_match(bits1, t.fp, lane_lsb, lane_msb);
        for k in kernel::available() {
            let m = k.flat_mask(&lanes1, t.fp);
            if (m != 0) != (want != 0) {
                return false;
            }
            if m != 0 && m.trailing_zeros() != want.trailing_zeros() {
                return false;
            }
            if k.flat_insert_slot(&lanes1) != want_slot {
                return false;
            }
            if k.flat_find_slot(&lanes1, t.fp) != want_find {
                return false;
            }
            let pair = k.flat_pair(&lanes1, &lanes2, t.fp);
            if ((pair & ((1 << SLOTS) - 1)) != 0) != (want != 0)
                || ((pair >> SLOTS) != 0) != (SCALAR.flat_mask(&lanes2, t.fp) != 0)
            {
                return false;
            }
            let pm = k.packed_match(bits1, t.fp, lane_lsb, lane_msb);
            if (pm != 0) != (want_pm != 0) {
                return false;
            }
            if pm != 0
                && pm.trailing_zeros() / case.fp_bits
                    != want_pm.trailing_zeros() / case.fp_bits
            {
                return false;
            }
        }
    }
    true
}

#[test]
fn p14_kernels_observationally_identical() {
    prop_check(
        "kernel-differential-flat",
        25,
        gen_kernel_case,
        p14_filter_check::<FlatTable>,
    );
    prop_check(
        "kernel-differential-packed",
        25,
        gen_kernel_case,
        p14_filter_check::<PackedTable>,
    );
    prop_check(
        "kernel-primitive-differential",
        25,
        gen_kernel_case,
        p14_primitive_check,
    );
}

/// A P13 case: an op mix plus the whole pooled-engine knob surface.
#[derive(Debug, Clone)]
struct PoolCase {
    ops: Vec<Op>,
    mode: Mode,
    batch: usize,
    shards: usize,
    workers: usize,
    queue_depth: usize,
    chunk: usize,
}

fn gen_pool_case(g: &mut Gen) -> PoolCase {
    let case = gen_case(g, 1500, 1 << 12);
    PoolCase {
        ops: case.ops,
        mode: case.mode,
        batch: *g.choose(&[1usize, 7, 64, 333]),
        shards: *g.choose(&[1usize, 2, 4]),
        workers: g.usize_in(1, 8),
        queue_depth: g.usize_in(1, 4),
        chunk: *g.choose(&[1usize, 16, 128]),
    }
}

#[test]
fn p13_pooled_report_matches_sharded_and_scalar() {
    prop_check("pooled-report-identity", 18, gen_pool_case, |case| {
        let pool = PoolConfig {
            workers: case.workers,
            queue_depth: case.queue_depth,
            chunk: case.chunk,
        };
        let policy = BatchPolicy {
            max_batch: case.batch,
            max_delay: std::time::Duration::from_secs(10),
        };

        // ---- sharded pair: run_pooled must equal run_sharded exactly
        // (same per-shard op streams → bit-identical shards) ----
        let cfg = OcfConfig {
            mode: case.mode,
            initial_capacity: 1024,
            min_capacity: 256,
            ..OcfConfig::default()
        };
        let a = ShardedOcf::with_shards(case.shards, cfg);
        let b = ShardedOcf::with_shards(case.shards, cfg);
        let ra = IngestPipeline::new(policy, HashExecutor::native(a.hasher()))
            .run_sharded(case.ops.iter().copied(), &a);
        let rb = IngestPipeline::new(policy, HashExecutor::native(b.hasher()))
            .run_pooled(case.ops.iter().copied(), &b, &pool);
        if (ra.ops, ra.batches, ra.inserts, ra.lookups, ra.lookup_hits, ra.deletes)
            != (rb.ops, rb.batches, rb.inserts, rb.lookups, rb.lookup_hits, rb.deletes)
        {
            return false;
        }
        if a.len() != b.len() || a.shard_lens() != b.shard_lens() {
            return false;
        }
        if !(0..(1u64 << 12))
            .step_by(61)
            .all(|k| a.contains_one(k) == b.contains_one(k))
        {
            return false;
        }

        // ---- generic pair: run_pooled over mutex<Ocf> vs scalar run.
        // Static sizing with ample headroom makes capacity (and thus
        // false-positive layout classes) independent of in-run
        // interleaving, so even lookup hits must agree exactly. ----
        let scfg = OcfConfig {
            mode: Mode::Static,
            initial_capacity: 1 << 14,
            min_capacity: 1 << 14,
            ..OcfConfig::default()
        };
        let mut scalar = Ocf::new(scfg);
        let rs = IngestPipeline::new(policy, HashExecutor::native(scalar.hasher()))
            .run(case.ops.iter().copied(), &mut scalar);
        let pooled = MutexFilter::new(Ocf::new(scfg));
        let rp = IngestPipeline::new(policy, HashExecutor::native(scalar.hasher()))
            .run_pooled(case.ops.iter().copied(), &pooled, &pool);
        if (rs.ops, rs.batches, rs.inserts, rs.lookups, rs.lookup_hits, rs.deletes)
            != (rp.ops, rp.batches, rp.inserts, rp.lookups, rp.lookup_hits, rp.deletes)
        {
            return false;
        }
        let inner = pooled.into_inner();
        if inner.len() != scalar.len() {
            return false;
        }
        // exact end-state agreement, model included
        let live = model_apply(&case.ops);
        inner.len() == live.len()
            && (0..(1u64 << 12))
                .step_by(43)
                .all(|k| inner.contains_exact(k) == scalar.contains_exact(k))
            && live.iter().all(|&k| inner.contains_exact(k))
    });
}

/// A P15 case: a filter population plus probe set over a geometry
/// drawn from non-pow2 sizes and the full fingerprint-width range.
#[derive(Debug, Clone)]
struct PersistCase {
    capacity: usize,
    fp_bits: u32,
    keys: Vec<u64>,
    probes: Vec<u64>,
}

fn gen_persist_case(g: &mut Gen) -> PersistCase {
    let capacity = *g.choose(&[192usize, 500, 1000, 1024, 3000, 4100]);
    let fp_bits = g.usize_in(4, 32) as u32;
    // ≤ half full so inserts are reliable across widths
    let nkeys = g.usize_in(1, capacity / 2);
    PersistCase {
        capacity,
        fp_bits,
        keys: g.vec(nkeys, |g| g.u64_below(1 << 20)),
        probes: g.vec(g.usize_in(1, 1500), |g| g.u64_below(1 << 21)),
    }
}

/// P15 check for one bucket-table backend: build → snapshot → persist
/// (v1 format) → reopen per backing → every probe answer and every
/// table word identical to the source filter.
fn p15_check<T: BucketTable>(dir: &std::path::Path, case: &PersistCase) -> bool {
    use ocf::filter::FrozenTable;
    use ocf::store::frozen::{read_filter_file, write_filter_file, Backing};
    use ocf::store::RealIo;
    let mut f = CuckooFilter::<T>::new(CuckooParams {
        capacity: case.capacity,
        fp_bits: case.fp_bits,
        victim_policy: VictimPolicy::Rollback,
        ..CuckooParams::default()
    });
    for &k in &case.keys {
        let _ = f.insert(k); // rejected inserts are fine: the snapshot
                             // must match whatever state resulted
    }
    let snapshot = FrozenTable::snapshot(&f);
    let path = dir.join(format!("p15-{}.fltr", case.capacity));
    let hasher = snapshot.hasher();
    write_filter_file(
        &RealIo,
        &path,
        snapshot.words(),
        snapshot.nbuckets(),
        case.fp_bits,
        hasher.seed,
        MembershipFilter::len(&snapshot),
    )
    .expect("write filter file");

    let mut backings = vec![Backing::Heap];
    if cfg!(all(unix, target_endian = "little")) {
        backings.push(Backing::Mmap);
        backings.push(Backing::Auto);
    }
    for backing in backings {
        let reopened = match read_filter_file(&RealIo, &path, backing) {
            Ok(t) => t,
            Err(_) => return false,
        };
        if reopened.words() != snapshot.words() {
            return false; // bit-identical words required
        }
        if reopened.nbuckets() != snapshot.nbuckets() {
            return false;
        }
        // scalar probes vs the live filter, batched vs batched
        if case
            .probes
            .iter()
            .any(|&k| MembershipFilter::contains(&reopened, k) != f.contains(k))
        {
            return false;
        }
        if reopened.contains_batch(&case.probes) != snapshot.contains_batch(&case.probes) {
            return false;
        }
        // no false negatives across the persistence boundary
        if case
            .keys
            .iter()
            .filter(|&&k| f.contains(k))
            .any(|&k| !MembershipFilter::contains(&reopened, k))
        {
            return false;
        }
    }
    true
}

#[test]
fn p15_persisted_frozen_tier_is_probe_transparent() {
    let dir = std::env::temp_dir().join(format!("ocf-p15-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    prop_check("persist-roundtrip-flat", 20, gen_persist_case, |case| {
        p15_check::<FlatTable>(&dir, case)
    });
    prop_check("persist-roundtrip-packed", 20, gen_persist_case, |case| {
        p15_check::<PackedTable>(&dir, case)
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// A P16 case: an arbitrary op mix (upserts with per-occurrence
/// values, deletes, flush/compact points), an fsync policy, and a
/// crash-point selector.
#[derive(Debug, Clone)]
struct WalReplayCase {
    steps: Vec<CrashStep>,
    fsync: FsyncPolicy,
    crash_sel: u64,
}

use ocf::store::{FaultyIo, FlushReason, FsyncPolicy};
use ocf::testutil::crash::{sweep_cfg, Step as CrashStep};

fn gen_wal_case(g: &mut Gen) -> WalReplayCase {
    let nsteps = g.usize_in(15, 45);
    let steps = g.vec(nsteps, |g| match g.usize_in(0, 99) {
        0..=59 => CrashStep::Put(g.u64_below(28)),
        60..=79 => CrashStep::Del(g.u64_below(32)),
        80..=91 => CrashStep::Flush,
        _ => CrashStep::Compact,
    });
    let fsync = *g.choose(&[
        FsyncPolicy::Always,
        FsyncPolicy::EveryN(4),
        FsyncPolicy::Os,
    ]);
    WalReplayCase {
        steps,
        fsync,
        crash_sel: g.u64_below(u64::MAX),
    }
}

/// The per-occurrence payload: key *and* step index, so a replay that
/// reorders or drops an upsert produces visibly wrong bytes.
fn p16_value(key: u64, idx: usize) -> Vec<u8> {
    format!("p16:{key}@{idx}").into_bytes()
}

/// Run the case's steps, returning the acknowledged-durable model
/// (key → expected bytes) plus the at-most-one uncertain in-flight op
/// `(step index, step)` whose record the crash may or may not have
/// persisted.
fn p16_run(
    node: &mut StorageNode,
    steps: &[CrashStep],
    io: Option<&FaultyIo>,
) -> (
    std::collections::BTreeMap<u64, Vec<u8>>,
    Option<(usize, CrashStep)>,
) {
    let mut durable = std::collections::BTreeMap::new();
    let mut uncertain = None;
    for (i, &step) in steps.iter().enumerate() {
        let dead_before = io.map(|x| x.crashed()).unwrap_or(false);
        match step {
            CrashStep::Put(k) => {
                let before = node.stats.wal_append_failed();
                node.put_value(k, &p16_value(k, i)).expect("non-static");
                if node.stats.wal_append_failed() == before {
                    durable.insert(k, p16_value(k, i));
                } else if uncertain.is_none() && !dead_before {
                    uncertain = Some((i, step));
                }
            }
            CrashStep::Del(k) => {
                let before = node.stats.wal_append_failed();
                if node.delete(k) {
                    if node.stats.wal_append_failed() == before {
                        durable.remove(&k);
                    } else if uncertain.is_none() && !dead_before {
                        uncertain = Some((i, step));
                    }
                }
            }
            CrashStep::Flush => node.flush(FlushReason::MemtableKeys),
            CrashStep::Compact => node.compact(),
        }
    }
    (durable, uncertain)
}

fn p16_visible(node: &StorageNode) -> std::collections::BTreeMap<u64, Vec<u8>> {
    (0..48u64)
        .filter_map(|k| node.get_value(k).map(|v| (k, v.to_vec())))
        .collect()
}

/// P16 check for one filter backend: run the mix against a seeded
/// fault injector, crash at the selected point, and require recovery
/// to restore exactly the acknowledged-durable state (order-preserving
/// — each key carries the bytes of its *last* durable upsert) — then
/// recover a second time and require the identical answer (replay is
/// idempotent).
fn p16_check(backend: &str, case: &WalReplayCase, seq: u64) -> bool {
    use ocf::store::FaultConfig;
    let scratch = |leg: &str| {
        let dir = std::env::temp_dir().join(format!(
            "ocf-p16-{backend}-{leg}-{}-{seq}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_str().unwrap().to_string()
    };

    // Counting pass + clean-recovery baseline.
    let dir = scratch("clean");
    let counter = std::sync::Arc::new(FaultyIo::new(FaultConfig::default()));
    let mut node = StorageNode::new(sweep_cfg(&dir, backend, case.fsync, Some(counter.clone())));
    let (clean_model, clean_uncertain) = p16_run(&mut node, &case.steps, Some(&counter));
    if clean_uncertain.is_some() || node.stats.wal_append_failed() != 0 {
        return false;
    }
    drop(node);
    let points = counter.mutations();
    let clean = match StorageNode::recover(sweep_cfg(&dir, backend, case.fsync, None)) {
        Ok(n) => n,
        Err(_) => return false,
    };
    let clean_ok = p16_visible(&clean) == clean_model;
    drop(clean);
    let _ = std::fs::remove_dir_all(&dir);
    if !clean_ok || points == 0 {
        return false;
    }

    // Crash pass at the selected point.
    let point = case.crash_sel % points;
    let dir = scratch("crash");
    let io = std::sync::Arc::new(FaultyIo::crash_at(0x9e16 ^ point, point));
    let mut node = StorageNode::new(sweep_cfg(&dir, backend, case.fsync, Some(io.clone())));
    let (durable, uncertain) = p16_run(&mut node, &case.steps, Some(&io));
    drop(node);

    let r1 = match StorageNode::recover(sweep_cfg(&dir, backend, case.fsync, None)) {
        Ok(n) => n,
        Err(_) => return false,
    };
    let got1 = p16_visible(&r1);
    drop(r1); // second crash before any flush: segments must survive
    let matches_model = got1 == durable
        || uncertain
            .map(|(i, step)| {
                let mut alt = durable.clone();
                match step {
                    CrashStep::Put(k) => {
                        alt.insert(k, p16_value(k, i));
                    }
                    CrashStep::Del(k) => {
                        alt.remove(&k);
                    }
                    _ => {}
                }
                got1 == alt
            })
            .unwrap_or(false);

    // Idempotency: replaying the same segments again answers the same.
    let r2 = match StorageNode::recover(sweep_cfg(&dir, backend, case.fsync, None)) {
        Ok(n) => n,
        Err(_) => return false,
    };
    let idempotent = p16_visible(&r2) == got1;
    drop(r2);
    let _ = std::fs::remove_dir_all(&dir);
    matches_model && idempotent
}

#[test]
fn p16_wal_replay_is_idempotent_and_order_preserving() {
    let seq = std::sync::atomic::AtomicU64::new(0);
    prop_check("wal-replay-flat", 12, gen_wal_case, |case| {
        p16_check(
            "cuckoo",
            case,
            seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        )
    });
    prop_check("wal-replay-packed", 12, gen_wal_case, |case| {
        p16_check(
            "cuckoo-packed",
            case,
            seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        )
    });
}

/// P17 case: an OCF geometry, a random op mix, and a set of "storm"
/// keys hammered through the FP-feedback path — across the whole
/// selector-count / extension-width grid.
#[derive(Debug, Clone)]
struct AdaptCase {
    mode: Mode,
    capacity: usize,
    fp_bits: u32,
    ext_bits: u32,
    max_selectors: u32,
    ops: Vec<Op>,
    /// Reported every storm regardless of residency: the band overlaps
    /// the op keyspace, so some are live (reports must be refused) and
    /// some absent (reports may remap a colliding resident).
    storms: Vec<u64>,
}

fn gen_adapt_case(g: &mut Gen) -> AdaptCase {
    let case = gen_case(g, 2000, 1 << 14);
    AdaptCase {
        mode: case.mode,
        capacity: *g.choose(&[256usize, 500, 1024, 3000]),
        // narrow widths maximize fingerprint collisions → ambiguous
        // (refused) reports; wide ones exercise the clean remap path
        fp_bits: *g.choose(&[4u32, 8, 12, 16]),
        ext_bits: *g.choose(&[1u32, 2, 4, 8, 16]),
        max_selectors: *g.choose(&[1u32, 3, 15, 255]),
        ops: case.ops,
        storms: g.vec(g.usize_in(1, 100), |g| g.u64_below(1 << 15)),
    }
}

fn p17_check<T: BucketTable>(case: &AdaptCase) -> bool {
    let mut f = AdaptiveOcf::<T>::with_config(AdaptiveConfig {
        base: OcfConfig {
            mode: case.mode,
            initial_capacity: case.capacity,
            min_capacity: 256,
            fp_bits: case.fp_bits,
            ..OcfConfig::default()
        },
        ext_bits: case.ext_bits,
        max_selectors: case.max_selectors,
    });
    let mut model: HashSet<u64> = HashSet::new();
    for (i, op) in case.ops.iter().enumerate() {
        match op {
            Op::Insert(k) => {
                if f.insert(*k).is_err() {
                    return false;
                }
                model.insert(*k);
            }
            Op::Lookup(k) => {
                // a positive the model disowns is a ground-truth FP —
                // report it, exactly like the node read path does
                if f.contains(*k) && !model.contains(k) {
                    f.report_false_positive(*k);
                }
            }
            Op::Delete(k) => {
                if f.delete(*k) != model.remove(k) {
                    return false;
                }
            }
        }
        // FP-report storm: hammer the storm set through the feedback
        // path, resident keys included
        if i % 256 == 255 {
            for &s in &case.storms {
                let resident = model.contains(&s);
                let _ = f.report_false_positive(s);
                if resident && !f.contains(s) {
                    return false; // reporting a live key must be inert
                }
            }
        }
    }
    // P1 under adaptation: every live key visible, scalar AND batched
    let live: Vec<u64> = model.iter().copied().collect();
    if live.iter().any(|&k| !f.contains(k)) {
        return false;
    }
    if f.contains_batch(&live).iter().any(|&b| !b) {
        return false;
    }
    // remapped keys stay delete-able, and the sidecar drains with them
    for &k in &live {
        if !f.delete(k) {
            return false;
        }
    }
    f.len() == 0 && f.adapted_slots() == 0
}

#[test]
fn p17_adaptive_never_costs_a_false_negative() {
    prop_check(
        "adaptive-no-fn-flat",
        20,
        gen_adapt_case,
        p17_check::<FlatTable>,
    );
    prop_check(
        "adaptive-no-fn-packed",
        20,
        gen_adapt_case,
        p17_check::<PackedTable>,
    );
}

#[test]
fn p18_chaos_runs_are_pure_functions_of_the_seed() {
    prop_check(
        "chaos-determinism",
        6,
        |g| {
            let seed = g.u64();
            let ops = g.usize_in(100, 300);
            let rate = *g.choose(&[0.0, 0.1, 0.25]);
            (seed, ops, rate)
        },
        |&(seed, ops, rate)| {
            let a = run_one_schedule(seed, ops, rate);
            let b = run_one_schedule(seed, ops, rate);
            // bit-identical fingerprints: answers, counters, per-node
            // state, drain behaviour — the whole ChaosOutcome
            a == b
        },
    );
}

#[test]
fn p18_ring_rebalance_moves_only_the_new_nodes_keys() {
    const SAMPLE: u64 = 2000;
    prop_check(
        "ring-minimal-movement",
        12,
        |g| {
            let n = g.usize_in(2, 8);
            let vnodes = *g.choose(&[32usize, 64]);
            let rf = g.usize_in(1, 3);
            (n, vnodes, rf)
        },
        |&(n, vnodes, rf)| {
            let small = HashRing::new(n, vnodes);
            let big = HashRing::new(n + 1, vnodes); // adds node id `n`
            let mut moved = 0u64;
            for k in 0..SAMPLE {
                let old_p = small.primary(k);
                let new_p = big.primary(k);
                // primary changes iff the added node captured the key
                // (the same statement read right-to-left is the
                // removal direction: dropping node `n` from `big`
                // yields `small` exactly)
                if (old_p != new_p) != (new_p == n) {
                    return false;
                }
                if old_p != new_p {
                    moved += 1;
                }
                let old_r = small.replicas(k, rf);
                let new_r = big.replicas(k, rf);
                // replica sets keep their size and stay distinct
                if old_r.len() != rf.min(n) || new_r.len() != rf.min(n + 1) {
                    return false;
                }
                // growth is confined to the added node...
                if new_r.iter().any(|x| !old_r.contains(x) && *x != n) {
                    return false;
                }
                // ...which displaces at most one old replica
                if old_r.iter().filter(|x| !new_r.contains(x)).count() > 1 {
                    return false;
                }
                // removal mirror: every big-ring replica other than
                // the (to-be-removed) node `n` survives into the
                // small ring
                if new_r.iter().any(|x| *x != n && !old_r.contains(x)) {
                    return false;
                }
            }
            // the new node owns ~1/(n+1) of the space; movement beyond
            // 3x that (plus slack for small samples) means keys moved
            // between *surviving* nodes
            let bound = 3.0 / (n as f64 + 1.0) + 0.05;
            (moved as f64 / SAMPLE as f64) < bound
        },
    );
}

#[test]
fn p19_membership_chaos_is_deterministic_and_conserving() {
    prop_check(
        "membership-chaos-determinism",
        5,
        |g| {
            let seed = g.u64();
            let ops = g.usize_in(120, 300);
            let rate = *g.choose(&[0.0, 0.1, 0.25]);
            (seed, ops, rate)
        },
        |&(seed, ops, rate)| {
            let a = run_one_membership_schedule(seed, ops, rate);
            // conservation laws (the run itself asserts the captured
            // form; re-state both here so a counter regression fails
            // the property, not just the harness's internal assert)
            if a.stats.keys_captured != a.stats.keys_streamed + a.stats.keys_superseded {
                return false;
            }
            if a.stats.hints_queued
                != a.stats.hints_replayed
                    + a.stats.hints_superseded
                    + a.stats.hints_dropped
                    + a.stats.hints_retired
            {
                return false;
            }
            if a.stats.transfers_completed != 2 {
                return false;
            }
            // determinism: the full outcome fingerprint replays
            // bit-identically from the seed, topology changes included
            let b = run_one_membership_schedule(seed, ops, rate);
            a == b
        },
    );
}

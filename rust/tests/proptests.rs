//! Property-based tests on coordinator/filter invariants (see
//! `ocf::testutil::prop` — the in-crate property harness).
//!
//! The invariants (DESIGN.md, `filter::ocf` docs):
//!  P1  no false negatives: every inserted, undeleted key is contained;
//!  P2  `len()` equals the number of distinct live keys;
//!  P3  occupancy after every op stays ≤ safe_load;
//!  P4  verified deletes of absent keys change nothing;
//!  P5  pipeline batching is semantically transparent;
//!  P6  KeyStore behaves as a set under arbitrary op sequences;
//!  P7  frozen-filter serialization preserves membership answers;
//!  P8  router replication: every acked write is readable.

use ocf::cluster::{Cluster, ReplicationConfig};
use ocf::filter::{MembershipFilter, Mode, Ocf, OcfConfig};
use ocf::pipeline::{BatchPolicy, IngestPipeline};
use ocf::runtime::HashExecutor;
use ocf::store::{FlushPolicy, NodeConfig};
use ocf::testutil::prop::{prop_check, Gen};
use ocf::workload::Op;
use std::collections::HashSet;

/// A random op sequence plus the mode to run it under.
#[derive(Debug, Clone)]
struct OpCase {
    mode: Mode,
    ops: Vec<Op>,
}

fn gen_case(g: &mut Gen, max_ops: usize, keyspace: u64) -> OpCase {
    let mode = *g.choose(&[Mode::Pre, Mode::Eof]);
    let n = g.usize_in(10, max_ops);
    let mut live: Vec<u64> = Vec::new();
    let ops = g.vec(n, |g| {
        let r = g.f64();
        if r < 0.55 || live.is_empty() {
            let k = g.u64_below(keyspace);
            live.push(k);
            Op::Insert(k)
        } else if r < 0.8 {
            Op::Lookup(g.u64_below(keyspace))
        } else {
            let i = g.usize_in(0, live.len() - 1);
            Op::Delete(live.swap_remove(i))
        }
    });
    OpCase { mode, ops }
}

fn model_apply(ops: &[Op]) -> HashSet<u64> {
    let mut live = HashSet::new();
    for op in ops {
        match op {
            Op::Insert(k) => {
                live.insert(*k);
            }
            Op::Delete(k) => {
                live.remove(k);
            }
            Op::Lookup(_) => {}
        }
    }
    live
}

#[test]
fn p1_p2_p3_no_false_negatives_len_and_load() {
    prop_check(
        "ocf-invariants",
        60,
        |g| gen_case(g, 3000, 1 << 14),
        |case| {
            let mut f = Ocf::new(OcfConfig {
                mode: case.mode,
                initial_capacity: 1024,
                min_capacity: 256,
                ..OcfConfig::default()
            });
            for op in &case.ops {
                match op {
                    Op::Insert(k) => {
                        if f.insert(*k).is_err() {
                            return false;
                        }
                    }
                    Op::Lookup(k) => {
                        let _ = f.contains(*k);
                    }
                    Op::Delete(k) => {
                        f.delete(*k);
                    }
                }
                // P3
                if f.occupancy() > f.config().safe_load + 1e-9 {
                    return false;
                }
            }
            let live = model_apply(&case.ops);
            // P2
            if f.len() != live.len() {
                return false;
            }
            // P1
            live.iter().all(|&k| f.contains(k))
        },
    );
}

#[test]
fn p4_absent_deletes_are_inert() {
    prop_check(
        "absent-delete-inert",
        40,
        |g| {
            let nkeys = g.usize_in(50, 500);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 30));
            let hostile = g.vec(200, |g| (1u64 << 40) + g.u64_below(1 << 20));
            (keys, hostile)
        },
        |(keys, hostile)| {
            let mut f = Ocf::new(OcfConfig {
                initial_capacity: 1024,
                ..OcfConfig::default()
            });
            for &k in keys {
                f.insert(k).unwrap();
            }
            let before: Vec<bool> = keys.iter().map(|&k| f.contains(k)).collect();
            for &h in hostile {
                if f.delete(h) {
                    return false; // verified delete must reject
                }
            }
            let after: Vec<bool> = keys.iter().map(|&k| f.contains(k)).collect();
            before == after && f.len() == {
                let s: HashSet<_> = keys.iter().collect();
                s.len()
            }
        },
    );
}

#[test]
fn p5_pipeline_transparent() {
    prop_check(
        "pipeline-transparent",
        25,
        |g| {
            let case = gen_case(g, 2000, 1 << 12);
            let batch = *g.choose(&[1usize, 7, 64, 333, 1024]);
            (case, batch)
        },
        |(case, batch)| {
            let cfg = OcfConfig {
                mode: case.mode,
                initial_capacity: 1024,
                ..OcfConfig::default()
            };
            let mut direct = Ocf::new(cfg);
            for op in &case.ops {
                match op {
                    Op::Insert(k) => {
                        let _ = direct.insert(*k);
                    }
                    Op::Lookup(k) => {
                        let _ = direct.contains(*k);
                    }
                    Op::Delete(k) => {
                        direct.delete(*k);
                    }
                }
            }
            let mut piped = Ocf::new(cfg);
            let mut p = IngestPipeline::new(
                BatchPolicy {
                    max_batch: *batch,
                    max_delay: std::time::Duration::from_secs(10),
                },
                HashExecutor::native(piped.hasher()),
            );
            p.run(case.ops.iter().copied(), &mut piped);
            if direct.len() != piped.len() {
                return false;
            }
            // membership answers identical across a probe sample
            (0..(1u64 << 12)).step_by(61).all(|k| direct.contains(k) == piped.contains(k))
        },
    );
}

#[test]
fn p6_keystore_is_a_set() {
    use ocf::filter::KeyStore;
    prop_check(
        "keystore-set-semantics",
        40,
        |g| {
            let n = g.usize_in(10, 2000);
            g.vec(n, |g| {
                let k = g.u64_below(300); // tight keyspace → collisions
                match g.usize_in(0, 2) {
                    0 => Op::Insert(k),
                    1 => Op::Delete(k),
                    _ => Op::Lookup(k),
                }
            })
        },
        |ops| {
            let mut ks = KeyStore::new();
            let mut model = HashSet::new();
            for op in ops {
                match op {
                    Op::Insert(k) => {
                        if ks.insert(*k) != model.insert(*k) {
                            return false;
                        }
                    }
                    Op::Delete(k) => {
                        if ks.remove(*k) != model.remove(k) {
                            return false;
                        }
                    }
                    Op::Lookup(k) => {
                        if ks.contains(*k) != model.contains(k) {
                            return false;
                        }
                    }
                }
            }
            ks.len() == model.len() && ks.iter().collect::<HashSet<_>>() == model
        },
    );
}

#[test]
fn p7_frozen_filter_preserves_answers() {
    use ocf::runtime::ProbeExecutor;
    prop_check(
        "frozen-roundtrip",
        30,
        |g| {
            let n = g.usize_in(10, 3000);
            g.vec(n, |g| g.u64())
        },
        |keys| {
            use ocf::filter::{CuckooFilter, CuckooParams, FlatTable};
            // frozen tables are always pow2-bucketed (xor index mapping
            // baked into the serialized layout) — match that here
            let capacity = (keys.len() * 4).next_power_of_two();
            let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
                capacity,
                ..CuckooParams::default()
            });
            for &k in keys {
                if f.insert(k).is_err() {
                    return true; // astronomically unlikely at 4×; skip
                }
            }
            let table = f.to_frozen();
            let h = f.hasher();
            let probes: Vec<u64> = keys.iter().copied().chain(0..500).collect();
            let triples: Vec<_> = probes.iter().map(|&k| h.hash_key(k)).collect();
            let frozen = ProbeExecutor::probe_native(&table, f.nbuckets(), &triples);
            probes
                .iter()
                .zip(frozen)
                .all(|(&k, hit)| hit == f.contains(k))
        },
    );
}

#[test]
fn p8_replicated_writes_readable() {
    prop_check(
        "replicated-write-read",
        15,
        |g| {
            let nodes = g.usize_in(1, 6);
            let rf = g.usize_in(1, 3);
            let nkeys = g.usize_in(10, 800);
            let keys = g.vec(nkeys, |g| g.u64_below(1 << 32));
            (nodes, rf, keys)
        },
        |(nodes, rf, keys)| {
            let mut c = Cluster::new(
                *nodes,
                32,
                NodeConfig {
                    flush: FlushPolicy::small(10_000),
                    ..NodeConfig::default()
                },
                ReplicationConfig {
                    rf: *rf,
                    ..ReplicationConfig::default()
                },
            );
            for &k in keys {
                if c.put(k).is_err() {
                    return false;
                }
            }
            keys.iter().all(|&k| c.get(k))
        },
    );
}

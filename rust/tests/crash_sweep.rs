//! Tier-1 crash-point sweep: kill the "disk" at every mutating I/O
//! operation of an ingest→flush→compact workload, recover, and assert
//! the WAL's contract — every acknowledged write whose durability
//! promise held is restored, every acknowledged delete stays dead,
//! and degradation is loud (counters), never a panic.
//!
//! See `ocf::testutil::crash` for the sweep machinery and the
//! acknowledged-durable model it checks against.

use ocf::store::FsyncPolicy;
use ocf::testutil::crash_sweep;

#[test]
fn sweep_every_crash_point_flat_bucket_backend() {
    let report = crash_sweep("cuckoo", FsyncPolicy::Always);
    assert!(
        report.crash_points > 20,
        "workload too small to mean anything: {report:?}"
    );
    assert!(
        report.wal_replayed > 0,
        "some crash points must recover via WAL replay: {report:?}"
    );
    assert!(
        report.torn_tails > 0,
        "torn-tail crash points must be visited: {report:?}"
    );
}

#[test]
fn sweep_every_crash_point_packed_bucket_backend() {
    let report = crash_sweep("cuckoo-packed", FsyncPolicy::Always);
    assert!(report.crash_points > 20, "{report:?}");
    assert!(report.wal_replayed > 0, "{report:?}");
}

#[test]
fn sweep_every_crash_point_under_group_commit() {
    // Group commit changes the sync cadence (and so the crash-point
    // space), not the process-crash durability: appends write through.
    let report = crash_sweep("ocf", FsyncPolicy::EveryN(8));
    assert!(report.crash_points > 10, "{report:?}");
    assert!(report.wal_replayed > 0, "{report:?}");
}

//! Integration across store + cluster + workload layers: a simulated
//! multi-node data store under realistic mixed workloads.

use ocf::cluster::{Cluster, ReplicationConfig};
use ocf::filter::{Mode, OcfConfig};
use ocf::store::{FlushPolicy, FlushReason, NodeConfig, StorageNode};
use ocf::workload::{ycsb::Preset, BurstGenerator, KeyDist, MixGenerator, Op, OpMix, Trace};

fn small_node_cfg() -> NodeConfig {
    NodeConfig {
        flush: FlushPolicy::small(2_000),
        ..NodeConfig::default()
    }
}

#[test]
fn node_survives_ycsb_all_presets() {
    for preset in Preset::all() {
        let mut node = StorageNode::new(small_node_cfg());
        let mut gen = preset.generator(50_000, 0xCE);
        let mut inserted = std::collections::HashSet::new();
        for _ in 0..20_000 {
            match gen.next_op() {
                Op::Insert(k) => {
                    node.put(k).unwrap();
                    inserted.insert(k);
                }
                Op::Lookup(k) => {
                    let got = node.get(k);
                    if inserted.contains(&k) {
                        assert!(got, "{}: lost key {k}", preset.name());
                    }
                }
                Op::Delete(k) => {
                    node.delete(k);
                    inserted.remove(&k);
                }
            }
        }
        // full retention audit
        for &k in &inserted {
            assert!(node.get(k), "{}: retention of {k}", preset.name());
        }
    }
}

#[test]
fn cluster_consistency_under_burst_workload() {
    let mut cluster = Cluster::new(
        4,
        64,
        small_node_cfg(),
        ReplicationConfig {
            rf: 2,
            ..ReplicationConfig::default()
        },
    );
    let mut gen = BurstGenerator::square_wave(5_000, 1 << 22, 0xBB);
    let mut model = std::collections::HashSet::new();
    for _ in 0..40_000 {
        let op = gen.next_op().unwrap();
        match op {
            Op::Insert(k) => {
                cluster.put(k).unwrap();
                model.insert(k);
            }
            Op::Lookup(k) => {
                if model.contains(&k) {
                    assert!(cluster.get(k).unwrap(), "lost {k}");
                }
            }
            Op::Delete(k) => {
                let was = model.remove(&k);
                let got = cluster.delete(k).unwrap();
                assert_eq!(got, was, "delete({k}) disagreement");
            }
        }
    }
    // audit a sample of live keys
    for &k in model.iter().take(2_000) {
        assert!(cluster.get(k).unwrap(), "retention of {k}");
    }
}

#[test]
fn trace_replay_gives_identical_cluster_state() {
    let mut gen = MixGenerator::new(KeyDist::uniform(1 << 20), OpMix::new(0.5, 0.3, 0.2), 7);
    let trace = Trace::record(15_000, || Some(gen.next_op()));

    let run = || {
        let mut c = Cluster::new(3, 32, small_node_cfg(), ReplicationConfig::none());
        trace.replay(|op| {
            let _ = c.apply(op);
        });
        c
    };
    let a = run();
    let b = run();
    for i in 0..3 {
        assert_eq!(a.node(i).live_keys(), b.node(i).live_keys(), "node {i}");
        assert_eq!(
            a.node(i).sstable_count(),
            b.node(i).sstable_count(),
            "node {i} sstables"
        );
    }
    assert_eq!(a.stats.per_node_ops, b.stats.per_node_ops);
}

#[test]
fn premature_flush_counters_differ_between_arms() {
    // fixed-filter cluster vs OCF cluster under identical load
    let run = |node_cfg: NodeConfig| {
        let mut c = Cluster::new(2, 32, node_cfg, ReplicationConfig::none());
        for k in 0..30_000u64 {
            let _ = c.put(k);
        }
        c.flush_counts()
    };
    let (fixed_premature, _) = run(NodeConfig {
        filter: OcfConfig {
            mode: Mode::Static,
            initial_capacity: 4096,
            ..OcfConfig::default()
        }
        .into(),
        flush: FlushPolicy::small(1_000_000).with_filter_pressure(0.85),
        ..NodeConfig::default()
    });
    let (ocf_premature, _) = run(NodeConfig {
        flush: FlushPolicy::small(1_000_000),
        ..NodeConfig::default()
    });
    assert!(fixed_premature > 0, "fixed arm must premature-flush");
    assert_eq!(ocf_premature, 0, "OCF arm must not");
}

#[test]
fn compaction_preserves_cluster_reads() {
    let mut node = StorageNode::new(NodeConfig {
        flush: FlushPolicy::small(500),
        ..NodeConfig::default()
    });
    for k in 0..5_000u64 {
        node.put(k).unwrap();
    }
    for k in 0..2_500u64 {
        assert!(node.delete(k));
    }
    node.flush(FlushReason::MemtableKeys);
    node.compact();
    assert_eq!(node.sstable_count(), 1);
    for k in 0..2_500u64 {
        assert!(!node.get(k), "{k} must stay deleted post-compaction");
    }
    for k in 2_500..5_000u64 {
        assert!(node.get(k), "{k} must survive compaction");
    }
}

// ---- persistent tier (PR 6) -------------------------------------------

fn scratch(tag: &str) -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir()
        .join(format!("ocf-it-{tag}-{}-{n}", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn durable_cfg(dir: &str, flush_keys: usize) -> NodeConfig {
    NodeConfig {
        persist_dir: Some(dir.to_string()),
        flush: FlushPolicy::small(flush_keys),
        ..NodeConfig::default()
    }
}

/// Full lifecycle: mixed ingest over several generations, deletes,
/// a compaction, a restart — the recovered node answers every key
/// identically to the model, without rebuilding a single filter.
#[test]
fn persisted_node_recovers_full_lifecycle() {
    let dir = scratch("lifecycle");
    let _ = std::fs::remove_dir_all(&dir);
    let mut model = std::collections::HashSet::new();
    {
        let mut node = StorageNode::new(durable_cfg(&dir, 1_000));
        let mut gen = MixGenerator::new(
            KeyDist::uniform(1 << 16),
            OpMix::new(0.6, 0.2, 0.2),
            0x51AB,
        );
        for _ in 0..20_000 {
            match gen.next_op() {
                Op::Insert(k) => {
                    node.put(k).unwrap();
                    model.insert(k);
                }
                Op::Lookup(k) => {
                    let _ = node.get(k);
                }
                Op::Delete(k) => {
                    node.delete(k);
                    model.remove(&k);
                }
            }
        }
        node.compact();
        // more churn after compaction, flushed so it is durable
        for k in (1u64 << 17)..(1 << 17) + 3_000 {
            node.put(k).unwrap();
            model.insert(k);
        }
        node.flush(FlushReason::MemtableKeys);
        assert!(node.sstable_count() >= 2);
    } // drop = crash (memtable is empty, everything flushed)

    let node = StorageNode::recover(durable_cfg(&dir, 1_000)).unwrap();
    assert_eq!(node.stats.filters_rebuilt(), 0, "no rebuilds expected");
    assert_eq!(node.stats.filter_recovery_rejected(), 0);
    assert_eq!(
        node.stats.filters_recovered() as usize,
        node.sstable_count(),
        "every sstable's filter served from disk"
    );
    assert_eq!(node.live_keys(), model.len());
    for &k in &model {
        assert!(node.get(k), "recovered node lost {k}");
    }
    // deleted keys stay deleted (tombstones / full-snapshot semantics)
    let mut probe = MixGenerator::new(
        KeyDist::uniform(1 << 16),
        OpMix::new(0.0, 1.0, 0.0),
        0x7777,
    );
    for _ in 0..5_000 {
        if let Op::Lookup(k) = probe.next_op() {
            assert_eq!(node.get(k), model.contains(&k), "key {k}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// The crash window where filter files were lost but runs survived:
/// recovery rebuilds (and re-persists) every filter, answers stay
/// identical, and the *next* restart recovers cleanly again.
#[test]
fn persisted_node_heals_lost_filter_files() {
    let dir = scratch("heal");
    let _ = std::fs::remove_dir_all(&dir);
    {
        let mut node = StorageNode::new(durable_cfg(&dir, 500));
        for k in 0..4_000u64 {
            node.put(k).unwrap();
        }
        node.flush(FlushReason::MemtableKeys);
    }
    let store = ocf::store::FrozenStore::open(&dir).unwrap();
    let gens = store.generations().unwrap();
    assert!(gens.len() >= 2);
    for &g in &gens {
        std::fs::remove_file(store.filter_path(g)).unwrap();
    }

    let node = StorageNode::recover(durable_cfg(&dir, 500)).unwrap();
    assert_eq!(node.stats.filters_rebuilt() as usize, gens.len());
    assert_eq!(node.stats.filter_recovery_rejected(), 0);
    for k in 0..4_000u64 {
        assert!(node.get(k), "rebuilt node lost {k}");
    }
    drop(node);

    // rebuild re-persisted the filters: round two is a clean recover
    let node = StorageNode::recover(durable_cfg(&dir, 500)).unwrap();
    assert_eq!(node.stats.filters_rebuilt(), 0, "healed files must load");
    assert_eq!(node.stats.filters_recovered() as usize, gens.len());
    let _ = std::fs::remove_dir_all(&dir);
}

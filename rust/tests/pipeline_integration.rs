//! Integration: the ingest pipeline end-to-end over workload → batcher
//! → (native) hash executor → OCF, including the threaded variant with
//! real backpressure, and hashed-op equivalence.

use ocf::filter::{MembershipFilter, Mode, Ocf, OcfConfig};
use ocf::pipeline::{BatchPolicy, CreditGate, IngestPipeline};
use ocf::runtime::HashExecutor;
use ocf::workload::{BurstGenerator, KeyDist, MixGenerator, Op, OpMix};
use std::sync::Arc;
use std::time::Duration;

fn mk_pipeline(batch: usize, filter: &Ocf) -> IngestPipeline {
    IngestPipeline::new(
        BatchPolicy {
            max_batch: batch,
            max_delay: Duration::from_millis(5),
        },
        HashExecutor::native(filter.hasher()),
    )
}

#[test]
fn burst_workload_through_pipeline_resizes_filter() {
    let mut filter = Ocf::new(OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 2048,
        ..OcfConfig::default()
    });
    let mut p = mk_pipeline(512, &filter);
    let mut gen = BurstGenerator::square_wave(10_000, 1 << 24, 3);
    let mut left = 60_000;
    let report = p.run(
        std::iter::from_fn(move || {
            if left == 0 {
                None
            } else {
                left -= 1;
                gen.next_op()
            }
        }),
        &mut filter,
    );
    assert_eq!(report.ops, 60_000);
    assert!(
        filter.stats().resizes() > 0,
        "bursts must trigger resizes: {:?}",
        filter.stats()
    );
    assert!(report.batches >= 60_000 / 512);
    assert!(report.ops_per_sec() > 0.0);
}

#[test]
fn hashed_ops_equal_plain_ops() {
    // insert_hashed/delete_hashed/contains_triple vs plain key APIs
    let cfg = OcfConfig {
        initial_capacity: 1024,
        ..OcfConfig::default()
    };
    let mut a = Ocf::new(cfg);
    let mut b = Ocf::new(cfg);
    let h = a.hasher();
    let mut gen = MixGenerator::new(KeyDist::uniform(1 << 16), OpMix::new(0.5, 0.2, 0.3), 11);
    for op in gen.batch(30_000) {
        match op {
            Op::Insert(k) => {
                let ra = a.insert(k);
                let rb = b.insert_hashed(k, h.hash_key(k));
                assert_eq!(ra.is_ok(), rb.is_ok());
            }
            Op::Lookup(k) => {
                assert_eq!(a.contains(k), b.contains_triple(h.hash_key(k)), "key {k}");
            }
            Op::Delete(k) => {
                assert_eq!(a.delete(k), b.delete_hashed(k, h.hash_key(k)), "key {k}");
            }
        }
    }
    assert_eq!(a.len(), b.len());
    assert_eq!(a.capacity(), b.capacity());
}

#[test]
fn threaded_pipeline_with_tight_queue_applies_backpressure() {
    let mut filter = Ocf::new(OcfConfig::default());
    let mut p = mk_pipeline(256, &filter);
    let mut gen = MixGenerator::new(KeyDist::uniform(1 << 30), OpMix::insert_only(), 5);
    let mut left = 50_000;
    // queue depth 1: the producer can only ever be one chunk ahead
    let report = p.run_threaded(
        move || {
            if left == 0 {
                None
            } else {
                left -= 1;
                Some(gen.next_op())
            }
        },
        &mut filter,
        1,
        256,
    );
    assert_eq!(report.ops, 50_000);
    assert_eq!(report.inserts, 50_000);
    assert_eq!(filter.len(), 50_000);
}

#[test]
fn credit_gate_bounds_concurrent_inflight() {
    let gate = Arc::new(CreditGate::new(4));
    let peak = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let inflight = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..8 {
            let gate = gate.clone();
            let peak = peak.clone();
            let inflight = inflight.clone();
            s.spawn(move || {
                for _ in 0..200 {
                    gate.acquire();
                    let now = inflight.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
                    peak.fetch_max(now, std::sync::atomic::Ordering::SeqCst);
                    std::thread::yield_now();
                    inflight.fetch_sub(1, std::sync::atomic::Ordering::SeqCst);
                    gate.release();
                }
            });
        }
    });
    let p = peak.load(std::sync::atomic::Ordering::SeqCst);
    assert!(p <= 4, "credit gate violated: peak inflight {p}");
    assert!(p >= 2, "test should exercise concurrency: peak {p}");
}

#[test]
fn pipeline_lookup_hit_rate_sane() {
    let mut filter = Ocf::new(OcfConfig::default());
    let mut p = mk_pipeline(1024, &filter);
    // insert 0..N then look them all up through the pipeline
    let n = 20_000u64;
    let ops = (0..n)
        .map(Op::Insert)
        .chain((0..n).map(Op::Lookup))
        .chain((n..2 * n).map(Op::Lookup)); // absent
    let report = p.run(ops, &mut filter);
    assert_eq!(report.inserts, n);
    assert_eq!(report.lookups, 2 * n);
    assert!(report.lookup_hits >= n, "no false negatives");
    let fp = report.lookup_hits - n;
    assert!(
        (fp as f64) < 0.01 * n as f64,
        "false-positive excess too high: {fp}"
    );
}

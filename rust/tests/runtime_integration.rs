//! Integration: the PJRT runtime executing the real AOT artifacts, and
//! the cross-language hash contract (rust native == XLA artifact).
//!
//! These tests need `artifacts/` (run `make artifacts` first). When the
//! directory is absent they SKIP (pass trivially with a note) so
//! `cargo test` works in a fresh checkout; CI always builds artifacts
//! first via `make test`.

use ocf::filter::fingerprint::Hasher;
use ocf::filter::{CuckooFilter, CuckooParams, MembershipFilter};
use ocf::runtime::{HashExecutor, PjrtEngine, ProbeExecutor};
use ocf::util::SplitMix64;
use std::sync::Arc;

fn engine() -> Option<Arc<PjrtEngine>> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtEngine::load_dir(&dir) {
        Ok(Some(e)) => Some(Arc::new(e)),
        Ok(None) => {
            eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
            None
        }
        Err(e) => panic!("artifact load failed: {e}"),
    }
}

#[test]
fn xla_hash_bit_exact_with_native() {
    let Some(engine) = engine() else { return };
    let mut rng = SplitMix64::new(0xC0411EC7);
    for fp_bits in [8u32, 16, 32] {
        let hasher = Hasher::new(rng.next_u64(), fp_bits);
        let xla = HashExecutor::with_engine(engine.clone(), hasher);
        assert_eq!(xla.kind(), ocf::runtime::ExecutorKind::Xla);
        // batch sizes exercising exact-fit, padding, and chunking paths
        for n in [1usize, 7, 256, 300, 1024, 5000] {
            let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let got = xla.hash_batch(&keys).expect("xla hash");
            assert_eq!(got.len(), n);
            for (k, t) in keys.iter().zip(&got) {
                assert_eq!(
                    *t,
                    hasher.hash_key(*k),
                    "fp_bits={fp_bits} n={n} key={k:#x}"
                );
            }
        }
    }
}

#[test]
fn xla_hash_edge_keys() {
    let Some(engine) = engine() else { return };
    let hasher = Hasher::new(0, 16);
    let xla = HashExecutor::with_engine(engine, hasher);
    let keys = [0u64, 1, u64::MAX, u64::MAX - 1, 0x8000_0000_0000_0000];
    let got = xla.hash_batch(&keys).unwrap();
    for (k, t) in keys.iter().zip(&got) {
        assert_eq!(*t, hasher.hash_key(*k), "key={k:#x}");
    }
}

#[test]
fn xla_probe_matches_native_on_frozen_table() {
    let Some(engine) = engine() else { return };
    // the probe artifact is built for nbuckets=16384 → capacity 65536
    let nbuckets = 16384usize;
    let mut filter = CuckooFilter::<ocf::filter::FlatTable>::new(CuckooParams {
        capacity: nbuckets * 4,
        ..CuckooParams::default()
    });
    for k in 0..40_000u64 {
        filter.insert(k).unwrap();
    }
    assert_eq!(filter.nbuckets(), nbuckets);
    let table = filter.to_frozen();
    let hasher = filter.hasher();

    let queries: Vec<_> = (0..10_000u64)
        .map(|i| hasher.hash_key(i * 7)) // mix of present/absent
        .collect();
    let native = ProbeExecutor::probe_native(&table, nbuckets, &queries);
    let xla = ProbeExecutor::with_engine(engine)
        .probe(&table, nbuckets, &queries)
        .expect("xla probe");
    assert_eq!(native, xla);
    // and both agree with the filter itself
    for (i, &hit) in native.iter().enumerate() {
        let k = (i as u64) * 7;
        assert_eq!(hit, filter.contains(k), "key {k}");
    }
}

#[test]
fn xla_probe_wrong_shape_falls_back_native() {
    let Some(engine) = engine() else { return };
    let nbuckets = 512usize; // no artifact at this shape
    let mut filter = CuckooFilter::<ocf::filter::FlatTable>::new(CuckooParams {
        capacity: nbuckets * 4,
        ..CuckooParams::default()
    });
    for k in 0..1000u64 {
        filter.insert(k).unwrap();
    }
    let table = filter.to_frozen();
    let h = filter.hasher();
    let queries: Vec<_> = (0..2000u64).map(|k| h.hash_key(k)).collect();
    let got = ProbeExecutor::with_engine(engine)
        .probe(&table, nbuckets, &queries)
        .unwrap();
    for (k, hit) in (0..2000u64).zip(got) {
        assert_eq!(hit, filter.contains(k));
    }
}

#[test]
fn engine_reports_expected_artifacts() {
    let Some(engine) = engine() else { return };
    let names = engine.artifact_names();
    for expected in [
        "hash_b256",
        "hash_b1024",
        "hash_b4096",
        "probe_nb16384_b1024",
        "hash_probe_nb16384_b1024",
    ] {
        assert!(
            names.contains(&expected),
            "missing artifact {expected}; have {names:?}"
        );
    }
    assert_eq!(engine.platform(), "cpu");
}

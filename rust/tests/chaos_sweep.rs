//! Tier-1 chaos sweep: run scripted workloads against clusters whose
//! replicas fail on seeded deterministic schedules (transient, latent,
//! crashed), and assert the replication layer's availability contract —
//! whenever quorum stays achievable no acknowledged write is lost, no
//! deleted key resurrects, reads never return a false negative, every
//! consistency miss is a typed error, and hint queues drain to zero
//! after recovery.
//!
//! See `ocf::testutil::chaos` for the sweep machinery and the
//! acknowledged-state model it checks against. All contract asserts
//! fire *inside* the sweep; the checks here prove the sweep was not
//! vacuous — faults actually happened and the machinery actually ran.
//!
//! The membership tests extend the same contract across topology
//! changes: nodes join and leave mid-schedule with streaming range
//! handoff, donors and joiners die mid-transfer, and the sweep still
//! proves no acked write lost, no resurrection, queues drained, and
//! every replica set converged to the *new* ring.

use std::sync::Arc;

use ocf::cluster::{
    Cluster, Consistency, FaultPlane, RealProxy, ReplicationConfig, ResilienceConfig, Verdict,
};
use ocf::store::{FlushPolicy, NodeConfig};
use ocf::testutil::{chaos_sweep, membership_sweep, run_one_membership_schedule, run_one_schedule};

#[test]
fn sweep_seeded_schedules_across_fault_rates() {
    // 12 schedules cycle the rate ladder [0.0, 0.05, 0.15, 0.3] three
    // times, with varying node counts (3..=5) derived from the seed.
    let report = chaos_sweep(12, 500);
    assert_eq!(report.schedules, 12);
    assert_eq!(report.ops, 12 * 500);
    assert!(
        report.writes_acked > 0,
        "sweep acked nothing: {report:?}"
    );
    // the faulted arms must actually exercise the fault machinery
    assert!(
        report.retries > 0,
        "no transient fault was ever retried: {report:?}"
    );
    assert!(
        report.hints_queued > 0,
        "no write ever missed a down replica: {report:?}"
    );
    assert_eq!(
        report.hints_queued,
        report.hints_replayed + report.hints_superseded + report.hints_retired,
        "every queued hint must replay, be superseded, or retire with \
         its decommissioned target: {report:?}"
    );
    assert!(
        report.breaker_trips > 0,
        "no crashed window ever tripped a breaker: {report:?}"
    );
}

#[test]
fn heavy_fault_rate_still_converges() {
    // Well past the sweep ladder: at 50% fault density quorum is lost
    // often, but the contract (typed errors, convergence after drain)
    // must still hold — run_one_schedule asserts it internally.
    let out = run_one_schedule(0xbad_c10c_c, 800, 0.5);
    assert!(
        out.stats.quorum_losses > 0,
        "50% fault density never lost quorum: {:?}",
        out.stats
    );
    assert_eq!(out.stats.hints_dropped, 0, "{:?}", out.stats);
    assert!(
        out.answers.iter().any(|&a| a == 2),
        "typed quorum-lost answers must surface to the client"
    );
}

#[test]
fn membership_sweep_holds_the_contract_across_topology_changes() {
    // 8 schedules cycle the rate ladder twice; every schedule runs a
    // join around ops/3 and a leave around 2·ops/3, both under the
    // same seeded fault planes as the replicas. All PR-9 contract
    // asserts (no lost acks, no resurrection, typed errors, drained
    // queues, convergence to the *final* ring) fire inside the run.
    let report = membership_sweep(8, 400);
    assert_eq!(report.schedules, 8);
    assert_eq!(
        report.transfers_started, 16,
        "one join and one leave per schedule: {report:?}"
    );
    assert_eq!(report.transfers_completed, 16, "{report:?}");
    assert!(
        report.keys_streamed > 0,
        "joins over a populated key space must stream keys: {report:?}"
    );
    assert!(
        report.transfers_retried > 0,
        "faulted arms never killed a donor or joiner mid-transfer: {report:?}"
    );
    assert_eq!(
        report.hints_queued,
        report.hints_replayed + report.hints_superseded + report.hints_retired,
        "hint conservation across membership changes: {report:?}"
    );
}

#[test]
fn heavy_fault_rate_membership_still_converges() {
    // Past the sweep ladder: 40% fault density across a join and a
    // leave. run_one_membership_schedule asserts the whole contract
    // internally — including the transfer conservation law.
    let out = run_one_membership_schedule(0xbad_70_90, 700, 0.4);
    assert_eq!(out.stats.transfers_completed, 2);
    assert_eq!(out.stats.hints_dropped, 0, "{:?}", out.stats);
    assert_eq!(
        out.stats.keys_captured,
        out.stats.keys_streamed + out.stats.keys_superseded,
        "{:?}",
        out.stats
    );
}

/// Crashed while `start <= clock < end`, healthy otherwise.
#[derive(Debug)]
struct DownDuring(u64, u64);

impl FaultPlane for DownDuring {
    fn verdict(&self, clock: u64, _attempt: u32) -> Verdict {
        if clock >= self.0 && clock < self.1 {
            Verdict::Crashed
        } else {
            Verdict::Healthy
        }
    }
    fn describe(&self) -> String {
        format!("down during [{}, {})", self.0, self.1)
    }
}

#[test]
fn donor_death_mid_transfer_stalls_the_range_and_recovers() {
    // 3-node rf=3 cluster: every range's donor set includes node 0, so
    // killing node 0 mid-transfer must stall every commit (the union
    // enumeration refuses to hand off a range whose donor was never
    // fully paged) without breaking reads, then complete after
    // recovery.
    let planes: Vec<Arc<dyn FaultPlane>> = vec![
        Arc::new(DownDuring(310, 600)),
        Arc::new(RealProxy),
        Arc::new(RealProxy),
    ];
    let mut c = Cluster::with_fault_planes(
        3,
        32,
        NodeConfig {
            flush: FlushPolicy::small(10_000),
            ..NodeConfig::default()
        },
        ReplicationConfig {
            rf: 3,
            read_consistency: Consistency::Quorum,
            write_consistency: Consistency::Quorum,
        },
        ResilienceConfig::default(),
        planes,
    );
    for k in 0..300u64 {
        c.put(k).unwrap();
    }
    let id = c.add_node().unwrap();
    c.advance_clock(20); // into node 0's crash window
    for _ in 0..60 {
        c.pump_transfers();
    }
    assert!(
        c.transfer_active(),
        "no range may commit while donor 0 is unreachable"
    );
    assert!(c.stats.transfers_retried > 0, "{:?}", c.stats);
    // reads keep serving from the surviving old owners
    for k in 0..300u64 {
        assert!(c.get(k).unwrap(), "{k} while the donor is down");
    }
    // writes during the stall dual-apply to the joiner or hint it
    for k in 300..340u64 {
        c.put(k).unwrap();
    }
    c.advance_clock(600 + c.resilience().breaker.cooldown);
    let mut rounds = 0u64;
    while c.pump_transfers() > 0 || c.replay_hints() > 0 {
        rounds += 1;
        assert!(rounds < 100_000, "transfer must complete after recovery");
    }
    assert!(!c.transfer_active());
    assert!(c.node(id).live_keys() > 0, "joiner received the stream");
    assert_eq!(
        c.stats.keys_captured,
        c.stats.keys_streamed + c.stats.keys_superseded,
        "{:?}",
        c.stats
    );
    for k in 0..340u64 {
        assert!(c.get(k).unwrap(), "{k} after recovery");
        for &n in &c.ring().replicas(k, 3) {
            assert!(c.node(n).get(k), "key {k} missing on replica {n}");
        }
    }
}

#[test]
fn latency_injection_reaches_the_latency_counters() {
    // Latent windows are a third of all fault windows; over enough
    // schedules some must fit under (or blow) the 1ms sweep timeout.
    let mut latency = 0u64;
    let mut timeouts = 0u64;
    for seed in 0..6u64 {
        let out = run_one_schedule(0x1a7e_0000 + seed, 500, 0.3);
        latency += out.synthetic_latency_us;
        timeouts += out.timeouts;
    }
    assert!(
        latency > 0 || timeouts > 0,
        "no latent window ever touched an op (latency {latency}µs, {timeouts} timeouts)"
    );
}

//! Tier-1 chaos sweep: run scripted workloads against clusters whose
//! replicas fail on seeded deterministic schedules (transient, latent,
//! crashed), and assert the replication layer's availability contract —
//! whenever quorum stays achievable no acknowledged write is lost, no
//! deleted key resurrects, reads never return a false negative, every
//! consistency miss is a typed error, and hint queues drain to zero
//! after recovery.
//!
//! See `ocf::testutil::chaos` for the sweep machinery and the
//! acknowledged-state model it checks against. All contract asserts
//! fire *inside* the sweep; the checks here prove the sweep was not
//! vacuous — faults actually happened and the machinery actually ran.

use ocf::testutil::{chaos_sweep, run_one_schedule};

#[test]
fn sweep_seeded_schedules_across_fault_rates() {
    // 12 schedules cycle the rate ladder [0.0, 0.05, 0.15, 0.3] three
    // times, with varying node counts (3..=5) derived from the seed.
    let report = chaos_sweep(12, 500);
    assert_eq!(report.schedules, 12);
    assert_eq!(report.ops, 12 * 500);
    assert!(
        report.writes_acked > 0,
        "sweep acked nothing: {report:?}"
    );
    // the faulted arms must actually exercise the fault machinery
    assert!(
        report.retries > 0,
        "no transient fault was ever retried: {report:?}"
    );
    assert!(
        report.hints_queued > 0,
        "no write ever missed a down replica: {report:?}"
    );
    assert_eq!(
        report.hints_queued,
        report.hints_replayed + report.hints_superseded,
        "every queued hint must replay or be superseded: {report:?}"
    );
    assert!(
        report.breaker_trips > 0,
        "no crashed window ever tripped a breaker: {report:?}"
    );
}

#[test]
fn heavy_fault_rate_still_converges() {
    // Well past the sweep ladder: at 50% fault density quorum is lost
    // often, but the contract (typed errors, convergence after drain)
    // must still hold — run_one_schedule asserts it internally.
    let out = run_one_schedule(0xbad_c10c_c, 800, 0.5);
    assert!(
        out.stats.quorum_losses > 0,
        "50% fault density never lost quorum: {:?}",
        out.stats
    );
    assert_eq!(out.stats.hints_dropped, 0, "{:?}", out.stats);
    assert!(
        out.answers.iter().any(|&a| a == 2),
        "typed quorum-lost answers must surface to the client"
    );
}

#[test]
fn latency_injection_reaches_the_latency_counters() {
    // Latent windows are a third of all fault windows; over enough
    // schedules some must fit under (or blow) the 1ms sweep timeout.
    let mut latency = 0u64;
    let mut timeouts = 0u64;
    for seed in 0..6u64 {
        let out = run_one_schedule(0x1a7e_0000 + seed, 500, 0.3);
        latency += out.synthetic_latency_us;
        timeouts += out.timeouts;
    }
    assert!(
        latency > 0 || timeouts > 0,
        "no latent window ever touched an op (latency {latency}µs, {timeouts} timeouts)"
    );
}

//! # OCF — Optimized Cuckoo Filter
//!
//! A production-shaped reproduction of *"Optimizing Cuckoo Filter for high
//! burst tolerance, low latency, and high throughput"* (Khalid, cs.DC 2020):
//! burst-tolerant membership testing for distributed data stores.
//!
//! The crate is organised in layers (bottom-up):
//!
//! * [`util`] — deterministic RNG (SplitMix64 / Xoshiro256++), helpers.
//! * [`filter`] — the membership-filter family: the partial-key cuckoo
//!   table, the traditional cuckoo filter baseline, **OCF** with its two
//!   resize policies (**PRE** — static thresholds, **EOF** — congestion
//!   aware), the **sharded concurrent front-end** (`ShardedOcf`), and
//!   the bloom / scalable-bloom / xor baselines the paper compares
//!   against.
//! * [`store`] — the Cassandra-like per-node substrate: memtable,
//!   SSTables with frozen per-table filters, flush + compaction policy.
//! * [`cluster`] — consistent-hash ring, router, replication, and the
//!   paper's §I.B cartesian-product query coordinator.
//! * [`pipeline`] — the streaming ingestion path: dynamic batcher,
//!   credit-based backpressure, worker pool.
//! * [`runtime`] — the PJRT bridge: loads the AOT HLO artifacts built by
//!   `python/compile/aot.py` and executes them from the hot path (with a
//!   bit-exact pure-rust fallback when artifacts are absent).
//! * [`workload`] — workload generators (uniform/zipf draws, YCSB-style
//!   mixes, burst phases, trace record/replay).
//! * [`metrics`] — latency histograms, counters, throughput meters.
//! * [`config`] — TOML-subset config files + CLI overrides.
//! * [`bench_harness`] — the warmup/measure/percentile engine behind
//!   every `cargo bench` target.
//! * [`exp`] — experiment drivers regenerating each paper table/figure.
//!
//! ## Quickstart
//!
//! ```
//! use ocf::filter::{MembershipFilter, Ocf, OcfConfig, Mode};
//!
//! let mut f = Ocf::new(OcfConfig { mode: Mode::Eof, ..OcfConfig::default() });
//! for k in 0..10_000u64 {
//!     f.insert(k).unwrap();
//! }
//! assert!(f.contains(42));
//! assert!(f.delete(42));
//! ```
//!
//! Python never runs on the request path: `make artifacts` AOT-lowers the
//! JAX/Pallas fingerprint pipeline once; the binary is then self-contained.

pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod exp;
pub mod filter;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod store;
pub mod testutil;
pub mod util;
pub mod workload;

pub use filter::{
    BatchedFilter, ConcurrentFilter, DynFilter, FilterBuilder, MembershipFilter, Mode, Ocf,
    OcfConfig, ProbeSession,
};

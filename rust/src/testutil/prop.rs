//! Minimal property-based testing: seeded generation + shrink-lite.
//!
//! ```
//! use ocf::testutil::prop::{prop_check, Gen};
//!
//! // every u64 survives a round-trip through encode/decode
//! prop_check("roundtrip", 500, |g| g.u64(), |&x| x.wrapping_add(2).wrapping_sub(2) == x);
//! ```

use crate::util::SplitMix64;

/// Random-case generator handed to the case factory.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: SplitMix64::new(seed),
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.next_below(n)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A vector of `len` draws from `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }
}

/// Run `property` against `cases` generated cases. Panics (with the
/// failing case's Debug rendering and its seed) on the first violation.
///
/// Seeds are derived deterministically from the test `name`, so every
/// test gets an independent but reproducible stream.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    cases: u64,
    mut gen_case: impl FnMut(&mut Gen) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut g = Gen::new(seed);
        let case = gen_case(&mut g);
        if !property(&case) {
            panic!(
                "property '{name}' failed on case #{i} (seed {seed:#x}):\n{case:#?}"
            );
        }
    }
}

/// Like [`prop_check`] but with shrinking: on failure, `shrink` proposes
/// smaller variants; the smallest still-failing case is reported.
pub fn prop_check_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cases: u64,
    mut gen_case: impl FnMut(&mut Gen) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut property: impl FnMut(&T) -> bool,
) {
    let base = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
        });
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut g = Gen::new(seed);
        let case = gen_case(&mut g);
        if property(&case) {
            continue;
        }
        // greedy shrink: keep taking the first failing shrink candidate
        let mut smallest = case.clone();
        let mut budget = 1000;
        'outer: loop {
            for cand in shrink(&smallest) {
                budget -= 1;
                if budget == 0 {
                    break 'outer;
                }
                if !property(&cand) {
                    smallest = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed on case #{i} (seed {seed:#x});\n\
             shrunk to:\n{smallest:#?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("add-commutes", 200, |g| (g.u64(), g.u64()), |&(a, b)| {
            a.wrapping_add(b) == b.wrapping_add(a)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_case() {
        prop_check("always-false", 10, |g| g.u64(), |_| false);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = vec![];
        let mut b = vec![];
        prop_check("det", 50, |g| g.u64(), |&x| {
            a.push(x);
            true
        });
        prop_check("det", 50, |g| g.u64(), |&x| {
            b.push(x);
            true
        });
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "shrunk to")]
    fn shrinking_minimizes() {
        // property: all values < 500. gen can exceed; shrink by halving.
        prop_check_shrink(
            "lt-500",
            100,
            |g| g.u64_below(10_000),
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |&x| x < 500,
        );
    }

    #[test]
    fn gen_helpers_in_range() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.usize_in(5, 10);
            assert!((5..=10).contains(&v));
            let f = g.f64();
            assert!((0.0..1.0).contains(&f));
        }
        let v = g.vec(10, |g| g.bool());
        assert_eq!(v.len(), 10);
        let xs = [1, 2, 3];
        assert!(xs.contains(g.choose(&xs)));
    }
}

//! Test utilities, including a small property-testing harness.
//!
//! The offline build has no access to `proptest`/`quickcheck`, so
//! [`prop`] provides the same workflow in ~150 lines: generate many
//! random cases from a seeded RNG, run the property, and on failure
//! *minimize* the case with a user-supplied shrinker before reporting.
//! Deterministic by construction (fixed seeds), so CI failures
//! reproduce locally. [`crash`] sweeps every WAL crash point and
//! [`chaos`] sweeps seeded replica fault schedules — the durability
//! and availability contracts, proven mechanically.

pub mod chaos;
pub mod crash;
pub mod prop;

pub use chaos::{
    chaos_sweep, membership_sweep, run_one_membership_schedule, run_one_schedule, ChaosOutcome,
    ChaosReport, Truth,
};
pub use crash::{crash_sweep, standard_script, SweepReport};
pub use prop::{prop_check, Gen};

//! Systematic crash-point sweeping for the persistent tier.
//!
//! The sweep proves the WAL's contract mechanically: run a fixed
//! ingest→flush→compact workload against a [`FaultyIo`] with no crash
//! configured and count its mutating I/O operations (`n`); then re-run
//! the *same deterministic workload* once per ordinal `0..n`, killing
//! the "disk" at that exact operation (optionally leaving a torn
//! prefix of the in-flight write). Every distinct on-disk state the
//! workload can be interrupted in is therefore visited. After each
//! crash the node is recovered over the real filesystem and its
//! visible state compared against the **acknowledged-durable model**:
//!
//! * every op acknowledged *with its WAL append intact* must survive
//!   — puts present with their exact value bytes, deletes absent;
//! * at most one op is *uncertain*: the one in flight when the crash
//!   fired (its record may or may not have reached the file). The
//!   recovered state must equal the model either without it or with
//!   exactly it — nothing else;
//! * ops after the crash (acknowledged degraded, `wal_append_failed`
//!   counted) must not resurrect, and no recovery may panic — typed
//!   errors and counters only.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

use crate::filter::FilterBuilder;
use crate::store::compaction::CompactionPolicy;
use crate::store::{
    FaultConfig, FaultyIo, FlushPolicy, FlushReason, FsyncPolicy, NodeConfig, StorageNode,
    StoreIo, WalConfig,
};
use crate::util::SplitMix64;

/// One step of a sweep workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    Put(u64),
    Del(u64),
    Flush,
    Compact,
}

/// The deterministic payload for `key` — recovery checks compare
/// recovered bytes against this, so values prove themselves.
pub fn value_for(key: u64) -> Vec<u8> {
    let mut rng = SplitMix64::new(key ^ 0x9e37_79b9_7f4a_7c15);
    let len = (rng.next_u64() % 24) as usize;
    (0..len).map(|_| rng.next_u64() as u8).collect()
}

/// The standard sweep workload: two memtable eras with overlapping
/// keys and deletes (including a delete of a flushed key), a
/// compaction, and a trailing unflushed era — every lifecycle
/// transition the WAL participates in.
pub fn standard_script() -> Vec<Step> {
    let mut s = Vec::new();
    for k in 0..12u64 {
        s.push(Step::Put(k));
    }
    s.push(Step::Del(3)); // memtable-local delete
    s.push(Step::Flush);
    for k in 8..20u64 {
        s.push(Step::Put(k)); // upserts 8..12 shadow the run
    }
    s.push(Step::Del(1)); // delete of a flushed key
    s.push(Step::Del(40)); // absent: rejected, never logged
    s.push(Step::Flush);
    s.push(Step::Compact);
    for k in 20..26u64 {
        s.push(Step::Put(k)); // unflushed era: WAL-only
    }
    s.push(Step::Del(9));
    s
}

/// Largest key any model/probe needs to cover (exclusive).
const PROBE_SPAN: u64 = 48;

/// Run `script` against `node`, tracking the acknowledged-durable
/// model. Returns `(durable, uncertain)`: the state every recovery
/// must restore, plus the at-most-one in-flight op the crash may or
/// may not have persisted (`None` when no op is uncertain).
pub fn run_script(
    node: &mut StorageNode,
    script: &[Step],
    io: Option<&FaultyIo>,
) -> (BTreeMap<u64, Vec<u8>>, Option<Step>) {
    let mut durable: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
    let mut uncertain: Option<Step> = None;
    for &step in script {
        // An op that fails *after* the disk already died cannot have
        // landed; only the op the crash fires inside is uncertain.
        let dead_before = io.map(|i| i.crashed()).unwrap_or(false);
        match step {
            Step::Put(k) => {
                let before = node.stats.wal_append_failed();
                node.put_value(k, &value_for(k))
                    .expect("sweep backends are not static");
                if node.stats.wal_append_failed() == before {
                    durable.insert(k, value_for(k));
                } else if uncertain.is_none() && !dead_before {
                    uncertain = Some(step);
                }
            }
            Step::Del(k) => {
                let before = node.stats.wal_append_failed();
                if node.delete(k) {
                    if node.stats.wal_append_failed() == before {
                        durable.remove(&k);
                    } else if uncertain.is_none() && !dead_before {
                        uncertain = Some(step);
                    }
                }
            }
            Step::Flush => node.flush(FlushReason::MemtableKeys),
            Step::Compact => node.compact(),
        }
    }
    (durable, uncertain)
}

/// Node config for sweep runs: manual flush/compact control (huge
/// thresholds), WAL on, the chosen filter backend and fsync policy.
pub fn sweep_cfg(
    dir: &str,
    backend: &str,
    fsync: FsyncPolicy,
    io: Option<Arc<dyn StoreIo>>,
) -> NodeConfig {
    NodeConfig {
        filter: FilterBuilder::named(backend)
            .unwrap_or_else(|e| panic!("sweep backend {backend}: {e}"))
            .with_initial_capacity(4096),
        flush: FlushPolicy::small(1_000_000),
        compaction: CompactionPolicy {
            max_tables: 64,
            drop_tombstones: true,
        },
        persist_dir: Some(dir.to_string()),
        wal: WalConfig {
            enabled: true,
            fsync,
        },
        io,
        ..NodeConfig::default()
    }
}

/// Unique scratch dir (no tempfile crate offline).
fn scratch(tag: &str) -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Relaxed);
    let dir = std::env::temp_dir().join(format!("ocf-sweep-{tag}-{}-{n}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_str().unwrap().to_string()
}

/// The visible key→value state of a node, probed over the sweep's
/// key span.
fn visible_state(node: &StorageNode) -> BTreeMap<u64, Vec<u8>> {
    (0..PROBE_SPAN)
        .filter_map(|k| node.get_value(k).map(|v| (k, v.to_vec())))
        .collect()
}

fn apply_uncertain(
    durable: &BTreeMap<u64, Vec<u8>>,
    uncertain: Step,
) -> BTreeMap<u64, Vec<u8>> {
    let mut alt = durable.clone();
    match uncertain {
        Step::Put(k) => {
            alt.insert(k, value_for(k));
        }
        Step::Del(k) => {
            alt.remove(&k);
        }
        Step::Flush | Step::Compact => {}
    }
    alt
}

/// Aggregate results of one full sweep.
#[derive(Debug, Default)]
pub struct SweepReport {
    /// Distinct crash points visited (the workload's mutation count).
    pub crash_points: u64,
    /// Ops replayed from the WAL, summed over all recoveries.
    pub wal_replayed: u64,
    /// Torn segment tails tolerated, summed over all recoveries.
    pub torn_tails: u64,
}

/// Sweep every crash point of [`standard_script`] for one backend ×
/// fsync policy, asserting the durability contract at each. Panics
/// (with the crash point in the message) on any violation.
pub fn crash_sweep(backend: &str, fsync: FsyncPolicy) -> SweepReport {
    let script = standard_script();
    let tag = format!("{backend}-{}", fsync.describe());

    // Counting pass: learn the workload's crash-point space.
    let dir = scratch(&format!("{tag}-count"));
    let counter = Arc::new(FaultyIo::new(FaultConfig::default()));
    let mut node = StorageNode::new(sweep_cfg(&dir, backend, fsync, Some(counter.clone())));
    let (clean_model, clean_uncertain) = run_script(&mut node, &script, Some(counter.as_ref()));
    assert_eq!(clean_uncertain, None, "fault-free run must not degrade");
    assert_eq!(node.stats.wal_append_failed(), 0);
    drop(node);
    let points = counter.mutations();
    assert!(points > 0, "workload must touch the disk");
    // The clean run's own recovery must restore the full model.
    let recovered = StorageNode::recover(sweep_cfg(&dir, backend, fsync, None))
        .unwrap_or_else(|e| panic!("clean recovery failed: {e}"));
    assert_eq!(visible_state(&recovered), clean_model, "clean-run recovery");
    drop(recovered);
    let _ = std::fs::remove_dir_all(&dir);

    let mut report = SweepReport {
        crash_points: points,
        ..SweepReport::default()
    };
    for point in 0..points {
        let dir = scratch(&format!("{tag}-p{point}"));
        let io = Arc::new(FaultyIo::crash_at(0xc0ff_ee00 ^ point, point));
        let mut node = StorageNode::new(sweep_cfg(&dir, backend, fsync, Some(io.clone())));
        let (durable, uncertain) = run_script(&mut node, &script, Some(io.as_ref()));
        assert!(io.crashed(), "crash point {point} must fire (of {points})");
        drop(node); // SIGKILL analog: no flush, no shutdown hooks

        // Recovery runs on the pristine real filesystem — the injected
        // crash left whatever bytes it left.
        let r = StorageNode::recover(sweep_cfg(&dir, backend, fsync, None))
            .unwrap_or_else(|e| panic!("crash point {point}: recovery failed: {e}"));
        let got = visible_state(&r);
        let ok = got == durable
            || uncertain
                .map(|u| got == apply_uncertain(&durable, u))
                .unwrap_or(false);
        assert!(
            ok,
            "crash point {point} ({backend}, fsync={}): recovered state diverged\n\
             acknowledged-durable: {durable:?}\nuncertain op: {uncertain:?}\nrecovered: {got:?}",
            fsync.describe(),
        );
        report.wal_replayed += r.stats.wal_replayed();
        report.torn_tails += r.stats.wal_torn_tail();
        drop(r);
        let _ = std::fs::remove_dir_all(&dir);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_for_is_deterministic_and_varied() {
        assert_eq!(value_for(7), value_for(7));
        let lens: std::collections::HashSet<usize> =
            (0..32u64).map(|k| value_for(k).len()).collect();
        assert!(lens.len() > 3, "payload lengths should vary: {lens:?}");
    }

    #[test]
    fn standard_script_exercises_every_lifecycle_stage() {
        let s = standard_script();
        assert!(s.iter().filter(|x| matches!(x, Step::Flush)).count() >= 2);
        assert!(s.contains(&Step::Compact));
        assert!(s.iter().any(|x| matches!(x, Step::Del(_))));
        assert!(s.len() >= 30);
        assert!(
            s.iter()
                .all(|x| match x {
                    Step::Put(k) | Step::Del(k) => *k < PROBE_SPAN,
                    _ => true,
                }),
            "probe span must cover every scripted key"
        );
    }

    #[test]
    fn clean_run_model_matches_node_state() {
        let dir = scratch("model");
        let mut node = StorageNode::new(sweep_cfg(&dir, "ocf", FsyncPolicy::Always, None));
        let (durable, uncertain) = run_script(&mut node, &standard_script(), None);
        assert_eq!(uncertain, None);
        assert_eq!(visible_state(&node), durable);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

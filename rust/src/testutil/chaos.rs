//! Chaos sweeping for the replication layer — the cluster-level
//! counterpart of [`crate::testutil::crash`].
//!
//! The crash sweep proves the WAL's durability contract by enumerating
//! every crash point; the chaos sweep proves the *router's* fault
//! contract by enumerating seeded fault schedules: each node gets its
//! own [`FaultSchedule`] (transient / latent / crashed windows over the
//! op clock, recovered past a horizon), a scripted workload runs
//! against the cluster, and the availability contract is asserted op
//! by op against an acknowledged-state model:
//!
//! * **No lost acks**: a key whose put was acknowledged at the write
//!   consistency level must never read `false` (quorum-lost reads are
//!   typed errors, not answers, and are exempt).
//! * **No resurrections**: a key whose delete was acknowledged must
//!   never read `true` again.
//! * **Convergence**: after the fault horizon, hint queues drain to
//!   zero with nothing dropped, and every non-uncertain key is in the
//!   model's state on *all* of its replicas.
//! * **Full availability when healthy**: a zero-rate schedule must ack
//!   every write and lose no quorum (the control arm).
//!
//! Ops that fail with [`ClusterError::QuorumLost`] mark their key
//! *uncertain* (the write may have partially applied; its hints will
//! replay later) — the model excludes them from the point asserts,
//! exactly like the crash sweep's single in-flight uncertain op.
//!
//! Everything is a pure function of `(seed, ops, fault_rate)`: the
//! workload, the schedules, the retry jitter, and the breaker cooldowns
//! all derive from the seed and the op clock, so a failing schedule
//! replays bit-identically (proptest P18 asserts this).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::{
    Cluster, ClusterError, ClusterStats, Consistency, FaultPlane, FaultSchedule, MembershipError,
    ReplicationConfig, ResilienceConfig,
};
use crate::cluster::health::BreakerConfig;
use crate::store::{FlushPolicy, NodeConfig};
use crate::util::{rng::GOLDEN_GAMMA, SplitMix64};

/// What the acknowledged-state model knows about one key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Truth {
    /// Last acknowledged write was a put: reads must say present.
    Present,
    /// Last acknowledged write was a delete (or the key was never
    /// written): reads must say absent.
    Absent,
    /// A quorum-lost write may have partially applied; no point assert
    /// holds until the next acknowledged write.
    Uncertain,
}

/// The deterministic fingerprint of one schedule run — two runs with
/// the same `(seed, ops, fault_rate)` must produce equal outcomes
/// (proptest P18's chaos-determinism property).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosOutcome {
    /// Full router counters, including `per_node_ops`.
    pub stats: ClusterStats,
    /// `live_keys` per node after the drain.
    pub per_node_live: Vec<u64>,
    /// Per-op answer codes: `0` absent, `1` present/acked, `2`
    /// quorum lost.
    pub answers: Vec<u8>,
    pub writes_attempted: u64,
    pub writes_acked: u64,
    /// Clock advances the drain loop needed before hints hit zero.
    pub drain_rounds: u64,
    /// Synthetic latency absorbed from latent windows (µs).
    pub synthetic_latency_us: u64,
    /// Latent ops that exceeded the timeout.
    pub timeouts: u64,
}

/// Aggregate counters over a multi-schedule sweep.
#[derive(Debug, Clone, Default)]
pub struct ChaosReport {
    pub schedules: u64,
    pub ops: u64,
    pub writes_attempted: u64,
    pub writes_acked: u64,
    pub quorum_losses: u64,
    pub retries: u64,
    pub breaker_trips: u64,
    pub hints_queued: u64,
    pub hints_replayed: u64,
    pub hints_superseded: u64,
    pub read_repairs: u64,
    pub timeouts: u64,
    pub transfers_started: u64,
    pub transfers_completed: u64,
    pub transfers_retried: u64,
    pub keys_streamed: u64,
    pub keys_superseded: u64,
    pub hints_retired: u64,
}

/// Keys the scripted workload draws from — small enough that puts,
/// deletes, and reads collide constantly.
const KEY_SPACE: u64 = 512;

/// The sweep's fixed policy: quorum reads *and* writes over rf=3, so
/// R + W > RF and the no-lost-acks argument is airtight.
fn sweep_replication() -> ReplicationConfig {
    ReplicationConfig {
        rf: 3,
        read_consistency: Consistency::Quorum,
        write_consistency: Consistency::Quorum,
    }
}

/// Tight-but-realistic fault handling for sweep runs: a small retry
/// budget, a breaker that trips fast and probes once, ample hint space.
fn sweep_resilience() -> ResilienceConfig {
    ResilienceConfig {
        retry_budget: 2,
        timeout_us: 1_000,
        breaker: BreakerConfig {
            threshold: 3,
            cooldown: 48,
            probes: 1,
        },
        handoff_capacity: 4_096,
        transfer_batch: 64,
    }
}

/// Build the sweep cluster: `3 + seed % 3` nodes, each behind its own
/// seeded fault schedule with fault density `fault_rate` over
/// `[0, ops)` ticks and guaranteed recovery afterwards.
fn sweep_cluster(seed: u64, ops: usize, fault_rate: f64) -> Cluster {
    let n = 3 + (seed % 3) as usize;
    let planes: Vec<Arc<dyn FaultPlane>> = (0..n)
        .map(|node| {
            let node_seed = seed ^ (node as u64 + 1).wrapping_mul(GOLDEN_GAMMA);
            Arc::new(FaultSchedule::seeded(node_seed, fault_rate, ops as u64))
                as Arc<dyn FaultPlane>
        })
        .collect();
    Cluster::with_fault_planes(
        n,
        32,
        NodeConfig {
            flush: FlushPolicy::small(10_000),
            ..NodeConfig::default()
        },
        sweep_replication(),
        sweep_resilience(),
        planes,
    )
}

/// The scripted workload plus its acknowledged-state model — the
/// per-op contract asserts live in [`Script::step`] so the plain and
/// membership schedules share one definition of "correct".
struct Script {
    seed: u64,
    fault_rate: f64,
    ops: usize,
    rng: SplitMix64,
    model: BTreeMap<u64, Truth>,
    answers: Vec<u8>,
    writes_attempted: u64,
    writes_acked: u64,
}

impl Script {
    fn new(seed: u64, ops: usize, fault_rate: f64) -> Self {
        Self {
            seed,
            fault_rate,
            ops,
            rng: SplitMix64::new(seed.wrapping_mul(GOLDEN_GAMMA) ^ 0xc4a0_5eed),
            model: BTreeMap::new(),
            answers: Vec::with_capacity(ops),
            writes_attempted: 0,
            writes_acked: 0,
        }
    }

    /// Run op `i` against the cluster and assert the availability
    /// contract against the model: no lost acks, no resurrections,
    /// typed errors only.
    fn step(&mut self, cluster: &mut Cluster, i: usize) {
        let key = self.rng.next_below(KEY_SPACE);
        let truth = self.model.get(&key).copied().unwrap_or(Truth::Absent);
        let ctx = |s: &Self| {
            format!(
                "seed {:#x}, rate {}, op {i}/{}",
                s.seed, s.fault_rate, s.ops
            )
        };
        // ~50% put / 20% delete / 30% get
        match self.rng.next_below(10) {
            0..=4 => {
                self.writes_attempted += 1;
                match cluster.put(key) {
                    Ok(()) => {
                        self.writes_acked += 1;
                        self.model.insert(key, Truth::Present);
                        self.answers.push(1);
                    }
                    Err(e) => {
                        assert!(
                            matches!(e, ClusterError::QuorumLost { .. }),
                            "{}: put must fail typed, got {e}",
                            ctx(self)
                        );
                        self.model.insert(key, Truth::Uncertain);
                        self.answers.push(2);
                    }
                }
            }
            5..=6 => {
                self.writes_attempted += 1;
                match cluster.delete(key) {
                    Ok(was) => {
                        self.writes_acked += 1;
                        if truth == Truth::Present {
                            assert!(
                                was,
                                "{}: acked delete of a present key found nothing",
                                ctx(self)
                            );
                        }
                        self.model.insert(key, Truth::Absent);
                        self.answers.push(u8::from(was));
                    }
                    Err(e) => {
                        assert!(
                            matches!(e, ClusterError::QuorumLost { .. }),
                            "{}: delete must fail typed, got {e}",
                            ctx(self)
                        );
                        self.model.insert(key, Truth::Uncertain);
                        self.answers.push(2);
                    }
                }
            }
            _ => match cluster.get(key) {
                Ok(hit) => {
                    match truth {
                        Truth::Present => assert!(
                            hit,
                            "{}: FALSE NEGATIVE — acked write of {key} read absent",
                            ctx(self)
                        ),
                        Truth::Absent => assert!(
                            !hit,
                            "{}: RESURRECTION — deleted key {key} read present",
                            ctx(self)
                        ),
                        Truth::Uncertain => {}
                    }
                    self.answers.push(u8::from(hit));
                }
                Err(e) => {
                    assert!(
                        matches!(e, ClusterError::QuorumLost { .. }),
                        "{}: get must fail typed, got {e}",
                        ctx(self)
                    );
                    self.answers.push(2);
                }
            },
        }
    }

    /// Converged audit: every non-uncertain key is in its modelled
    /// state on every one of its *current* replicas — after a
    /// membership change, that is the new ring's replica set.
    fn audit(&self, cluster: &Cluster) {
        let rf = cluster.replication().rf;
        for (&key, &truth) in &self.model {
            let expect = match truth {
                Truth::Present => true,
                Truth::Absent => false,
                Truth::Uncertain => continue,
            };
            for n in cluster.ring().replicas(key, rf) {
                assert_eq!(
                    cluster.node(n).get(key),
                    expect,
                    "seed {:#x}, rate {}: replica {n} diverged on key {key} \
                     (model {truth:?}) after drain",
                    self.seed,
                    self.fault_rate
                );
            }
        }
    }

    fn outcome(self, cluster: &Cluster, drain_rounds: u64) -> ChaosOutcome {
        ChaosOutcome {
            synthetic_latency_us: cluster.synthetic_latency_us(),
            timeouts: cluster.timeouts(),
            stats: cluster.stats.clone(),
            per_node_live: (0..cluster.node_count())
                .map(|n| cluster.node(n).live_keys() as u64)
                .collect(),
            answers: self.answers,
            writes_attempted: self.writes_attempted,
            writes_acked: self.writes_acked,
            drain_rounds,
        }
    }
}

/// Run one seeded schedule: scripted workload, per-op contract asserts,
/// recovery drain, final all-replica audit. Panics with the seed, rate,
/// and op index on any violation; returns the run's deterministic
/// fingerprint otherwise.
pub fn run_one_schedule(seed: u64, ops: usize, fault_rate: f64) -> ChaosOutcome {
    let mut cluster = sweep_cluster(seed, ops, fault_rate);
    let mut script = Script::new(seed, ops, fault_rate);
    for i in 0..ops {
        script.step(&mut cluster, i);
    }

    // Recovery: the clock is at the fault horizon, so every plane is
    // permanently healthy — hint queues must drain completely once the
    // breakers' cooldowns elapse.
    let cooldown = cluster.resilience().breaker.cooldown;
    let mut drain_rounds = 0u64;
    while cluster.replay_hints() > 0 {
        drain_rounds += 1;
        assert!(
            drain_rounds < 64,
            "seed {seed:#x}, rate {fault_rate}: hints refuse to drain \
             ({} pending after {drain_rounds} rounds)",
            cluster.hints_pending()
        );
        cluster.advance_clock(cooldown + 1);
    }
    assert_eq!(
        cluster.stats.hints_dropped, 0,
        "seed {seed:#x}, rate {fault_rate}: dropped hints void the contract"
    );
    script.audit(&cluster);
    script.outcome(&cluster, drain_rounds)
}

/// Run one seeded schedule with live membership changes interleaved:
/// a node joins around `ops/3`, one of the original nodes leaves
/// around `2·ops/3` (retrying each tick while the join is still
/// streaming), both under the same per-node fault schedules as the
/// plain sweep — so donors and joiners crash mid-transfer. Asserts the
/// full PR-9 contract per op *across* the topology changes, then
/// drains transfers and hints to zero and audits every key against the
/// *final* ring.
pub fn run_one_membership_schedule(seed: u64, ops: usize, fault_rate: f64) -> ChaosOutcome {
    let mut cluster = sweep_cluster(seed, ops, fault_rate);
    let n0 = cluster.node_count();
    let mut script = Script::new(seed, ops, fault_rate);
    let join_at = (ops / 3 + (seed % 32) as usize).min(ops.saturating_sub(1));
    let leave_at = (2 * ops / 3 + (seed % 16) as usize).min(ops.saturating_sub(1));
    let leaver = (seed % n0 as u64) as usize;
    let mut left = false;

    for i in 0..ops {
        if i == join_at {
            // the joiner runs under its own seeded fault schedule, so
            // the stream's *target* can die mid-transfer too
            let plane_seed = seed ^ (n0 as u64 + 1).wrapping_mul(GOLDEN_GAMMA);
            let plane: Arc<dyn FaultPlane> =
                Arc::new(FaultSchedule::seeded(plane_seed, fault_rate, ops as u64));
            let id = cluster
                .add_node_with_plane(plane)
                .expect("no transfer in flight at join time");
            assert_eq!(id, n0, "stable ids: joiner takes the next slot");
        }
        if i >= leave_at && !left {
            match cluster.remove_node(leaver) {
                Ok(()) => left = true,
                // the join is still streaming: one transition at a
                // time — retry on the next tick, deterministically
                Err(MembershipError::TransferInProgress) => {}
                Err(e) => panic!("seed {seed:#x}: remove_node({leaver}) failed: {e}"),
            }
        }
        script.step(&mut cluster, i);
    }

    // Drain: pump the transfer and replay hints together until both
    // queues are empty. Past the fault horizon every plane is healthy,
    // so the only waits left are breaker cooldowns.
    let cooldown = cluster.resilience().breaker.cooldown;
    let mut drain_rounds = 0u64;
    let drain = |cluster: &mut Cluster, drain_rounds: &mut u64| loop {
        let ranges = cluster.pump_transfers();
        let hints = cluster.replay_hints();
        if ranges == 0 && hints == 0 && !cluster.transfer_active() {
            break;
        }
        *drain_rounds += 1;
        assert!(
            *drain_rounds < 4_096,
            "seed {seed:#x}, rate {fault_rate}: transfer/hints refuse to drain \
             ({} ranges, {} hints pending after {drain_rounds} rounds)",
            cluster.ranges_pending(),
            cluster.hints_pending()
        );
        cluster.advance_clock(cooldown + 1);
    };
    drain(&mut cluster, &mut drain_rounds);
    if !left {
        // the whole workload ran inside the join transfer: run the
        // leave now that the ring is quiet, and drain it too
        cluster
            .remove_node(leaver)
            .expect("join drained; leave must start");
        drain(&mut cluster, &mut drain_rounds);
    }

    // Post-drain contract: both transitions completed, nothing pending,
    // nothing dropped, and the transfer conservation law holds.
    assert!(!cluster.transfer_active());
    assert_eq!(cluster.ranges_pending(), 0);
    assert_eq!(cluster.stats.transfers_started, 2, "one join, one leave");
    assert_eq!(cluster.stats.transfers_completed, 2);
    assert_eq!(
        cluster.stats.hints_dropped, 0,
        "seed {seed:#x}, rate {fault_rate}: dropped hints void the contract"
    );
    assert_eq!(
        cluster.stats.keys_captured,
        cluster.stats.keys_streamed + cluster.stats.keys_superseded,
        "seed {seed:#x}, rate {fault_rate}: transfer conservation violated"
    );
    assert!(cluster.ring().contains(n0), "joiner is a ring member");
    assert!(!cluster.ring().contains(leaver), "leaver retired");
    assert!(cluster.is_retired(leaver));
    script.audit(&cluster);
    script.outcome(&cluster, drain_rounds)
}

/// Fault densities a sweep cycles through; the 0.0 arm is the control
/// (full availability required).
pub const SWEEP_RATES: [f64; 4] = [0.0, 0.05, 0.15, 0.3];

/// Sweep `schedules` seeded runs of `ops` ops each, cycling over
/// [`SWEEP_RATES`]; asserts the contract inside every run plus full
/// availability on the control arms, and returns aggregate counters.
pub fn chaos_sweep(schedules: usize, ops: usize) -> ChaosReport {
    let mut report = ChaosReport::default();
    for i in 0..schedules {
        let rate = SWEEP_RATES[i % SWEEP_RATES.len()];
        let seed = 0xc4a0_5000 + i as u64;
        let out = run_one_schedule(seed, ops, rate);
        if rate == 0.0 {
            assert_eq!(
                out.writes_acked, out.writes_attempted,
                "seed {seed:#x}: healthy control arm must ack every write"
            );
            assert_eq!(
                out.stats.quorum_losses, 0,
                "seed {seed:#x}: healthy control arm lost a quorum"
            );
        }
        report.absorb(&out);
    }
    report
}

impl ChaosReport {
    fn absorb(&mut self, out: &ChaosOutcome) {
        self.schedules += 1;
        self.ops += out.answers.len() as u64;
        self.writes_attempted += out.writes_attempted;
        self.writes_acked += out.writes_acked;
        self.quorum_losses += out.stats.quorum_losses;
        self.retries += out.stats.retries;
        self.breaker_trips += out.stats.breaker_trips;
        self.hints_queued += out.stats.hints_queued;
        self.hints_replayed += out.stats.hints_replayed;
        self.hints_superseded += out.stats.hints_superseded;
        self.read_repairs += out.stats.read_repairs;
        self.timeouts += out.timeouts;
        self.transfers_started += out.stats.transfers_started;
        self.transfers_completed += out.stats.transfers_completed;
        self.transfers_retried += out.stats.transfers_retried;
        self.keys_streamed += out.stats.keys_streamed;
        self.keys_superseded += out.stats.keys_superseded;
        self.hints_retired += out.stats.hints_retired;
    }
}

/// [`chaos_sweep`] with topology changes: every schedule interleaves a
/// node join and a node leave with the fault windows
/// ([`run_one_membership_schedule`]). Control arms must stay fully
/// available *through* the membership changes.
pub fn membership_sweep(schedules: usize, ops: usize) -> ChaosReport {
    let mut report = ChaosReport::default();
    for i in 0..schedules {
        let rate = SWEEP_RATES[i % SWEEP_RATES.len()];
        let seed = 0xc4a0_6000 + i as u64;
        let out = run_one_membership_schedule(seed, ops, rate);
        if rate == 0.0 {
            assert_eq!(
                out.writes_acked, out.writes_attempted,
                "seed {seed:#x}: membership control arm must ack every write"
            );
            assert_eq!(
                out.stats.quorum_losses, 0,
                "seed {seed:#x}: membership control arm lost a quorum"
            );
        }
        assert_eq!(out.stats.transfers_completed, 2);
        report.absorb(&out);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn control_schedule_is_fully_available() {
        let out = run_one_schedule(0xc0_01, 400, 0.0);
        assert_eq!(out.writes_acked, out.writes_attempted);
        assert_eq!(out.stats.quorum_losses, 0);
        assert_eq!(out.stats.hints_queued, 0);
        assert_eq!(out.drain_rounds, 0);
        assert!(!out.answers.contains(&2), "no quorum losses when healthy");
    }

    #[test]
    fn chaotic_schedule_engages_the_fault_machinery() {
        let out = run_one_schedule(0xc4_a05, 600, 0.3);
        // a 30% fault density over 600 ticks must exercise *some* of
        // the machinery — retries, hints, or breaker trips
        assert!(
            out.stats.retries + out.stats.hints_queued + out.stats.breaker_trips > 0,
            "rate 0.3 engaged nothing: {:?}",
            out.stats
        );
        assert_eq!(out.stats.hints_dropped, 0);
    }

    #[test]
    fn same_seed_replays_bit_identically() {
        let a = run_one_schedule(0x5eed, 300, 0.2);
        let b = run_one_schedule(0x5eed, 300, 0.2);
        assert_eq!(a, b, "chaos runs must be pure functions of the seed");
    }

    #[test]
    fn membership_control_schedule_is_fully_available() {
        let out = run_one_membership_schedule(0x1015, 400, 0.0);
        assert_eq!(out.writes_acked, out.writes_attempted);
        assert_eq!(out.stats.quorum_losses, 0);
        assert_eq!(out.stats.transfers_started, 2);
        assert_eq!(out.stats.transfers_completed, 2);
        assert!(
            out.stats.keys_streamed > 0,
            "a healthy join over a populated key space must stream keys"
        );
        // the joiner (last per_node_live slot) received data
        assert!(*out.per_node_live.last().unwrap() > 0);
        assert!(!out.answers.contains(&2), "no quorum losses when healthy");
    }

    #[test]
    fn chaotic_membership_schedule_survives_mid_transfer_faults() {
        let out = run_one_membership_schedule(0x1016, 600, 0.3);
        // the per-op and post-drain asserts inside the run are the real
        // test; here we pin that faults actually hit the transfer path
        assert_eq!(out.stats.transfers_completed, 2);
        assert!(
            out.stats.retries + out.stats.hints_queued + out.stats.transfers_retried > 0,
            "rate 0.3 engaged nothing: {:?}",
            out.stats
        );
        assert_eq!(
            out.stats.keys_captured,
            out.stats.keys_streamed + out.stats.keys_superseded
        );
    }

    #[test]
    fn same_membership_seed_replays_bit_identically() {
        let a = run_one_membership_schedule(0x5eed, 300, 0.2);
        let b = run_one_membership_schedule(0x5eed, 300, 0.2);
        assert_eq!(a, b, "membership chaos must be a pure function of the seed");
    }
}

//! Deterministic PRNGs: SplitMix64 and Xoshiro256++.
//!
//! Everything in this crate that needs randomness (workload generators,
//! eviction victim choice, property tests) threads one of these through
//! explicitly — no global RNG, no OS entropy — so every experiment and
//! every test is bit-reproducible from its seed.

/// SplitMix64 (Steele, Lea & Flood 2014). Also the seeding PRNG
/// recommended for Xoshiro. One `next_u64` is exactly the `mix64`
/// finalizer used by the filter hash family.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift reduction.
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Xoshiro256++ (Blackman & Vigna 2019): the workhorse generator for
/// bulk workload generation (faster mixing per call than SplitMix64 and
/// a much larger state/period).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_vectors() {
        // Canonical SplitMix64 stream from seed 0.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = Xoshiro256pp::new(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn xoshiro_not_obviously_broken() {
        // mean of uniform draws ~ 0.5 (law of large numbers, loose bound)
        let mut r = Xoshiro256pp::new(99);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "seed 5 must permute");
    }
}

//! Bounded retry-with-backoff for *transient* I/O errors.
//!
//! POSIX file ops can fail spuriously with `EINTR` (a signal landed
//! mid-syscall) or `EAGAIN`/`EWOULDBLOCK` (kernel buffer pressure on
//! some filesystems); both map to [`std::io::ErrorKind::Interrupted`]
//! / [`std::io::ErrorKind::WouldBlock`] in Rust. Those are the only
//! error kinds worth retrying blindly — anything else (ENOSPC, EIO,
//! permission errors) signals real state the caller must handle.
//!
//! [`retry_transient`] re-runs the operation up to a small fixed
//! number of attempts with an exponential-ish spin/sleep backoff and
//! reports *how many retries it absorbed*, so callers can surface the
//! count (the store feeds it into the `io_retries` `NodeStats`
//! counter — transient churn is a health signal even when every retry
//! succeeds).
//!
//! The budget is deliberately tiny and the backoff deliberately short
//! (micro-sleeps, ~1 ms worst case in total): this helper sits on
//! write paths (`FrozenStore` atomic writes, WAL fsync) where hiding
//! a persistent failure behind long sleeps would be worse than
//! failing loudly.

use std::io;
use std::time::Duration;

/// Maximum attempts per operation (1 initial + `MAX_RETRIES` retries).
pub const MAX_RETRIES: u32 = 4;

/// Outcome of [`retry_transient`]: the final result plus the number
/// of transient failures that were absorbed along the way. `retries`
/// can be non-zero even on `Ok` (that is the point of counting).
#[derive(Debug)]
pub struct Retried<T> {
    pub result: io::Result<T>,
    pub retries: u32,
}

impl<T> Retried<T> {
    /// Unwrap into a plain `io::Result`, discarding the retry count.
    pub fn into_result(self) -> io::Result<T> {
        self.result
    }
}

/// True when `kind` is a transient condition that a blind retry can
/// legitimately clear (`EINTR` / `EAGAIN`).
pub fn is_transient(kind: io::ErrorKind) -> bool {
    matches!(kind, io::ErrorKind::Interrupted | io::ErrorKind::WouldBlock)
}

/// Run `op`, retrying up to [`MAX_RETRIES`] times on transient errors
/// with a short exponential backoff (10 µs, 40 µs, 160 µs, 640 µs).
///
/// Non-transient errors and exhaustion both surface as the final
/// `Err`; the retry count is reported either way.
pub fn retry_transient<T>(mut op: impl FnMut() -> io::Result<T>) -> Retried<T> {
    retry_transient_with(MAX_RETRIES, 0, |_| op())
}

/// [`retry_transient`] with a caller-chosen budget and optional seeded
/// jitter — the shape the cluster router needs, where the budget is a
/// `[cluster] retry_budget` config key rather than a compile-time
/// constant and many replicas may be retrying the same fault window.
///
/// `op` receives the attempt index (0 = first try), so callers can
/// thread it into per-attempt context. A non-zero `jitter_seed` adds a
/// deterministic pseudo-random 0..=50% to each backoff step so replicas
/// don't sleep in lockstep (the classic retry thundering herd); the
/// jitter only perturbs *sleep durations*, never control flow, so
/// seeded runs stay bit-reproducible.
pub fn retry_transient_with<T>(
    budget: u32,
    jitter_seed: u64,
    mut op: impl FnMut(u32) -> io::Result<T>,
) -> Retried<T> {
    let mut retries = 0u32;
    loop {
        match op(retries) {
            Ok(v) => {
                return Retried {
                    result: Ok(v),
                    retries,
                }
            }
            Err(e) if is_transient(e.kind()) && retries < budget => {
                // 10 µs · 4^n: long enough to let a signal storm or a
                // momentarily full buffer drain, short enough to be
                // invisible on the write path. The exponent is capped
                // so a generous configured budget can't sleep seconds.
                let base = 10u64 << (2 * retries.min(4));
                let jitter = if jitter_seed == 0 {
                    0
                } else {
                    let mut rng =
                        crate::util::rng::SplitMix64::new(jitter_seed ^ u64::from(retries));
                    rng.next_below(base / 2 + 1)
                };
                std::thread::sleep(Duration::from_micros(base + jitter));
                retries += 1;
            }
            Err(e) => {
                return Retried {
                    result: Err(e),
                    retries,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_try_success_counts_zero_retries() {
        let r = retry_transient(|| Ok::<_, io::Error>(7));
        assert_eq!(r.result.unwrap(), 7);
        assert_eq!(r.retries, 0);
    }

    #[test]
    fn transient_errors_are_absorbed_and_counted() {
        let mut failures = 2;
        let r = retry_transient(|| {
            if failures > 0 {
                failures -= 1;
                Err(io::Error::new(io::ErrorKind::Interrupted, "EINTR"))
            } else {
                Ok(42)
            }
        });
        assert_eq!(r.result.unwrap(), 42);
        assert_eq!(r.retries, 2);
    }

    #[test]
    fn wouldblock_is_transient_too() {
        let mut failed = false;
        let r = retry_transient(|| {
            if !failed {
                failed = true;
                Err(io::Error::new(io::ErrorKind::WouldBlock, "EAGAIN"))
            } else {
                Ok(())
            }
        });
        assert!(r.result.is_ok());
        assert_eq!(r.retries, 1);
    }

    #[test]
    fn non_transient_errors_surface_immediately() {
        let mut calls = 0;
        let r = retry_transient(|| -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::PermissionDenied, "nope"))
        });
        assert_eq!(calls, 1, "must not retry a hard error");
        assert_eq!(r.retries, 0);
        assert_eq!(
            r.result.unwrap_err().kind(),
            io::ErrorKind::PermissionDenied
        );
    }

    #[test]
    fn configurable_budget_and_attempt_indices() {
        let mut seen = Vec::new();
        let r = retry_transient_with(2, 0x5EED, |attempt| -> io::Result<()> {
            seen.push(attempt);
            Err(io::Error::new(io::ErrorKind::Interrupted, "EINTR"))
        });
        assert_eq!(seen, vec![0, 1, 2], "1 initial + 2 retries, indexed");
        assert_eq!(r.retries, 2);
        assert!(r.result.is_err());

        // budget 0 = fail-fast on the first transient
        let mut calls = 0;
        let r = retry_transient_with(0, 0, |_| -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::WouldBlock, "EAGAIN"))
        });
        assert_eq!(calls, 1);
        assert_eq!(r.retries, 0);
    }

    #[test]
    fn budget_is_bounded() {
        let mut calls = 0u32;
        let r = retry_transient(|| -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::Interrupted, "EINTR forever"))
        });
        assert_eq!(calls, 1 + MAX_RETRIES);
        assert_eq!(r.retries, MAX_RETRIES);
        assert_eq!(r.result.unwrap_err().kind(), io::ErrorKind::Interrupted);
    }
}

//! Minimal read-only file memory-mapping.
//!
//! The crate is dependency-free, so on unix targets `mmap`/`munmap` are
//! declared directly against the libc that `std` already links (the
//! same trick `std` itself uses for its platform layer); no new crates,
//! no build scripts. Non-unix targets compile the same API but report
//! mapping as unsupported ([`MmapRegion::supported`] = false), and
//! callers fall back to a heap read — the frozen-filter store does
//! exactly that, so persistence works everywhere and zero-copy serving
//! works where `mmap` exists.
//!
//! Only the read-only private mapping the frozen-filter tier needs is
//! implemented: map a whole file, hand out `&[u8]`, unmap on drop. The
//! region is `Send + Sync` (the kernel mapping is immutable and the
//! file is never written through it).

use std::fs::File;
use std::io;

#[cfg(unix)]
mod sys {
    //! Raw bindings to the 3 libc symbols we need. Constants cover the
    //! unix platforms this crate targets (linux/macos/freebsd share
    //! `PROT_READ = 1` and `MAP_PRIVATE = 2`).
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        pub fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }
}

/// A read-only memory mapping of an entire file.
pub struct MmapRegion {
    ptr: *const u8,
    len: usize,
}

// The mapping is immutable (PROT_READ, MAP_PRIVATE) and owned: sharing
// the region across threads is as safe as sharing a `&[u8]`.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapRegion").field("len", &self.len).finish()
    }
}

impl MmapRegion {
    /// Does this target support file mapping? When false,
    /// [`MmapRegion::map_file`] always errors and callers should use
    /// their heap-read fallback.
    pub const fn supported() -> bool {
        cfg!(unix)
    }

    /// Map the first `len` bytes of `file` read-only. `len` must be
    /// > 0 and ≤ the file's length (mapping past EOF would fault on
    /// first touch rather than fail cleanly, so it is rejected here).
    #[cfg(unix)]
    pub fn map_file(file: &File, len: usize) -> io::Result<MmapRegion> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "cannot map an empty range",
            ));
        }
        let file_len = file.metadata()?.len();
        if (len as u64) > file_len {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                format!("map of {len} bytes exceeds file length {file_len}"),
            ));
        }
        // Offset 0 is page-aligned on every page size, so the returned
        // base is page-aligned and interior offsets keep their natural
        // alignment (the frozen format places its u32 payload at a
        // 4096-byte interior offset).
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(MmapRegion {
            ptr: ptr as *const u8,
            len,
        })
    }

    #[cfg(not(unix))]
    pub fn map_file(_file: &File, _len: usize) -> io::Result<MmapRegion> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "mmap is not available on this target; use the heap fallback",
        ))
    }

    /// The mapped bytes.
    #[inline]
    pub fn as_bytes(&self) -> &[u8] {
        // Safe: the mapping is valid for `len` bytes until drop, and
        // never written through (PROT_READ).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Mapped length in bytes.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        unsafe {
            // munmap accepts any length; the kernel rounds up to page
            // granularity. Failure here is unrecoverable and harmless
            // to ignore (the address range simply stays reserved).
            let _ = sys::munmap(self.ptr as *mut core::ffi::c_void, self.len);
        }
    }
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp_file(tag: &str, bytes: &[u8]) -> (std::path::PathBuf, File) {
        let path = std::env::temp_dir().join(format!(
            "ocf-mmap-test-{tag}-{}",
            std::process::id()
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        (path.clone(), File::open(&path).unwrap())
    }

    #[test]
    fn maps_and_reads_file_contents() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let (path, f) = tmp_file("roundtrip", &data);
        let m = MmapRegion::map_file(&f, data.len()).unwrap();
        assert_eq!(m.as_bytes(), &data[..]);
        assert_eq!(m.len(), data.len());
        drop(m);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn partial_map_sees_prefix() {
        let data = vec![7u8; 8192];
        let (path, f) = tmp_file("prefix", &data);
        let m = MmapRegion::map_file(&f, 100).unwrap();
        assert_eq!(m.as_bytes(), &data[..100]);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn zero_and_oversized_maps_rejected() {
        let (path, f) = tmp_file("bounds", &[1, 2, 3]);
        assert!(MmapRegion::map_file(&f, 0).is_err());
        assert!(MmapRegion::map_file(&f, 4).is_err(), "past EOF must fail");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn region_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<MmapRegion>();
        assert!(MmapRegion::supported());
    }
}

//! Small deterministic utilities shared across the crate.

pub mod mmap;
pub mod retry;
pub mod rng;

pub use mmap::MmapRegion;
pub use retry::{is_transient, retry_transient, retry_transient_with, Retried, MAX_RETRIES};
pub use rng::{SplitMix64, Xoshiro256pp};

/// FNV-1a 64-bit checksum — the integrity check of the frozen-filter
/// on-disk format (`store::frozen`). Not cryptographic; it guards
/// against torn writes and bit rot, exactly like the per-block
/// checksums of LSM stores. Kept in `util` so format tooling and tests
/// share one definition.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Round `n` up to the next power of two (min 1).
#[inline]
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Format a byte count human-readably (KiB/MiB/GiB).
pub fn fmt_bytes(b: usize) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{b:.0} B")
    } else if b < K * K {
        format!("{:.1} KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1} MiB", b / (K * K))
    } else {
        format!("{:.2} GiB", b / (K * K * K))
    }
}

/// Format ops/sec human-readably.
pub fn fmt_rate(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e6 {
        format!("{:.2} Mops/s", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.1} Kops/s", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.1} ops/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_reference_vectors() {
        // offset basis for the empty input, published FNV-1a vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        // sensitivity: one flipped bit changes the sum
        assert_ne!(fnv1a64(&[0, 1, 2, 3]), fnv1a64(&[0, 1, 2, 2]));
    }

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).contains("MiB"));
        assert!(fmt_bytes(5 * 1024 * 1024 * 1024).contains("GiB"));
    }

    #[test]
    fn fmt_rate_units() {
        assert!(fmt_rate(2_500_000.0).contains("Mops"));
        assert!(fmt_rate(2_500.0).contains("Kops"));
        assert!(fmt_rate(25.0).contains("ops/s"));
    }
}

//! Runtime metrics: latency histograms, counters, throughput meters.
//!
//! The coordinator's observability substrate. [`Histogram`] is an
//! HDR-style log-linear bucketed recorder (fixed memory, ~2.5%
//! worst-case quantile error) built for the hot path: recording is two
//! integer ops + one increment, no allocation, no locks (single-writer;
//! use [`Histogram::merge`] to aggregate across threads).

pub mod histogram;
pub mod meter;

pub use histogram::Histogram;
pub use meter::{Counter, ThroughputMeter};

/// A latency/metric summary row for reports.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub count: u64,
    pub min: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
}

impl Summary {
    /// Render with a unit suffix (e.g. "ns", "us").
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} min={}{u} p50={}{u} p90={}{u} p99={}{u} p99.9={}{u} max={}{u} mean={:.1}{u}",
            self.count,
            self.min,
            self.p50,
            self.p90,
            self.p99,
            self.p999,
            self.max,
            self.mean,
            u = unit
        )
    }
}

//! Log-linear bucketed histogram (HDR-style).
//!
//! Values are bucketed by (exponent, mantissa-slice): 64 exponent rows
//! × [`SUBBUCKETS`] linear sub-buckets per row. Worst-case relative
//! quantile error is `1/SUBBUCKETS` (≈ 1.6% at 64). Fixed 32 KiB
//! footprint, O(1) record, O(buckets) quantile.

use super::Summary;

/// Linear sub-buckets per power of two (must be a power of two).
pub const SUBBUCKETS: usize = 64;
// rows: one exact row (values < SUBBUCKETS) + one per msb position in
// [sub_bits, 63] — row index = msb - sub_bits + 1, max 64 - sub_bits.
const ROWS: usize = 64 - SUBBUCKETS.trailing_zeros() as usize + 1;

/// Fixed-size log-linear histogram of `u64` samples (typically ns).
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>, // ROWS × SUBBUCKETS
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0u64; ROWS * SUBBUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline(always)]
    fn index_of(value: u64) -> usize {
        // row = how far the MSB is above the sub-bucket resolution;
        // values below SUBBUCKETS land in row 0 with exact resolution.
        let v = value.max(1);
        let msb = 63 - v.leading_zeros() as usize;
        let sub_bits = SUBBUCKETS.trailing_zeros() as usize;
        if msb < sub_bits {
            v as usize
        } else {
            let row = msb - sub_bits + 1;
            let sub = (v >> (msb - sub_bits)) as usize & (SUBBUCKETS - 1);
            // row 0 is the exact region [0, SUBBUCKETS); rows ≥ 1 each
            // cover [2^(msb), 2^(msb+1)) with SUBBUCKETS cells... but the
            // first half of row r duplicates row r-1's range, so offset
            // by SUBBUCKETS/2-aligned packing: use full rows for clarity.
            row * SUBBUCKETS + sub
        }
    }

    /// Representative (upper-bound) value for a bucket index.
    fn value_of(index: usize) -> u64 {
        let sub_bits = SUBBUCKETS.trailing_zeros() as usize;
        let row = index / SUBBUCKETS;
        let sub = index % SUBBUCKETS;
        if row == 0 {
            sub as u64
        } else {
            let msb = row + sub_bits - 1;
            ((SUBBUCKETS + sub) as u64) << (msb - sub_bits)
        }
    }

    /// Record one sample. O(1), allocation-free.
    #[inline]
    pub fn record(&mut self, value: u64) {
        let idx = Self::index_of(value);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile in [0, 1]; returns the bucket-representative value.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                // clamp to observed extrema so tiny samples report exactly
                return Self::value_of(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Merge another histogram into this one (cross-thread aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn summary(&self) -> Summary {
        Summary {
            count: self.count,
            min: if self.count == 0 { 0 } else { self.min },
            max: self.max,
            mean: self.mean(),
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        }
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SplitMix64;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn exact_small_values() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 3, 3, 10, 63] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.quantile(0.5), 3); // small values are exact
        assert_eq!(h.quantile(1.0), 63);
    }

    #[test]
    fn quantile_error_bounded() {
        let mut h = Histogram::new();
        let mut rng = SplitMix64::new(17);
        let mut vals: Vec<u64> = (0..100_000)
            .map(|_| 100 + rng.next_below(1_000_000))
            .collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99] {
            let exact = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let est = h.quantile(q);
            let rel = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.05, "q={q}: est={est} exact={exact} rel={rel}");
        }
    }

    #[test]
    fn mean_exact() {
        let mut h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        let mut rng = SplitMix64::new(3);
        for i in 0..10_000 {
            let v = rng.next_below(1_000_000);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            c.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(a.quantile(q), c.quantile(q), "q={q}");
        }
        assert_eq!(a.summary(), c.summary());
    }

    #[test]
    fn huge_values_dont_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        h.record(0);
        assert_eq!(h.count(), 3);
        assert!(h.quantile(0.99) >= u64::MAX / 2);
    }

    #[test]
    fn clear_resets() {
        let mut h = Histogram::new();
        h.record(5);
        h.clear();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn summary_renders() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let s = h.summary().render("ns");
        assert!(s.contains("n=3"));
        assert!(s.contains("p50="));
    }
}

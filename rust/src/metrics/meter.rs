//! Counters and throughput meters.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A shareable monotone counter (relaxed; used for cross-thread tallies
/// where exactness at read time doesn't matter).
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Windowed throughput meter: ops since construction / per window.
#[derive(Debug)]
pub struct ThroughputMeter {
    start: Instant,
    ops: u64,
    window_start: Instant,
    window_ops: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        let now = Instant::now();
        Self {
            start: now,
            ops: 0,
            window_start: now,
            window_ops: 0,
        }
    }

    #[inline]
    pub fn tick(&mut self, n: u64) {
        self.ops += n;
        self.window_ops += n;
    }

    /// Total ops/sec since construction.
    pub fn overall(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.ops as f64 / dt
        }
    }

    /// Ops/sec in the current window, then reset the window.
    pub fn window(&mut self) -> f64 {
        let dt = self.window_start.elapsed().as_secs_f64();
        let rate = if dt <= 0.0 {
            0.0
        } else {
            self.window_ops as f64 / dt
        };
        self.window_start = Instant::now();
        self.window_ops = 0;
        rate
    }

    pub fn total_ops(&self) -> u64 {
        self.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let c = Counter::new();
        c.inc();
        c.add(5);
        assert_eq!(c.get(), 6);
    }

    #[test]
    fn counter_cross_thread() {
        let c = std::sync::Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 40_000);
    }

    #[test]
    fn meter_counts_ops() {
        let mut m = ThroughputMeter::new();
        m.tick(100);
        m.tick(50);
        assert_eq!(m.total_ops(), 150);
        assert!(m.overall() > 0.0);
        let w = m.window();
        assert!(w > 0.0);
        // window reset
        std::thread::sleep(std::time::Duration::from_millis(1));
        assert_eq!(m.window(), 0.0);
    }
}

//! TOML-subset parser: sections, scalar `key = value` pairs, comments.
//!
//! Supported values: `"strings"`, integers (decimal, underscores ok),
//! floats, booleans. Arrays/tables-in-tables/dates are not — config for
//! this system doesn't need them (and the environment has no `toml`
//! crate; see DESIGN.md §substitutions).

use std::collections::BTreeMap;

/// Parse/typing errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    Parse { line: usize, msg: String },
    Type(String),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
            ConfigError::Type(msg) => write!(f, "type error: {msg}"),
            ConfigError::Invalid(msg) => write!(f, "invalid value: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    fn parse(raw: &str, line: usize) -> Result<Self, ConfigError> {
        let raw = raw.trim();
        if raw.is_empty() {
            return Err(ConfigError::Parse {
                line,
                msg: "empty value".into(),
            });
        }
        if let Some(stripped) = raw.strip_prefix('"') {
            let inner = stripped.strip_suffix('"').ok_or(ConfigError::Parse {
                line,
                msg: "unterminated string".into(),
            })?;
            return Ok(Value::Str(inner.to_string()));
        }
        match raw {
            "true" => return Ok(Value::Bool(true)),
            "false" => return Ok(Value::Bool(false)),
            _ => {}
        }
        let cleaned = raw.replace('_', "");
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = cleaned.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        // bare words act as strings (lenient; also covers enum-ish
        // values and filesystem paths — `store.persist_dir=/var/ocf`
        // must work as a --set override without shell-quoted quotes)
        if raw
            .chars()
            .all(|c| c.is_alphanumeric() || matches!(c, '-' | '_' | '/' | '.' | '~'))
        {
            return Ok(Value::Str(raw.to_string()));
        }
        Err(ConfigError::Parse {
            line,
            msg: format!("cannot parse value '{raw}'"),
        })
    }
}

/// Parsed config: section → key → value.
#[derive(Debug, Clone, Default)]
pub struct ConfigTree {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl ConfigTree {
    /// Parse file text.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut tree = ConfigTree::default();
        let mut section = String::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match raw_line.find('#') {
                Some(p) if !raw_line[..p].contains('"') => &raw_line[..p],
                _ => raw_line,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner.strip_suffix(']').ok_or(ConfigError::Parse {
                    line: line_no,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                tree.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or(ConfigError::Parse {
                line: line_no,
                msg: format!("expected key = value, got '{line}'"),
            })?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(ConfigError::Parse {
                    line: line_no,
                    msg: "empty key".into(),
                });
            }
            let value = Value::parse(value, line_no)?;
            tree.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(tree)
    }

    /// Apply a `section.key=value` override string.
    pub fn apply_override(&mut self, spec: &str) -> Result<(), ConfigError> {
        let (path, raw) = spec.split_once('=').ok_or_else(|| {
            ConfigError::Invalid(format!("override '{spec}' must be section.key=value"))
        })?;
        let (section, key) = path.split_once('.').ok_or_else(|| {
            ConfigError::Invalid(format!("override path '{path}' must be section.key"))
        })?;
        let value = Value::parse(raw, 0)?;
        self.sections
            .entry(section.trim().to_string())
            .or_default()
            .insert(key.trim().to_string(), value);
        Ok(())
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Str(s)) => Ok(Some(s.clone())),
            Some(v) => Err(ConfigError::Type(format!(
                "{section}.{key}: expected string, got {v:?}"
            ))),
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Result<Option<i64>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Int(i)) => Ok(Some(*i)),
            Some(v) => Err(ConfigError::Type(format!(
                "{section}.{key}: expected integer, got {v:?}"
            ))),
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Result<Option<f64>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Float(f)) => Ok(Some(*f)),
            Some(Value::Int(i)) => Ok(Some(*i as f64)), // ints widen
            Some(v) => Err(ConfigError::Type(format!(
                "{section}.{key}: expected float, got {v:?}"
            ))),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(Value::Bool(b)) => Ok(Some(*b)),
            Some(v) => Err(ConfigError::Type(format!(
                "{section}.{key}: expected bool, got {v:?}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let t = ConfigTree::parse(
            "[s]\na = 1\nb = 2.5\nc = \"hi\"\nd = true\ne = 1_000_000\nf = bare-word\n",
        )
        .unwrap();
        assert_eq!(t.get_int("s", "a").unwrap(), Some(1));
        assert_eq!(t.get_float("s", "b").unwrap(), Some(2.5));
        assert_eq!(t.get_str("s", "c").unwrap(), Some("hi".into()));
        assert_eq!(t.get_bool("s", "d").unwrap(), Some(true));
        assert_eq!(t.get_int("s", "e").unwrap(), Some(1_000_000));
        assert_eq!(t.get_str("s", "f").unwrap(), Some("bare-word".into()));
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let t = ConfigTree::parse("# top\n\n[s]\n a = 1  # trailing\n").unwrap();
        assert_eq!(t.get_int("s", "a").unwrap(), Some(1));
    }

    #[test]
    fn keys_before_any_section_live_in_root() {
        let t = ConfigTree::parse("x = 5\n[s]\ny = 6\n").unwrap();
        assert_eq!(t.get_int("", "x").unwrap(), Some(5));
        assert_eq!(t.get_int("s", "y").unwrap(), Some(6));
    }

    #[test]
    fn type_errors_reported() {
        let t = ConfigTree::parse("[s]\na = \"text\"\n").unwrap();
        assert!(t.get_int("s", "a").is_err());
        assert!(t.get_bool("s", "a").is_err());
    }

    #[test]
    fn int_widens_to_float() {
        let t = ConfigTree::parse("[s]\na = 3\n").unwrap();
        assert_eq!(t.get_float("s", "a").unwrap(), Some(3.0));
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        let err = ConfigTree::parse("[s]\nnot-a-kv\n").unwrap_err();
        match err {
            ConfigError::Parse { line, .. } => assert_eq!(line, 2),
            other => panic!("{other:?}"),
        }
        let err = ConfigTree::parse("[unterminated\n").unwrap_err();
        assert!(matches!(err, ConfigError::Parse { line: 1, .. }));
    }

    #[test]
    fn overrides_create_and_replace() {
        let mut t = ConfigTree::parse("[s]\na = 1\n").unwrap();
        t.apply_override("s.a=2").unwrap();
        t.apply_override("new.k=3.5").unwrap();
        assert_eq!(t.get_int("s", "a").unwrap(), Some(2));
        assert_eq!(t.get_float("new", "k").unwrap(), Some(3.5));
        assert!(t.apply_override("malformed").is_err());
        assert!(t.apply_override("nodots=1").is_err());
        // bare paths parse as strings (persist_dir overrides)
        t.apply_override("store.persist_dir=/tmp/ocf.d").unwrap();
        assert_eq!(
            t.get_str("store", "persist_dir").unwrap().as_deref(),
            Some("/tmp/ocf.d")
        );
    }

    #[test]
    fn missing_returns_none() {
        let t = ConfigTree::parse("").unwrap();
        assert_eq!(t.get_int("a", "b").unwrap(), None);
    }
}

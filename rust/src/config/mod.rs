//! Configuration: a TOML-subset file format + CLI overrides.
//!
//! The offline build has no `serde`/`toml`, so [`parser`] implements
//! the subset real deployments need: `[section]` headers, `key = value`
//! with string/int/float/bool values, comments. [`OcfFileConfig`] maps
//! the parsed tree onto the typed configs of the filter, store and
//! pipeline layers; every field has a default so a partial file (or no
//! file) works. CLI `--set section.key=value` overrides come last.
//!
//! The `[filter]` section assembles a [`FilterBuilder`] — including
//! `backend = "ocf-eof" | "sharded" | "bloom" | ...` and `shards = N` —
//! so config files and the CLI select any filter backend by name; the
//! builder's validation runs at load time and surfaces as a
//! [`ConfigError`] instead of a construction panic later.

pub mod parser;

pub use parser::{ConfigError, ConfigTree, Value};

use crate::cluster::{Consistency, ReplicationConfig, ResilienceConfig};
use crate::filter::{FilterBackend, FilterBuilder, Mode};
use crate::pipeline::PoolConfig;
use crate::store::{FlushPolicy, FsyncPolicy, NodeConfig};

/// Typed application config assembled from file + overrides.
#[derive(Debug, Clone)]
pub struct OcfFileConfig {
    /// Filter construction surface (backend by name, capacity, mode
    /// bands, shards, bloom fpr — see [`FilterBuilder`]).
    pub filter: FilterBuilder,
    pub node: NodeConfig,
    /// Cluster shape.
    pub nodes: usize,
    pub vnodes: usize,
    pub rf: usize,
    /// Read/write consistency levels (`one` | `quorum` | `all`).
    pub read_consistency: Consistency,
    pub write_consistency: Consistency,
    /// Replica fault handling: retry budget, op timeout, circuit
    /// breaker thresholds, hinted-handoff capacity.
    pub resilience: ResilienceConfig,
    /// Pipeline shape.
    pub batch_size: usize,
    pub queue_depth: usize,
    /// Worker threads of the pooled ingest engine (`0` = auto).
    pub workers: usize,
    /// Task grain (ops) of the pooled engine's chunk-parallel dispatch.
    pub chunk_size: usize,
    /// Artifacts directory for the PJRT runtime.
    pub artifacts_dir: String,
}

impl Default for OcfFileConfig {
    fn default() -> Self {
        Self {
            filter: FilterBuilder::default(),
            node: NodeConfig::default(),
            nodes: 3,
            vnodes: 64,
            rf: 1,
            read_consistency: Consistency::One,
            write_consistency: Consistency::Quorum,
            resilience: ResilienceConfig::default(),
            batch_size: 1024,
            queue_depth: 64,
            workers: 0,
            chunk_size: 1024,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl OcfFileConfig {
    /// Build from a parsed tree (missing keys keep defaults).
    pub fn from_tree(tree: &ConfigTree) -> Result<Self, ConfigError> {
        let mut cfg = Self::default();

        if let Some(backend) = tree.get_str("filter", "backend")? {
            cfg.filter
                .set_backend(&backend)
                .map_err(|e| ConfigError::Invalid(e.to_string()))?;
        }
        if let Some(mode) = tree.get_str("filter", "mode")? {
            cfg.filter.ocf.mode = match mode.as_str() {
                "pre" => Mode::Pre,
                "eof" => Mode::Eof,
                "static" => Mode::Static,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "filter.mode must be pre|eof|static, got '{other}'"
                    )))
                }
            };
        }
        if let Some(v) = tree.get_int("filter", "initial_capacity")? {
            cfg.filter.ocf.initial_capacity = v as usize;
        }
        if let Some(v) = tree.get_int("filter", "fp_bits")? {
            cfg.filter.ocf.fp_bits = v as u32;
        }
        if let Some(v) = tree.get_int("filter", "max_displacements")? {
            cfg.filter.ocf.max_displacements = v as u32;
        }
        if let Some(v) = tree.get_int("filter", "seed")? {
            cfg.filter.ocf.seed = v as u64;
        }
        if let Some(v) = tree.get_float("filter", "o_min")? {
            cfg.filter.ocf.o_min = v;
        }
        if let Some(v) = tree.get_float("filter", "o_max")? {
            cfg.filter.ocf.o_max = v;
        }
        if let Some(v) = tree.get_float("filter", "k_min")? {
            cfg.filter.ocf.k_min = v;
        }
        if let Some(v) = tree.get_float("filter", "k_max")? {
            cfg.filter.ocf.k_max = v;
        }
        if let Some(v) = tree.get_float("filter", "g")? {
            cfg.filter.ocf.g = v;
        }
        if let Some(v) = tree.get_int("filter", "min_capacity")? {
            cfg.filter.ocf.min_capacity = v as usize;
        }
        if let Some(v) = tree.get_int("filter", "max_capacity")? {
            cfg.filter.ocf.max_capacity = Some(v as usize);
        }
        if let Some(v) = tree.get_bool("filter", "verify_deletes")? {
            cfg.filter.ocf.verify_deletes = v;
        }
        if let Some(v) = tree.get_int("filter", "shards")? {
            cfg.filter.shards = v as usize;
        }
        if let Some(v) = tree.get_float("filter", "bloom_fpr")? {
            cfg.filter.bloom_fpr = v;
        }
        if let Some(v) = tree.get_int("filter", "ext_bits")? {
            cfg.filter.ext_bits = v as u32;
        }
        if let Some(v) = tree.get_bool("filter", "adaptive")? {
            // `adaptive = true` upgrades an OCF-family backend to its
            // adaptive twin, keeping mode/shards/capacity knobs — the
            // orthogonal spelling of `backend = "adaptive"`.
            if v {
                cfg.filter.backend = match cfg.filter.backend {
                    FilterBackend::Ocf | FilterBackend::Adaptive => FilterBackend::Adaptive,
                    FilterBackend::AdaptivePacked => FilterBackend::AdaptivePacked,
                    other => {
                        return Err(ConfigError::Invalid(format!(
                            "filter.adaptive = true requires an OCF-family backend \
                             (feedback needs the authoritative key store), got '{}'",
                            other.as_str()
                        )))
                    }
                };
            }
        }

        if let Some(v) = tree.get_int("store", "max_memtable_keys")? {
            cfg.node.flush.max_memtable_keys = v as usize;
        }
        if let Some(v) = tree.get_int("store", "max_memtable_bytes")? {
            cfg.node.flush.max_memtable_bytes = v as usize;
        }
        if let Some(v) = tree.get_float("store", "filter_pressure")? {
            cfg.node.flush = FlushPolicy {
                filter_pressure: Some(v),
                ..cfg.node.flush
            };
        }
        if let Some(v) = tree.get_int("store", "max_sstables")? {
            cfg.node.compaction.max_tables = v as usize;
        }
        if let Some(v) = tree.get_str("store", "persist_dir")? {
            if v.is_empty() {
                return Err(ConfigError::Invalid(
                    "store.persist_dir must not be empty".to_string(),
                ));
            }
            cfg.node.persist_dir = Some(v);
        }
        if let Some(v) = tree.get_bool("store", "wal")? {
            cfg.node.wal.enabled = v;
        }
        let fsync_every = match tree.get_int("store", "fsync_every")? {
            Some(v) => {
                if v < 1 {
                    return Err(ConfigError::Invalid(format!(
                        "store.fsync_every must be >= 1, got {v}"
                    )));
                }
                v as u32
            }
            None => 32,
        };
        if let Some(v) = tree.get_str("store", "fsync")? {
            cfg.node.wal.fsync = match v.as_str() {
                "always" => FsyncPolicy::Always,
                "every_n" => FsyncPolicy::EveryN(fsync_every),
                "os" => FsyncPolicy::Os,
                other => {
                    return Err(ConfigError::Invalid(format!(
                        "store.fsync must be always|every_n|os, got '{other}'"
                    )))
                }
            };
        }

        if let Some(v) = tree.get_int("cluster", "nodes")? {
            cfg.nodes = v as usize;
        }
        if let Some(v) = tree.get_int("cluster", "vnodes")? {
            cfg.vnodes = v as usize;
        }
        if let Some(v) = tree.get_int("cluster", "rf")? {
            cfg.rf = v as usize;
        }
        if let Some(s) = tree.get_str("cluster", "read_consistency")? {
            cfg.read_consistency = Consistency::parse(&s).ok_or_else(|| {
                ConfigError::Invalid(format!(
                    "cluster.read_consistency must be one|quorum|all, got '{s}'"
                ))
            })?;
        }
        if let Some(s) = tree.get_str("cluster", "write_consistency")? {
            cfg.write_consistency = Consistency::parse(&s).ok_or_else(|| {
                ConfigError::Invalid(format!(
                    "cluster.write_consistency must be one|quorum|all, got '{s}'"
                ))
            })?;
        }
        if let Some(v) = tree.get_int("cluster", "retry_budget")? {
            if !(0..=16).contains(&v) {
                return Err(ConfigError::Invalid(format!(
                    "cluster.retry_budget must be 0..=16, got {v}"
                )));
            }
            cfg.resilience.retry_budget = v as u32;
        }
        if let Some(v) = tree.get_int("cluster", "timeout_us")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "cluster.timeout_us must be >= 1, got {v}"
                )));
            }
            cfg.resilience.timeout_us = v as u64;
        }
        if let Some(v) = tree.get_int("cluster", "breaker_threshold")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "cluster.breaker_threshold must be >= 1, got {v}"
                )));
            }
            cfg.resilience.breaker.threshold = v as u32;
        }
        if let Some(v) = tree.get_int("cluster", "breaker_cooldown")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "cluster.breaker_cooldown must be >= 1 op-tick, got {v}"
                )));
            }
            cfg.resilience.breaker.cooldown = v as u64;
        }
        if let Some(v) = tree.get_int("cluster", "breaker_probes")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "cluster.breaker_probes must be >= 1, got {v}"
                )));
            }
            cfg.resilience.breaker.probes = v as u32;
        }
        if let Some(v) = tree.get_int("cluster", "handoff_capacity")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "cluster.handoff_capacity must be >= 1, got {v}"
                )));
            }
            cfg.resilience.handoff_capacity = v as usize;
        }
        if let Some(v) = tree.get_int("cluster", "transfer_batch")? {
            if !(1..=65536).contains(&v) {
                return Err(ConfigError::Invalid(format!(
                    "cluster.transfer_batch must be 1..=65536, got {v}"
                )));
            }
            cfg.resilience.transfer_batch = v as usize;
        }

        if let Some(v) = tree.get_int("pipeline", "batch_size")? {
            cfg.batch_size = v as usize;
        }
        if let Some(v) = tree.get_int("pipeline", "queue_depth")? {
            if !(1..=65536).contains(&v) {
                return Err(ConfigError::Invalid(format!(
                    "pipeline.queue_depth must be 1..=65536, got {v}"
                )));
            }
            cfg.queue_depth = v as usize;
        }
        if let Some(v) = tree.get_int("pipeline", "workers")? {
            if !(0..=4096).contains(&v) {
                return Err(ConfigError::Invalid(format!(
                    "pipeline.workers must be 0 (auto) ..= 4096, got {v}"
                )));
            }
            cfg.workers = v as usize;
        }
        if let Some(v) = tree.get_int("pipeline", "chunk_size")? {
            if v < 1 {
                return Err(ConfigError::Invalid(format!(
                    "pipeline.chunk_size must be >= 1, got {v}"
                )));
            }
            cfg.chunk_size = v as usize;
        }
        if let Some(v) = tree.get_str("runtime", "artifacts_dir")? {
            cfg.artifacts_dir = v;
        }

        // One validation pass for the whole knob combination (range
        // checks for shards/fp_bits/bands live in the builder).
        cfg.filter
            .validate()
            .map_err(|e| ConfigError::Invalid(e.to_string()))?;
        cfg.node.filter = cfg.filter.clone();
        Ok(cfg)
    }

    /// Parse file text + apply `section.key=value` CLI overrides.
    pub fn load(text: &str, overrides: &[String]) -> Result<Self, ConfigError> {
        let mut tree = ConfigTree::parse(text)?;
        for ov in overrides {
            tree.apply_override(ov)?;
        }
        Self::from_tree(&tree)
    }

    /// The pooled ingest engine's shape assembled from the `[pipeline]`
    /// section (`workers` / `queue_depth` / `chunk_size`).
    pub fn pool(&self) -> PoolConfig {
        PoolConfig {
            workers: self.workers,
            queue_depth: self.queue_depth,
            chunk: self.chunk_size,
        }
    }

    /// Replication policy assembled from the `[cluster]` section
    /// (`rf` / `read_consistency` / `write_consistency`).
    pub fn replication(&self) -> ReplicationConfig {
        ReplicationConfig {
            rf: self.rf,
            read_consistency: self.read_consistency,
            write_consistency: self.write_consistency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{FilterBackend, MembershipFilter};

    #[test]
    fn defaults_without_file() {
        let cfg = OcfFileConfig::load("", &[]).unwrap();
        assert_eq!(cfg.nodes, 3);
        assert_eq!(cfg.filter.ocf.mode, Mode::Eof);
        assert_eq!(cfg.filter.backend, FilterBackend::Ocf);
    }

    #[test]
    fn full_file_parses() {
        let text = r#"
# OCF config
[filter]
mode = "pre"
initial_capacity = 8192
fp_bits = 12
o_min = 0.25
o_max = 0.8
verify_deletes = false

[store]
max_memtable_keys = 5000
filter_pressure = 0.8
persist_dir = "/tmp/ocf-data"

[cluster]
nodes = 5
rf = 3

[pipeline]
batch_size = 4096
"#;
        let cfg = OcfFileConfig::load(text, &[]).unwrap();
        assert_eq!(cfg.filter.ocf.mode, Mode::Pre);
        assert_eq!(cfg.filter.ocf.initial_capacity, 8192);
        assert_eq!(cfg.filter.ocf.fp_bits, 12);
        assert!(!cfg.filter.ocf.verify_deletes);
        assert_eq!(cfg.node.flush.max_memtable_keys, 5000);
        assert_eq!(cfg.node.flush.filter_pressure, Some(0.8));
        assert_eq!(cfg.nodes, 5);
        assert_eq!(cfg.rf, 3);
        assert_eq!(cfg.batch_size, 4096);
        // node filter config mirrors the filter section
        assert_eq!(cfg.node.filter.ocf.fp_bits, 12);
        assert_eq!(cfg.node.filter.describe(), "ocf-pre");
        assert_eq!(cfg.node.persist_dir.as_deref(), Some("/tmp/ocf-data"));
    }

    #[test]
    fn persist_dir_defaults_off_and_rejects_empty() {
        let cfg = OcfFileConfig::load("", &[]).unwrap();
        assert_eq!(cfg.node.persist_dir, None, "persistence is opt-in");
        assert!(OcfFileConfig::load("[store]\npersist_dir = \"\"\n", &[]).is_err());
        // settable through --set overrides like every other knob
        let cfg =
            OcfFileConfig::load("", &["store.persist_dir=/tmp/ocf-x".into()]).unwrap();
        assert_eq!(cfg.node.persist_dir.as_deref(), Some("/tmp/ocf-x"));
    }

    #[test]
    fn wal_knobs_parse_and_validate() {
        let cfg = OcfFileConfig::load("", &[]).unwrap();
        assert!(cfg.node.wal.enabled, "WAL defaults on");
        assert_eq!(cfg.node.wal.fsync, FsyncPolicy::Always, "strictest default");

        let text = "[store]\nwal = false\n";
        let cfg = OcfFileConfig::load(text, &[]).unwrap();
        assert!(!cfg.node.wal.enabled);

        let text = "[store]\nfsync = \"every_n\"\nfsync_every = 128\n";
        let cfg = OcfFileConfig::load(text, &[]).unwrap();
        assert_eq!(cfg.node.wal.fsync, FsyncPolicy::EveryN(128));

        // every_n without fsync_every takes the documented default
        let cfg = OcfFileConfig::load("[store]\nfsync = \"every_n\"\n", &[]).unwrap();
        assert_eq!(cfg.node.wal.fsync, FsyncPolicy::EveryN(32));

        let cfg = OcfFileConfig::load("[store]\nfsync = \"os\"\n", &[]).unwrap();
        assert_eq!(cfg.node.wal.fsync, FsyncPolicy::Os);

        // --set overrides hit the same keys
        let cfg = OcfFileConfig::load("", &["store.fsync=os".into(), "store.wal=false".into()])
            .unwrap();
        assert_eq!(cfg.node.wal.fsync, FsyncPolicy::Os);
        assert!(!cfg.node.wal.enabled);

        assert!(OcfFileConfig::load("[store]\nfsync = \"warp\"\n", &[]).is_err());
        assert!(OcfFileConfig::load("[store]\nfsync_every = 0\n", &[]).is_err());
        assert!(
            OcfFileConfig::load("[store]\nfsync = \"every_n\"\nfsync_every = -4\n", &[]).is_err()
        );
    }

    #[test]
    fn backend_selectable_by_name() {
        let cfg = OcfFileConfig::load("[filter]\nbackend = \"bloom\"\n", &[]).unwrap();
        assert_eq!(cfg.filter.backend, FilterBackend::Bloom);
        assert_eq!(cfg.filter.build().unwrap().name(), "bloom");

        // mode-qualified backend names work through --set overrides too
        let cfg = OcfFileConfig::load("", &["filter.backend=ocf-static".into()]).unwrap();
        assert_eq!(cfg.filter.describe(), "ocf-static");

        let cfg = OcfFileConfig::load("[filter]\nbackend = \"sharded\"\nshards = 8\n", &[])
            .unwrap();
        assert_eq!(cfg.filter.describe(), "sharded-ocf");
        assert_eq!(cfg.filter.shards, 8);

        assert!(OcfFileConfig::load("[filter]\nbackend = \"warp\"\n", &[]).is_err());
        // bloom cannot shard — builder validation surfaces at load time
        assert!(
            OcfFileConfig::load("[filter]\nbackend = \"bloom\"\nshards = 4\n", &[]).is_err()
        );
    }

    #[test]
    fn adaptive_knobs_parse() {
        // by backend name
        let cfg = OcfFileConfig::load("[filter]\nbackend = \"adaptive\"\n", &[]).unwrap();
        assert_eq!(cfg.filter.backend, FilterBackend::Adaptive);
        assert_eq!(cfg.filter.build().unwrap().name(), "adaptive-ocf");

        // by the orthogonal bool, composing with shards
        let cfg = OcfFileConfig::load("[filter]\nadaptive = true\nshards = 4\n", &[]).unwrap();
        assert_eq!(cfg.filter.describe(), "sharded-adaptive-ocf");

        // adaptive = false is a no-op
        let cfg = OcfFileConfig::load("[filter]\nadaptive = false\n", &[]).unwrap();
        assert_eq!(cfg.filter.backend, FilterBackend::Ocf);

        // ext_bits flows to the builder; bad widths rejected at load
        let cfg = OcfFileConfig::load("[filter]\nadaptive = true\next_bits = 12\n", &[])
            .unwrap();
        assert_eq!(cfg.filter.ext_bits, 12);
        assert!(OcfFileConfig::load("[filter]\next_bits = 0\n", &[]).is_err());
        assert!(OcfFileConfig::load("[filter]\next_bits = 17\n", &[]).is_err());

        // --set override spelling
        let cfg = OcfFileConfig::load("", &["filter.backend=adaptive".into()]).unwrap();
        assert_eq!(cfg.filter.describe(), "adaptive-ocf");

        // non-OCF backends cannot adapt
        assert!(
            OcfFileConfig::load("[filter]\nbackend = \"bloom\"\nadaptive = true\n", &[])
                .is_err()
        );
    }

    #[test]
    fn filter_shards_opt_in() {
        let cfg = OcfFileConfig::load("", &[]).unwrap();
        assert_eq!(cfg.node.filter.shards, 1, "sharding is opt-in");
        let cfg = OcfFileConfig::load("[filter]\nshards = 8\n", &[]).unwrap();
        assert_eq!(cfg.node.filter.shards, 8);
        let cfg = OcfFileConfig::load("", &["filter.shards=4".into()]).unwrap();
        assert_eq!(cfg.node.filter.shards, 4);
        assert!(OcfFileConfig::load("[filter]\nshards = 0\n", &[]).is_err());
        assert!(OcfFileConfig::load("[filter]\nshards = 1000000000\n", &[]).is_err());
    }

    #[test]
    fn pipeline_pool_knobs_parse_and_validate() {
        let cfg = OcfFileConfig::load("", &[]).unwrap();
        assert_eq!(cfg.workers, 0, "pooled workers default to auto");
        assert_eq!(cfg.chunk_size, 1024);
        assert!(cfg.pool().effective_workers() >= 1);

        let text = "[pipeline]\nworkers = 6\nqueue_depth = 8\nchunk_size = 256\n";
        let cfg = OcfFileConfig::load(text, &[]).unwrap();
        let pool = cfg.pool();
        assert_eq!(pool.workers, 6);
        assert_eq!(pool.queue_depth, 8);
        assert_eq!(pool.chunk, 256);

        // serve-style --set overrides hit the same keys
        let cfg = OcfFileConfig::load("", &["pipeline.workers=3".into()]).unwrap();
        assert_eq!(cfg.pool().effective_workers(), 3);

        assert!(OcfFileConfig::load("[pipeline]\nworkers = 5000\n", &[]).is_err());
        assert!(OcfFileConfig::load("[pipeline]\nchunk_size = 0\n", &[]).is_err());
        // a negative/zero queue depth must not wrap into an unbounded
        // backpressure window
        assert!(OcfFileConfig::load("[pipeline]\nqueue_depth = 0\n", &[]).is_err());
        assert!(OcfFileConfig::load("[pipeline]\nqueue_depth = -1\n", &[]).is_err());
    }

    #[test]
    fn cli_overrides_win() {
        let text = "[cluster]\nnodes = 2\n";
        let cfg =
            OcfFileConfig::load(text, &["cluster.nodes=7".into(), "filter.mode=static".into()])
                .unwrap();
        assert_eq!(cfg.nodes, 7);
        assert_eq!(cfg.filter.ocf.mode, Mode::Static);
    }

    #[test]
    fn cluster_resilience_knobs_parse_and_validate() {
        let text = r#"
[cluster]
nodes = 5
rf = 3
read_consistency = "quorum"
write_consistency = "all"
retry_budget = 5
timeout_us = 750
breaker_threshold = 4
breaker_cooldown = 128
breaker_probes = 3
handoff_capacity = 512
transfer_batch = 128
"#;
        let cfg = OcfFileConfig::load(text, &[]).unwrap();
        assert_eq!(cfg.read_consistency, Consistency::Quorum);
        assert_eq!(cfg.write_consistency, Consistency::All);
        assert_eq!(cfg.resilience.retry_budget, 5);
        assert_eq!(cfg.resilience.timeout_us, 750);
        assert_eq!(cfg.resilience.breaker.threshold, 4);
        assert_eq!(cfg.resilience.breaker.cooldown, 128);
        assert_eq!(cfg.resilience.breaker.probes, 3);
        assert_eq!(cfg.resilience.handoff_capacity, 512);
        assert_eq!(cfg.resilience.transfer_batch, 128);
        let repl = cfg.replication();
        assert_eq!(repl.rf, 3);
        assert_eq!(repl.write_consistency.required(repl.rf), 3);

        // defaults when the section is silent
        let d = OcfFileConfig::load("", &[]).unwrap();
        assert_eq!(d.read_consistency, Consistency::One);
        assert_eq!(d.write_consistency, Consistency::Quorum);
        assert_eq!(d.resilience.retry_budget, 3);

        // range/spelling validation is loud
        for bad in [
            "[cluster]\nread_consistency = \"two\"\n",
            "[cluster]\nretry_budget = 17\n",
            "[cluster]\ntimeout_us = 0\n",
            "[cluster]\nbreaker_threshold = 0\n",
            "[cluster]\nbreaker_cooldown = 0\n",
            "[cluster]\nbreaker_probes = 0\n",
            "[cluster]\nhandoff_capacity = 0\n",
            "[cluster]\ntransfer_batch = 0\n",
        ] {
            assert!(OcfFileConfig::load(bad, &[]).is_err(), "{bad}");
        }
    }

    #[test]
    fn bad_mode_rejected() {
        assert!(OcfFileConfig::load("[filter]\nmode = \"warp\"\n", &[]).is_err());
    }

    #[test]
    fn invalid_band_rejected_at_load() {
        assert!(OcfFileConfig::load("[filter]\no_min = 0.9\no_max = 0.5\n", &[]).is_err());
        assert!(OcfFileConfig::load("[filter]\nfp_bits = 40\n", &[]).is_err());
    }
}

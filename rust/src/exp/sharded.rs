//! E9 — shard-scaling throughput of the concurrent OCF front-end.
//!
//! Measures aggregate insert+lookup+delete throughput of
//! [`ShardedOcf`](crate::filter::ShardedOcf) at 1/2/4/8 shards under
//! the burst workload generator (square-wave insert/delete storms —
//! the paper's §I "sudden changes in traffic"), driven by a fixed pool
//! of writer threads using the batched APIs. One shard serializes the
//! pool on a single lock stripe; N shards let disjoint groups proceed
//! concurrently, so throughput should scale until memory bandwidth or
//! core count binds (the Cuckoo-GPU partitioning argument on CPU).

use super::report::{f, Table};
use super::Scale;
use crate::filter::{OcfConfig, ShardedOcf};
use crate::workload::{BurstGenerator, Op};
use std::time::Instant;

/// One measured scaling point.
#[derive(Debug, Clone, Copy)]
pub struct ScalingRow {
    pub shards: usize,
    pub threads: usize,
    pub ops: u64,
    pub secs: f64,
}

impl ScalingRow {
    pub fn ops_per_sec(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.ops as f64 / self.secs
        }
    }
}

/// Drive one arm: `threads` workers, each feeding its own burst stream
/// over a disjoint key range into the shared filter via the batched
/// APIs (`batch` ops per call, split by op kind).
pub fn run_arm(shards: usize, threads: usize, ops_per_thread: usize, batch: usize) -> ScalingRow {
    let filter = ShardedOcf::with_shards(
        shards,
        OcfConfig {
            initial_capacity: 1 << 16,
            ..OcfConfig::default()
        },
    );
    let start = Instant::now();
    std::thread::scope(|s| {
        for t in 0..threads {
            let filter = &filter;
            s.spawn(move || {
                // disjoint key ranges: contention is purely on the
                // filter's lock stripes, never on key ownership
                let base = (t as u64 + 1) << 40;
                let mut gen =
                    BurstGenerator::square_wave(batch.max(1024) * 4, 1 << 22, 0xB007 + t as u64);
                let mut inserts = Vec::with_capacity(batch);
                let mut lookups = Vec::with_capacity(batch);
                let mut deletes = Vec::with_capacity(batch);
                let mut done = 0usize;
                while done < ops_per_thread {
                    inserts.clear();
                    lookups.clear();
                    deletes.clear();
                    let take = batch.min(ops_per_thread - done);
                    for _ in 0..take {
                        match gen.next_op() {
                            Some(Op::Insert(k)) => inserts.push(base | k),
                            Some(Op::Lookup(k)) => lookups.push(base | k),
                            Some(Op::Delete(k)) => deletes.push(base | k),
                            None => break,
                        }
                    }
                    if !inserts.is_empty() {
                        for r in filter.insert_batch(&inserts) {
                            let _ = r;
                        }
                    }
                    if !lookups.is_empty() {
                        std::hint::black_box(filter.contains_batch(&lookups));
                    }
                    if !deletes.is_empty() {
                        std::hint::black_box(filter.delete_batch(&deletes));
                    }
                    done += take;
                }
            });
        }
    });
    ScalingRow {
        shards,
        threads,
        ops: (threads * ops_per_thread) as u64,
        secs: start.elapsed().as_secs_f64(),
    }
}

/// Measure the scaling curve across `shard_counts`.
pub fn scaling_curve(
    shard_counts: &[usize],
    threads: usize,
    ops_per_thread: usize,
    batch: usize,
) -> Vec<ScalingRow> {
    shard_counts
        .iter()
        .map(|&n| run_arm(n, threads, ops_per_thread, batch))
        .collect()
}

/// Default thread pool: 8, capped by the machine's parallelism.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8)
        .max(2)
}

/// The experiment driver: markdown report over 1/2/4/8 shards.
pub fn run(scale: Scale) -> String {
    let threads = default_threads();
    let ops_per_thread = scale.n(400_000, 10_000);
    let batch = 1024;
    let rows = scaling_curve(&[1, 2, 4, 8], threads, ops_per_thread, batch);
    let base = rows[0].ops_per_sec();
    let mut table = Table::new(
        format!("E9 — sharded OCF scaling ({threads} threads, burst workload)"),
        &["shards", "threads", "ops", "secs", "Mops/s", "speedup"],
    );
    for r in &rows {
        let speedup = if base > 0.0 { r.ops_per_sec() / base } else { 0.0 };
        table.row(&[
            r.shards.to_string(),
            r.threads.to_string(),
            r.ops.to_string(),
            f(r.secs, 3),
            f(r.ops_per_sec() / 1e6, 2),
            format!("{}x", f(speedup, 2)),
        ]);
    }
    table.note(
        "one shard serializes the thread pool on a single lock stripe; \
         N shards let disjoint batch groups proceed concurrently",
    );
    table.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arm_runs_and_counts() {
        let r = run_arm(4, 2, 5_000, 512);
        assert_eq!(r.shards, 4);
        assert_eq!(r.ops, 10_000);
        assert!(r.secs > 0.0);
        assert!(r.ops_per_sec() > 0.0);
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.01));
        assert!(md.contains("E9"));
        assert!(md.contains("| 4 |"));
    }
}

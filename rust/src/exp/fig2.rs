//! E2 — Fig 2: throughput of EOF, PRE and the traditional cuckoo
//! filter over insert trials.
//!
//! Protocol (reconstructed): a *trial* is a fixed batch of inserts plus
//! background lookups. The traditional filter has fixed capacity and
//! "gets completely filled within first few trials"; EOF and PRE keep
//! absorbing inserts. We record per-trial achieved throughput and
//! accepted-insert counts, sampling rows for the report.
//!
//! Expected shape: traditional collapses to ~0 accepted inserts once
//! full; PRE and EOF sustain; PRE's capacity staircase overshoots
//! ("PRE gets exponentially larger therefore consuming more space");
//! EOF tracks demand.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{MembershipFilter, Mode, Ocf, OcfConfig};
use std::time::Instant;

const FULL_TRIALS: usize = 2_500;
const INSERTS_PER_TRIAL: usize = 400;
const LOOKUPS_PER_TRIAL: usize = 100;

/// Per-trial sample for one arm.
#[derive(Debug, Clone)]
pub struct TrialSample {
    pub trial: usize,
    pub ops_per_sec: f64,
    pub accepted: usize,
    pub capacity: usize,
    pub memory_bytes: usize,
}

/// Drive one arm for `trials`; returns sampled rows (every `stride`).
pub fn run_arm(mode: Mode, trials: usize, stride: usize, seed: u64) -> Vec<TrialSample> {
    // traditional arm = Static mode with the paper's "capacity for the
    // expected first chunk" — it will saturate partway through.
    let initial_capacity = match mode {
        Mode::Static => (trials * INSERTS_PER_TRIAL / 8).next_power_of_two(),
        _ => 4096,
    };
    let mut filter = Ocf::new(OcfConfig {
        mode,
        initial_capacity,
        seed,
        ..OcfConfig::default()
    });
    let mut samples = Vec::new();
    let mut next_key = 0u64;
    for trial in 0..trials {
        let t0 = Instant::now();
        let mut accepted = 0;
        for _ in 0..INSERTS_PER_TRIAL {
            if filter.insert(next_key).is_ok() {
                accepted += 1;
            }
            next_key += 1;
        }
        let mut _hits = 0u64;
        for i in 0..LOOKUPS_PER_TRIAL as u64 {
            if filter.contains(next_key.wrapping_sub(i + 1)) {
                _hits += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        if trial % stride == 0 || trial == trials - 1 {
            samples.push(TrialSample {
                trial,
                ops_per_sec: (INSERTS_PER_TRIAL + LOOKUPS_PER_TRIAL) as f64 / dt,
                accepted,
                capacity: filter.capacity(),
                memory_bytes: filter.memory_bytes(),
            });
        }
    }
    samples
}

/// Full experiment.
pub fn run(scale: Scale) -> String {
    let trials = scale.n(FULL_TRIALS, 60);
    let stride = (trials / 12).max(1);
    let eof = run_arm(Mode::Eof, trials, stride, 0xF16_2);
    let pre = run_arm(Mode::Pre, trials, stride, 0xF16_2);
    let trad = run_arm(Mode::Static, trials, stride, 0xF16_2);

    let mut t = Table::new(
        format!(
            "E2 / Fig 2 — per-trial throughput ({INSERTS_PER_TRIAL} inserts + {LOOKUPS_PER_TRIAL} lookups per trial, {trials} trials)"
        ),
        &[
            "Trial",
            "EOF Kops/s",
            "PRE Kops/s",
            "Trad Kops/s",
            "EOF accepted",
            "PRE accepted",
            "Trad accepted",
        ],
    );
    for i in 0..eof.len() {
        t.row(&[
            eof[i].trial.to_string(),
            f(eof[i].ops_per_sec / 1e3, 0),
            f(pre[i].ops_per_sec / 1e3, 0),
            f(trad[i].ops_per_sec / 1e3, 0),
            eof[i].accepted.to_string(),
            pre[i].accepted.to_string(),
            trad[i].accepted.to_string(),
        ]);
    }
    let trad_sat = trad.iter().find(|s| s.accepted == 0).map(|s| s.trial);
    let last = eof.len() - 1;
    t.note(format!(
        "shape check: traditional saturates (0 accepted inserts) {} — paper: \
         'gets completely filled within first few trials'. final memory: \
         EOF {} vs PRE {} (PRE/EOF = {:.2}×, paper: PRE 'consuming more space than necessary').",
        trad_sat
            .map(|t| format!("by trial {t}"))
            .unwrap_or_else(|| "never (increase trials)".into()),
        crate::util::fmt_bytes(eof[last].memory_bytes),
        crate::util::fmt_bytes(pre[last].memory_bytes),
        pre[last].memory_bytes as f64 / eof[last].memory_bytes as f64,
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traditional_saturates_dynamic_arms_dont() {
        let trials = 80;
        let eof = run_arm(Mode::Eof, trials, 1, 3);
        let pre = run_arm(Mode::Pre, trials, 1, 3);
        let trad = run_arm(Mode::Static, trials, 1, 3);
        // traditional: later trials accept ~nothing
        let trad_late: usize = trad[trials - 10..].iter().map(|s| s.accepted).sum();
        assert!(
            trad_late < 10 * INSERTS_PER_TRIAL / 4,
            "traditional must be mostly saturated, accepted {trad_late}"
        );
        // dynamic arms accept everything
        assert!(eof.iter().all(|s| s.accepted == INSERTS_PER_TRIAL));
        assert!(pre.iter().all(|s| s.accepted == INSERTS_PER_TRIAL));
    }

    #[test]
    fn pre_memory_overshoots_eof() {
        let trials = 100;
        let eof = run_arm(Mode::Eof, trials, trials - 1, 3);
        let pre = run_arm(Mode::Pre, trials, trials - 1, 3);
        let (e, p) = (
            eof.last().unwrap().memory_bytes,
            pre.last().unwrap().memory_bytes,
        );
        assert!(p as f64 >= 1.2 * e as f64, "pre={p} eof={e}");
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.03));
        assert!(md.contains("Fig 2"));
        assert!(md.contains("shape check"));
    }
}

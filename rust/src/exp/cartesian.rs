//! E7 — the §I.B cartesian-product query across a 3-node cluster.
//!
//! T and U live on their own nodes; the coordinator generates |T|·|U|
//! probes against V's node. The membership filter on V absorbs the
//! overwhelmingly-absent probe stream; we report per-node lookup
//! counts (the paper's fan-out asymmetry), prune rate, and wallclock
//! with the filter enabled vs disabled (disabled = every probe walks
//! the SSTables).

use super::report::{f, Table};
use super::Scale;
use crate::cluster::{CartesianQuery, Coordinator};
use crate::store::{FlushPolicy, FlushReason, NodeConfig, StorageNode};
use std::time::Instant;

/// Experiment outcome.
#[derive(Debug, Clone)]
pub struct CartesianRow {
    pub pairs: u64,
    pub matches: u64,
    pub pruned: u64,
    pub probed: u64,
    pub elapsed_ms: f64,
}

/// Run the query at given set sizes; `planted` pairs are made to match.
pub fn run_query(t_size: usize, u_size: usize, v_extra: usize, planted: usize) -> CartesianRow {
    let t: Vec<u64> = (0..t_size as u64).collect();
    let u: Vec<u64> = (1000..1000 + u_size as u64).collect();

    let mut v = StorageNode::new(NodeConfig {
        flush: FlushPolicy::small(50_000),
        ..NodeConfig::default()
    });
    // plant matches for the first `planted` (t, u) pairs
    for i in 0..planted.min(t_size).min(u_size) {
        v.put(CartesianQuery::pair_key(t[i], u[i])).unwrap();
    }
    // plus unrelated bulk data (so SSTable probes are non-trivial)
    for k in 0..v_extra as u64 {
        v.put((1 << 50) + k).unwrap();
    }
    v.flush(FlushReason::MemtableKeys);

    let q = CartesianQuery {
        t,
        u,
        probe_key: CartesianQuery::pair_key,
    };
    let t0 = Instant::now();
    let stats = Coordinator::execute(&q, &mut v);
    let elapsed_ms = t0.elapsed().as_secs_f64() * 1e3;
    CartesianRow {
        pairs: stats.pairs_generated,
        matches: stats.matches,
        pruned: stats.v_filter_pruned,
        probed: stats.v_probes,
        elapsed_ms,
    }
}

/// Full experiment.
pub fn run(scale: Scale) -> String {
    let t_size = scale.n(400, 50);
    let u_size = scale.n(400, 50);
    let planted = 25;
    let r = run_query(t_size, u_size, scale.n(50_000, 5_000), planted);

    let mut t = Table::new(
        format!("E7 — cartesian query T×U⋈V (|T|={t_size}, |U|={u_size}, {planted} planted matches)"),
        &[
            "Pairs generated",
            "Matches",
            "Filter-pruned probes",
            "Storage probes",
            "Prune rate",
            "Elapsed ms",
        ],
    );
    t.row(&[
        r.pairs.to_string(),
        r.matches.to_string(),
        r.pruned.to_string(),
        r.probed.to_string(),
        f(r.pruned as f64 / r.pairs as f64, 4),
        f(r.elapsed_ms, 1),
    ]);
    t.note(format!(
        "paper §I.B: the query 'will trigger s = |T|·|U| queries in V'; the \
         node filter absorbed {:.1}% of them before any storage work.",
        100.0 * r.pruned as f64 / r.pairs as f64
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_matches_found_and_pruning_dominant() {
        let r = run_query(100, 100, 2_000, 10);
        assert_eq!(r.pairs, 10_000);
        assert!(r.matches >= 10, "{r:?}");
        assert!(r.matches <= 30, "fp collisions only add a few: {r:?}");
        assert!(
            r.pruned as f64 / r.pairs as f64 > 0.95,
            "pruning must dominate: {r:?}"
        );
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.2));
        assert!(md.contains("E7"));
        assert!(md.contains("Prune"));
    }
}

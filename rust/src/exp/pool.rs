//! E11 — the pooled ingest engine: persistent workers vs scoped
//! fan-out vs a single thread.
//!
//! Drives the SAME op stream (a YCSB-style insert/lookup/delete mix)
//! through four pipeline arms over identically-configured filters:
//!
//! * `single` — [`IngestPipeline::run_concurrent`]: one thread, the
//!   batched `&self` trait surface (the no-parallelism floor);
//! * `scoped` — [`IngestPipeline::run_sharded`]: the PR-1 design, a
//!   fresh `thread::scope` fan-out per batch (thread startup on every
//!   batch, hashing serialized against apply);
//! * `pooled` — [`IngestPipeline::run_pooled`] at several worker
//!   counts: persistent shard workers + staged hash/apply overlap;
//! * `pooled-mutex` — `run_pooled` over a [`MutexFilter`]-wrapped OCF:
//!   the filter-generic chunk dispatch (coarse lock, so this measures
//!   pipeline overlap rather than apply parallelism).
//!
//! The sharded arms must produce **count-identical** reports (asserted
//! here, property-tested as P13) — the speedup is measured against
//! workloads that are provably the same work. `measure()` is shared
//! with `benches/pipeline_pool.rs`, which emits the
//! `BENCH_pipeline.json` trajectory point.
//!
//! [`IngestPipeline::run_concurrent`]: crate::pipeline::IngestPipeline::run_concurrent
//! [`IngestPipeline::run_sharded`]: crate::pipeline::IngestPipeline::run_sharded
//! [`IngestPipeline::run_pooled`]: crate::pipeline::IngestPipeline::run_pooled
//! [`MutexFilter`]: crate::filter::MutexFilter

use super::report::{f, Table};
use super::Scale;
use crate::filter::{MutexFilter, Ocf, OcfConfig, ShardedOcf};
use crate::pipeline::{BatchPolicy, IngestPipeline, IngestReport, PoolConfig};
use crate::runtime::HashExecutor;
use crate::workload::{KeyDist, MixGenerator, Op, OpMix};
use std::time::Duration;

/// Shards of the concurrent front-end in every sharded arm.
pub const SHARDS: usize = 8;
/// Batch size of every arm (one size so the arms are comparable).
pub const BATCH: usize = 4096;

/// One measured arm.
#[derive(Debug, Clone)]
pub struct PoolPoint {
    /// Arm ("single" | "scoped" | "pooled" | "pooled-mutex").
    pub mode: &'static str,
    /// Worker threads applying batches (1 for the serial arm; the
    /// scoped arm peaks at one thread per non-empty shard group).
    pub workers: usize,
    pub ops: u64,
    pub secs: f64,
    pub batches: u64,
    pub inserts: u64,
    pub hits: u64,
    pub deletes: u64,
}

impl PoolPoint {
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.ops as f64 / self.secs / 1e6
        }
    }
}

fn point(mode: &'static str, workers: usize, r: &IngestReport) -> PoolPoint {
    PoolPoint {
        mode,
        workers,
        ops: r.ops,
        secs: r.elapsed_secs,
        batches: r.batches,
        inserts: r.inserts,
        hits: r.lookup_hits,
        deletes: r.deletes,
    }
}

fn gen_ops(n: usize) -> Vec<Op> {
    let mut gen = MixGenerator::new(KeyDist::uniform(1 << 24), OpMix::new(0.5, 0.3, 0.2), 0xE11);
    gen.batch(n)
}

fn sharded() -> ShardedOcf {
    ShardedOcf::with_shards(
        SHARDS,
        OcfConfig {
            initial_capacity: 1 << 16,
            ..OcfConfig::default()
        },
    )
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: BATCH,
        max_delay: Duration::from_millis(5),
    }
}

/// Measure every arm over one shared op stream. Sharded arms are
/// asserted count-identical before any speedup is reported.
pub fn measure(n_ops: usize, worker_counts: &[usize]) -> Vec<PoolPoint> {
    let ops = gen_ops(n_ops);
    let mut out = Vec::with_capacity(worker_counts.len() + 3);

    // single thread, batched &self trait surface
    {
        let filter = sharded();
        let mut p = IngestPipeline::new(policy(), HashExecutor::native(filter.hasher()));
        let r = p.run_concurrent(ops.iter().copied(), &filter);
        out.push(point("single", 1, &r));
    }

    // scoped per-batch fan-out (the pre-pool parallel mode)
    {
        let filter = sharded();
        let mut p = IngestPipeline::new(policy(), HashExecutor::native(filter.hasher()));
        let r = p.run_sharded(ops.iter().copied(), &filter);
        out.push(point("scoped", SHARDS, &r));
    }

    // persistent pool at each worker count
    for &w in worker_counts {
        let filter = sharded();
        let mut p = IngestPipeline::new(policy(), HashExecutor::native(filter.hasher()));
        let cfg = PoolConfig {
            workers: w,
            queue_depth: 4,
            chunk: 2048,
        };
        let r = p.run_pooled(ops.iter().copied(), &filter, &cfg);
        out.push(point("pooled", w, &r));
    }

    // filter-generic chunk dispatch over a coarse-locked OCF
    {
        let filter = MutexFilter::new(Ocf::new(OcfConfig {
            initial_capacity: 1 << 16,
            ..OcfConfig::default()
        }));
        let mut p = IngestPipeline::new(
            policy(),
            HashExecutor::native(filter.with_inner(|fl| fl.hasher())),
        );
        let w = worker_counts.iter().copied().max().unwrap_or(4);
        let cfg = PoolConfig {
            workers: w,
            queue_depth: 4,
            chunk: 2048,
        };
        let r = p.run_pooled(ops.iter().copied(), &filter, &cfg);
        out.push(point("pooled-mutex", w, &r));
    }

    // The speedups below are only meaningful because the sharded arms
    // did provably identical work (P13 pins this property-wide).
    let base = &out[0];
    for p in &out[1..] {
        assert_eq!(p.ops, base.ops, "{}: op count diverged", p.mode);
        assert_eq!(p.inserts, base.inserts, "{}: inserts diverged", p.mode);
        assert_eq!(p.deletes, base.deletes, "{}: deletes diverged", p.mode);
        // hit counts are layout-dependent, so only arms sharing the
        // sharded filter layout must agree exactly
        if p.mode != "pooled-mutex" {
            assert_eq!(p.hits, base.hits, "{}: lookup hits diverged", p.mode);
        }
    }
    out
}

/// Throughput ratio `mode_a / mode_b` (best point of each mode);
/// `None` if either arm is missing.
pub fn speedup(points: &[PoolPoint], num: &str, den: &str) -> Option<f64> {
    let best = |mode: &str| {
        points
            .iter()
            .filter(|p| p.mode == mode)
            .max_by(|a, b| a.mops().total_cmp(&b.mops()))
    };
    let (n, d) = (best(num)?, best(den)?);
    if d.mops() > 0.0 {
        Some(n.mops() / d.mops())
    } else {
        None
    }
}

/// The best-throughput pooled point (the bench records its worker
/// count alongside the speedups).
pub fn best_pooled(points: &[PoolPoint]) -> Option<&PoolPoint> {
    points
        .iter()
        .filter(|p| p.mode == "pooled")
        .max_by(|a, b| a.mops().total_cmp(&b.mops()))
}

/// Render measured points as a markdown table (shared by the
/// experiment driver and the `pipeline_pool` bench).
pub fn render(title: impl Into<String>, points: &[PoolPoint]) -> String {
    let mut table = Table::new(
        title,
        &["mode", "workers", "ops", "secs", "Mops/s", "vs single"],
    );
    let single = points.iter().find(|p| p.mode == "single").map(|p| p.mops());
    for p in points {
        let vs = match single {
            Some(s) if s > 0.0 && p.mode != "single" => format!("{}x", f(p.mops() / s, 2)),
            _ => String::new(),
        };
        table.row(&[
            p.mode.to_string(),
            p.workers.to_string(),
            p.ops.to_string(),
            f(p.secs, 3),
            f(p.mops(), 2),
            vs,
        ]);
    }
    table.note(
        "same op stream, same filter configs; sharded arms are asserted \
         count-identical (inserts/hits/deletes) before speedups are \
         reported. pooled = persistent workers + staged hash/apply \
         overlap; scoped = per-batch thread::scope fan-out; pooled-mutex \
         = filter-generic chunk dispatch behind one coarse lock.",
    );
    table.markdown()
}

/// The experiment driver (paper scale: 2M ops).
pub fn run(scale: Scale) -> String {
    let n_ops = scale.n(2_000_000, 20_000);
    let max_w = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let worker_counts: Vec<usize> = [1usize, 2, 4, 8]
        .into_iter()
        .filter(|&w| w == 1 || w <= max_w)
        .collect();
    let points = measure(n_ops, &worker_counts);
    render(
        format!("E11 — pooled ingest engine ({n_ops} ops, {SHARDS} shards, batch {BATCH})"),
        &points,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_agree_and_cover_grid() {
        let points = measure(20_000, &[1, 2]);
        assert_eq!(points.len(), 5); // single + scoped + 2 pooled + pooled-mutex
        for mode in ["single", "scoped", "pooled", "pooled-mutex"] {
            assert!(points.iter().any(|p| p.mode == mode), "{mode} missing");
        }
        assert!(speedup(&points, "pooled", "single").is_some());
        assert!(speedup(&points, "pooled", "scoped").is_some());
        assert_eq!(best_pooled(&points).unwrap().mode, "pooled");
        assert!(points.iter().all(|p| p.ops == 20_000));
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.01));
        assert!(md.contains("E11"));
        assert!(md.contains("| single |"));
        assert!(md.contains("| scoped |"));
        assert!(md.contains("| pooled |"));
        assert!(md.contains("| pooled-mutex |"));
    }
}

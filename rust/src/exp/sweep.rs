//! E4 — §III key-size sweep: 10k … 1M keys, all filter arms.
//!
//! "We ran our implementation on different key sizes ranging from
//! 10000 - 1000000. We test both the modes of OCF for throughput and
//! accuracy." Extended with the baselines the paper positions against:
//! traditional cuckoo (sized for the workload — the favourable case),
//! bloom, scalable bloom, and the static xor filter.

use super::report::{f, Table};
use super::Scale;
use crate::filter::scalable_bloom::SbfParams;
use crate::filter::{
    BloomFilter, MembershipFilter, Mode, Ocf, OcfConfig, ScalableBloomFilter, XorFilter,
};
use std::time::Instant;

const FULL_SIZES: [usize; 5] = [10_000, 30_000, 100_000, 300_000, 1_000_000];
const PROBES: usize = 100_000;

/// One (filter, size) measurement.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub filter: String,
    pub n: usize,
    pub build_mops: f64,
    pub lookup_mops: f64,
    pub fp_rate: f64,
    pub memory_bytes: usize,
    pub bits_per_key: f64,
}

fn measure_dynamic(name: &str, filter: &mut dyn MembershipFilter, n: usize) -> SweepRow {
    let t0 = Instant::now();
    for k in 0..n as u64 {
        filter.insert(k).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    let build = n as f64 / t0.elapsed().as_secs_f64() / 1e6;

    let t1 = Instant::now();
    let lookups = n.min(PROBES);
    let mut hits = 0u64;
    for k in 0..lookups as u64 {
        if filter.contains(k) {
            hits += 1;
        }
    }
    let lookup = lookups as f64 / t1.elapsed().as_secs_f64() / 1e6;
    assert_eq!(hits as usize, lookups, "{name}: false negatives!");

    let mut fps = 0u64;
    for k in 0..PROBES as u64 {
        if filter.contains((1 << 41) + k) {
            fps += 1;
        }
    }
    SweepRow {
        filter: name.to_string(),
        n,
        build_mops: build,
        lookup_mops: lookup,
        fp_rate: fps as f64 / PROBES as f64,
        memory_bytes: filter.memory_bytes(),
        bits_per_key: filter.memory_bytes() as f64 * 8.0 / n as f64,
    }
}

fn measure_xor(n: usize) -> SweepRow {
    let keys: Vec<u64> = (0..n as u64).collect();
    let t0 = Instant::now();
    let xf = XorFilter::build(&keys, 0x50_50);
    let build = n as f64 / t0.elapsed().as_secs_f64() / 1e6;
    let t1 = Instant::now();
    let mut hits = 0;
    for &k in keys.iter().take(PROBES) {
        if xf.contains(k) {
            hits += 1;
        }
    }
    assert_eq!(hits, keys.len().min(PROBES));
    let lookup = keys.len().min(PROBES) as f64 / t1.elapsed().as_secs_f64() / 1e6;
    let mut fps = 0u64;
    for k in 0..PROBES as u64 {
        if xf.contains((1 << 41) + k) {
            fps += 1;
        }
    }
    SweepRow {
        filter: "xor (static)".into(),
        n,
        build_mops: build,
        lookup_mops: lookup,
        fp_rate: fps as f64 / PROBES as f64,
        memory_bytes: xf.memory_bytes(),
        bits_per_key: xf.bits_per_key(),
    }
}

/// All arms at one size.
pub fn run_size(n: usize) -> Vec<SweepRow> {
    let mut rows = Vec::new();
    for mode in [Mode::Eof, Mode::Pre] {
        let mut ocf = Ocf::new(OcfConfig {
            mode,
            initial_capacity: 4096,
            ..OcfConfig::default()
        });
        rows.push(measure_dynamic(
            &format!("ocf-{}", mode.as_str()),
            &mut ocf,
            n,
        ));
    }
    // traditional cuckoo pre-sized for n (its favourable configuration)
    let mut trad = Ocf::new(OcfConfig {
        mode: Mode::Static,
        initial_capacity: n * 2,
        ..OcfConfig::default()
    });
    rows.push(measure_dynamic("cuckoo (pre-sized)", &mut trad, n));
    let mut bloom = BloomFilter::new(n, 0.01, 0xB100);
    rows.push(measure_dynamic("bloom (1% target)", &mut bloom, n));
    let mut sbf = ScalableBloomFilter::new(
        SbfParams {
            initial_capacity: 4096,
            fpr: 0.01,
            ..SbfParams::default()
        },
        0x5BF,
    );
    rows.push(measure_dynamic("scalable-bloom", &mut sbf, n));
    rows.push(measure_xor(n));
    rows
}

/// Full sweep.
pub fn run(scale: Scale) -> String {
    let mut t = Table::new(
        "E4 — key-size sweep (10k…1M), all filter arms",
        &[
            "Filter",
            "Keys",
            "Build Mops/s",
            "Lookup Mops/s",
            "FP rate",
            "Memory",
            "Bits/key",
        ],
    );
    for &full_n in &FULL_SIZES {
        let n = scale.n(full_n, 5_000);
        for row in run_size(n) {
            t.row(&[
                row.filter.clone(),
                row.n.to_string(),
                f(row.build_mops, 2),
                f(row.lookup_mops, 2),
                format!("{:.2e}", row.fp_rate),
                crate::util::fmt_bytes(row.memory_bytes),
                f(row.bits_per_key, 1),
            ]);
        }
    }
    t.note(
        "paper §II: 'The traditional Cuckoo filter provides higher lookup \
         performance than Bloom Filters, it also consumes less space provided \
         the false positive rate remains below 3%' — compare cuckoo vs bloom \
         lookup columns; xor is the static floor line.",
    );
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_arms_measured_no_false_negatives() {
        let rows = run_size(8_000);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.build_mops > 0.0, "{}", r.filter);
            assert!(r.lookup_mops > 0.0, "{}", r.filter);
            assert!(r.fp_rate < 0.05, "{}: {}", r.filter, r.fp_rate);
        }
    }

    #[test]
    fn cuckoo_lookup_faster_than_bloom() {
        // the paper's §II claim, at moderate scale (averaged over 3 runs
        // to reduce timer noise on a 1-vCPU container)
        let score = |name: &str| -> f64 {
            (0..3)
                .map(|_| {
                    run_size(20_000)
                        .into_iter()
                        .find(|r| r.filter.starts_with(name))
                        .unwrap()
                        .lookup_mops
                })
                .sum::<f64>()
                / 3.0
        };
        let cuckoo = score("cuckoo");
        let bloom = score("bloom");
        assert!(
            cuckoo > bloom * 0.8,
            "cuckoo {cuckoo} must not trail bloom {bloom} badly"
        );
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.01));
        assert!(md.contains("E4"));
        assert!(md.contains("xor"));
    }
}

//! E6 — burst tolerance: premature flushes and ingest latency (§I.A).
//!
//! Two identical storage nodes under the same square-wave burst
//! workload; one carries a fixed-capacity filter with the
//! filter-pressure flush trigger (the Cassandra failure mode the paper
//! describes), the other an OCF-EOF filter. We count flushes (total /
//! premature), measure per-op ingest latency, and report filter memory.
//!
//! Expected shape: the fixed arm premature-flushes repeatedly (each one
//! a full in-memory rebuild → latency spikes); the OCF arm only
//! flushes when the memtable is genuinely full.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{Mode, OcfConfig};
use crate::metrics::Histogram;
use crate::store::{FlushPolicy, NodeConfig, StorageNode};
use crate::workload::{BurstGenerator, Op};
use std::time::Instant;

/// One node-arm outcome.
#[derive(Debug, Clone)]
pub struct BurstRow {
    pub arm: String,
    pub ops: u64,
    pub flushes: u64,
    pub premature_flushes: u64,
    pub p50_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub filter_memory: usize,
}

fn drive(mut node: StorageNode, ops_budget: usize, seed: u64, arm: &str) -> BurstRow {
    let mut gen = BurstGenerator::square_wave(ops_budget / 8, 1 << 24, seed);
    let mut lat = Histogram::new();
    let mut done = 0u64;
    while (done as usize) < ops_budget {
        let op = match gen.next_op() {
            Some(op) => op,
            None => break,
        };
        let t0 = Instant::now();
        match op {
            Op::Insert(k) => {
                let _ = node.put(k);
            }
            Op::Lookup(k) => {
                let _ = node.get(k);
            }
            Op::Delete(k) => {
                let _ = node.delete(k);
            }
        }
        lat.record(t0.elapsed().as_nanos() as u64);
        done += 1;
    }
    BurstRow {
        arm: arm.to_string(),
        ops: done,
        flushes: node.stats.flushes,
        premature_flushes: node.stats.flushes_premature,
        p50_ns: lat.quantile(0.5),
        p99_ns: lat.quantile(0.99),
        max_ns: lat.quantile(1.0),
        filter_memory: node.filter_memory_bytes(),
    }
}

/// Both arms at `ops` budget.
pub fn run_arms(ops: usize, seed: u64) -> (BurstRow, BurstRow) {
    // fixed arm: filter sized for ~1/4 of the burst peak → pressure
    let fixed = StorageNode::new(NodeConfig {
        filter: OcfConfig {
            mode: Mode::Static,
            initial_capacity: (ops / 8).next_power_of_two().max(2048),
            ..OcfConfig::default()
        }
        .into(),
        flush: FlushPolicy::small(ops).with_filter_pressure(0.85),
        ..NodeConfig::default()
    });
    let ocf = StorageNode::new(NodeConfig {
        filter: OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 4096,
            ..OcfConfig::default()
        }
        .into(),
        flush: FlushPolicy::small(ops),
        ..NodeConfig::default()
    });
    (
        drive(fixed, ops, seed, "fixed filter + pressure flush"),
        drive(ocf, ops, seed, "OCF-EOF (burst tolerant)"),
    )
}

/// Full experiment.
pub fn run(scale: Scale) -> String {
    let ops = scale.n(400_000, 20_000);
    let (fixed, ocf) = run_arms(ops, 0xB00_57);
    let mut t = Table::new(
        format!("E6 — burst tolerance on a storage node ({ops} square-wave ops)"),
        &[
            "Arm",
            "Ops",
            "Flushes",
            "Premature flushes",
            "p50 ns",
            "p99 ns",
            "max ns",
            "Filter memory",
        ],
    );
    for r in [&fixed, &ocf] {
        t.row(&[
            r.arm.clone(),
            r.ops.to_string(),
            r.flushes.to_string(),
            r.premature_flushes.to_string(),
            r.p50_ns.to_string(),
            r.p99_ns.to_string(),
            r.max_ns.to_string(),
            crate::util::fmt_bytes(r.filter_memory),
        ]);
    }
    t.note(format!(
        "paper §I.A shape: OCF 'improves latency by preventing premature \
         flushes'. premature flushes: fixed {} vs OCF {}; p99 ratio \
         fixed/OCF = {}.",
        fixed.premature_flushes,
        ocf.premature_flushes,
        f(fixed.p99_ns as f64 / ocf.p99_ns.max(1) as f64, 2),
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ocf_arm_never_premature_fixed_arm_is() {
        let (fixed, ocf) = run_arms(30_000, 3);
        assert!(
            fixed.premature_flushes > 0,
            "fixed arm must premature-flush: {fixed:?}"
        );
        assert_eq!(ocf.premature_flushes, 0, "{ocf:?}");
        assert_eq!(ocf.ops, 30_000);
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.05));
        assert!(md.contains("E6"));
        assert!(md.contains("Premature"));
    }
}

//! E15 — chaos: availability and op latency vs replica fault rate, per
//! consistency level.
//!
//! The paper's throughput story assumes replicas answer; this
//! experiment measures what the cluster actually delivers when they
//! don't. Each arm is a 5-node rf=3 cluster whose replicas fail on
//! independent seeded [`FaultSchedule`]s (transient, latent, crashed
//! windows over the op clock) at a swept fault density, running a fixed
//! put/get/delete mix at one consistency level (used for both reads and
//! writes):
//!
//! * **One** maximizes availability — a single reachable replica acks —
//!   at the cost of read-your-write guarantees mid-fault (R+W ≤ RF).
//! * **Quorum** keeps R+W > RF: every acked write stays readable
//!   through arbitrary single-replica faults, which the in-run gates
//!   assert op by op.
//! * **All** maximizes consistency and pays for it: any unreachable
//!   replica fails the op with a typed [`ClusterError::QuorumLost`].
//!
//! Latency is reported two ways: measured wall time per op (the real
//! cost of retries, breaker bookkeeping and hint queueing) and the
//! synthetic latency the latent fault windows injected (accounted by
//! the proxy, not slept — see `cluster::proxy`).
//!
//! In-run gates (all arms): zero-rate arms must ack every op and lose
//! no quorum; after every arm the hint queues must drain to zero with
//! nothing dropped, and a full-replica audit asserts no acknowledged
//! write was lost and no deleted key resurrected — at *every*
//! consistency level, because hinted handoff eventually lands every
//! acked write on all RF replicas even when only one acked it.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use super::report::{f, Table};
use super::Scale;
use crate::cluster::{
    Cluster, Consistency, FaultPlane, FaultSchedule, ReplicationConfig, ResilienceConfig,
};
use crate::store::{FlushPolicy, NodeConfig};
use crate::util::{rng::GOLDEN_GAMMA, SplitMix64};

const SEED: u64 = 0xE15_C4A0;
const NODES: usize = 5;
const RF: usize = 3;
/// Small key space so puts, deletes and reads collide constantly.
const KEY_SPACE: u64 = 1024;

/// Fault densities swept per consistency level (0.0 is the control).
pub const FAULT_RATES: [f64; 4] = [0.0, 0.02, 0.1, 0.25];

/// Consistency levels swept (used for both reads and writes).
pub const LEVELS: [Consistency; 3] = [Consistency::One, Consistency::Quorum, Consistency::All];

/// One (consistency level × fault rate) cell.
#[derive(Debug, Clone)]
pub struct ChaosArm {
    pub level: Consistency,
    pub fault_rate: f64,
    pub ops: usize,
    /// Ops that returned `Ok` (writes acked, reads answered).
    pub ok_ops: u64,
    pub quorum_losses: u64,
    pub retries: u64,
    pub breaker_trips: u64,
    pub hints_queued: u64,
    pub hints_replayed: u64,
    pub read_repairs: u64,
    pub timeouts: u64,
    /// Wall time of the op loop (excludes the drain).
    pub secs: f64,
    /// Synthetic latency injected by latent windows, summed (µs).
    pub synthetic_us: u64,
    /// Clock advances needed before the hint queues hit zero.
    pub drain_rounds: u64,
}

impl ChaosArm {
    /// Fraction of ops served at the arm's consistency level.
    pub fn availability(&self) -> f64 {
        self.ok_ops as f64 / self.ops.max(1) as f64
    }

    /// Measured wall latency per op (µs).
    pub fn wall_us_per_op(&self) -> f64 {
        self.secs * 1e6 / self.ops.max(1) as f64
    }
}

/// What the acknowledged-state model knows about one key (quorum-lost
/// writes make a key uncertain until the next acked write).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Truth {
    Present,
    Absent,
    Uncertain,
}

fn arm_cluster(level: Consistency, fault_rate: f64, ops: usize, arm_seed: u64) -> Cluster {
    let planes: Vec<Arc<dyn FaultPlane>> = (0..NODES)
        .map(|n| {
            let node_seed = arm_seed ^ (n as u64 + 1).wrapping_mul(GOLDEN_GAMMA);
            Arc::new(FaultSchedule::seeded(node_seed, fault_rate, ops as u64))
                as Arc<dyn FaultPlane>
        })
        .collect();
    Cluster::with_fault_planes(
        NODES,
        32,
        NodeConfig {
            flush: FlushPolicy::small(10_000),
            ..NodeConfig::default()
        },
        ReplicationConfig {
            rf: RF,
            read_consistency: level,
            write_consistency: level,
        },
        ResilienceConfig::default(),
        planes,
    )
}

/// Run one arm: scripted workload, availability/latency measurement,
/// drain, convergence audit. Panics on any contract violation.
pub fn run_arm(level: Consistency, fault_rate: f64, ops: usize, arm_seed: u64) -> ChaosArm {
    let mut cluster = arm_cluster(level, fault_rate, ops, arm_seed);
    let mut model: BTreeMap<u64, Truth> = BTreeMap::new();
    let mut rng = SplitMix64::new(arm_seed.wrapping_mul(GOLDEN_GAMMA));
    // R+W > RF ⇒ acked writes must stay readable *during* the faults,
    // not just after the drain
    let strict = level.required(RF) * 2 > RF;
    let ctx = || format!("E15 {}/{fault_rate}", level.as_str());
    let mut ok_ops = 0u64;

    let t0 = Instant::now();
    for i in 0..ops {
        let key = rng.next_below(KEY_SPACE);
        let truth = model.get(&key).copied().unwrap_or(Truth::Absent);
        match rng.next_below(10) {
            0..=4 => match cluster.put(key) {
                Ok(()) => {
                    ok_ops += 1;
                    model.insert(key, Truth::Present);
                }
                Err(_) => {
                    model.insert(key, Truth::Uncertain);
                }
            },
            5..=6 => match cluster.delete(key) {
                Ok(_) => {
                    ok_ops += 1;
                    model.insert(key, Truth::Absent);
                }
                Err(_) => {
                    model.insert(key, Truth::Uncertain);
                }
            },
            _ => match cluster.get(key) {
                Ok(hit) => {
                    ok_ops += 1;
                    if strict {
                        match truth {
                            Truth::Present => {
                                assert!(hit, "{} op {i}: lost acked write {key}", ctx())
                            }
                            Truth::Absent => {
                                assert!(!hit, "{} op {i}: key {key} resurrected", ctx())
                            }
                            Truth::Uncertain => {}
                        }
                    }
                }
                Err(_) => {}
            },
        }
    }
    let secs = t0.elapsed().as_secs_f64();

    if fault_rate == 0.0 {
        assert_eq!(
            ok_ops, ops as u64,
            "{}: healthy control arm must serve every op",
            ctx()
        );
        assert_eq!(cluster.stats.quorum_losses, 0, "{}", ctx());
    }

    // Drain: the clock sits at the fault horizon, so every replica is
    // recovered — pending hints must land once breakers re-close.
    let cooldown = cluster.resilience().breaker.cooldown;
    let mut drain_rounds = 0u64;
    while cluster.replay_hints() > 0 {
        drain_rounds += 1;
        assert!(
            drain_rounds < 64,
            "{}: {} hints refuse to drain",
            ctx(),
            cluster.hints_pending()
        );
        cluster.advance_clock(cooldown + 1);
    }
    assert_eq!(cluster.stats.hints_dropped, 0, "{}: hints dropped", ctx());

    // Convergence audit, every level: an acked write (even at One) must
    // now be on all of its replicas, an acked delete on none.
    for (&key, &truth) in &model {
        let expect = match truth {
            Truth::Present => true,
            Truth::Absent => false,
            Truth::Uncertain => continue,
        };
        for n in cluster.ring().replicas(key, RF) {
            assert_eq!(
                cluster.node(n).get(key),
                expect,
                "{}: replica {n} diverged on key {key} after drain",
                ctx()
            );
        }
    }

    ChaosArm {
        level,
        fault_rate,
        ops,
        ok_ops,
        quorum_losses: cluster.stats.quorum_losses,
        retries: cluster.stats.retries,
        breaker_trips: cluster.stats.breaker_trips,
        hints_queued: cluster.stats.hints_queued,
        hints_replayed: cluster.stats.hints_replayed,
        read_repairs: cluster.stats.read_repairs,
        timeouts: cluster.timeouts(),
        secs,
        synthetic_us: cluster.synthetic_latency_us(),
        drain_rounds,
    }
}

/// Run the full sweep: every consistency level × every fault rate.
pub fn measure(ops: usize) -> Vec<ChaosArm> {
    let mut arms = Vec::with_capacity(LEVELS.len() * FAULT_RATES.len());
    for (li, &level) in LEVELS.iter().enumerate() {
        for (ri, &rate) in FAULT_RATES.iter().enumerate() {
            let arm_seed = SEED ^ (((li * FAULT_RATES.len() + ri) as u64 + 1) << 8);
            arms.push(run_arm(level, rate, ops, arm_seed));
        }
    }
    arms
}

/// Render the E15 table.
pub fn render(title: impl Into<String>, arms: &[ChaosArm]) -> String {
    let mut t = Table::new(
        title,
        &[
            "level",
            "fault rate",
            "availability",
            "wall µs/op",
            "inj µs/op",
            "quorum lost",
            "retries",
            "trips",
            "hints q→replay",
            "repairs",
            "timeouts",
        ],
    );
    for a in arms {
        t.row(&[
            a.level.as_str().to_string(),
            f(a.fault_rate, 2),
            format!("{}%", f(a.availability() * 100.0, 2)),
            f(a.wall_us_per_op(), 2),
            f(a.synthetic_us as f64 / a.ops.max(1) as f64, 2),
            a.quorum_losses.to_string(),
            a.retries.to_string(),
            a.breaker_trips.to_string(),
            format!("{}→{}", a.hints_queued, a.hints_replayed),
            a.read_repairs.to_string(),
            a.timeouts.to_string(),
        ]);
    }
    t.note(format!(
        "{NODES} nodes, rf={RF}, {} ops per arm over a {KEY_SPACE}-key space \
         (~50% put / 20% delete / 30% get); the level column sets both read \
         and write consistency. 'availability' counts ops served at the \
         arm's level — failures are typed QuorumLost errors, never silent \
         wrong answers. 'inj µs/op' is latency injected by latent fault \
         windows (accounted, not slept). Gates asserted in-run: healthy arms \
         serve 100%, R+W>RF arms never lose an acked write or resurrect a \
         delete mid-fault, every arm's hint queues drain to zero after \
         recovery, and all replicas converge to the acknowledged state.",
        arms.first().map_or(0, |a| a.ops),
    ));
    t.markdown()
}

/// The experiment driver (paper scale: 60k ops per arm × 12 arms).
pub fn run(scale: Scale) -> String {
    let ops = scale.n(60_000, 1_500);
    let arms = measure(ops);
    render(
        format!("E15 — availability & latency vs replica fault rate ({ops} ops/arm)"),
        &arms,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders() {
        // Floor scale: 1 500 ops per arm, 12 arms. All contract gates
        // (control availability, no lost acks at quorum, drain-to-zero,
        // convergence audit) run inside measure().
        let md = run(Scale(0.002));
        assert!(md.contains("E15"));
        assert!(md.contains("| one |"));
        assert!(md.contains("| quorum |"));
        assert!(md.contains("| all |"));
        assert!(md.contains("100"));
    }

    #[test]
    fn faulted_quorum_arm_engages_the_machinery() {
        let arm = run_arm(Consistency::Quorum, 0.25, 2_000, SEED ^ 0x77);
        assert!(
            arm.retries + arm.hints_queued + arm.breaker_trips > 0,
            "25% fault density engaged nothing: {arm:?}"
        );
        assert!(arm.availability() > 0.5, "quorum should ride out most faults");
    }
}

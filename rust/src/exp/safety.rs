//! E5 — the safety experiments behind §II and §IV.
//!
//! 1. **False negatives at high load** (§II: "We observed an occasional
//!    false negative when operating at this threshold"): fill a
//!    traditional filter (naive Drop victim handling) to ~0.95 load and
//!    count resident keys the filter denies. OCF must show zero.
//! 2. **Unsafe deletes** (§IV: "trying to delete keys that were not
//!    inserted from traditional cuckoo filter removes fingerprints
//!    inserted by other keys"): fire deletes of never-inserted keys at
//!    both and count collateral false negatives. OCF's verified-delete
//!    path must reject all of them.

use super::report::Table;
use super::Scale;
use crate::filter::{
    CuckooFilter, CuckooParams, FlatTable, MembershipFilter, Mode, Ocf, OcfConfig, VictimPolicy,
};

/// Outcome of one safety arm.
#[derive(Debug, Clone)]
pub struct SafetyRow {
    pub arm: String,
    pub resident_keys: usize,
    pub false_negatives_overload: usize,
    pub hostile_deletes_accepted: usize,
    pub false_negatives_after_deletes: usize,
}

/// Traditional filter with naive (Drop) victim handling.
pub fn run_traditional(n_target: usize, seed: u64) -> SafetyRow {
    let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
        capacity: n_target,
        victim_policy: VictimPolicy::Drop,
        seed,
        // 12-bit fingerprints: the collision probability per hostile
        // delete is ~2b·O/2^12 ≈ 2e-3, so a few thousand hostile
        // deletes reliably demonstrate the §IV failure (16-bit would
        // need millions of trials to show the same effect).
        fp_bits: 12,
        ..CuckooParams::default()
    });
    // overfill past the ~0.9 failure threshold: keep hammering until the
    // displacement budget has failed repeatedly — each failure under the
    // naive Drop policy loses a *resident* fingerprint (paper §II: "We
    // observed an occasional false negative when operating at this
    // threshold")
    let mut resident = Vec::new();
    let mut k = 0u64;
    while f.stats.dropped_fingerprints < 50 && (k as usize) < n_target * 4 {
        if f.insert(k).is_ok() {
            resident.push(k);
        }
        k += 1;
    }
    let fn_overload = resident.iter().filter(|&&k| !f.contains(k)).count();

    // hostile deletes: never-inserted keys
    let mut accepted = 0;
    for h in 0..n_target as u64 {
        if f.delete((1 << 42) + h) {
            accepted += 1;
        }
    }
    let fn_after = resident.iter().filter(|&&k| !f.contains(k)).count();
    SafetyRow {
        arm: "traditional (Drop victims, unverified deletes)".into(),
        resident_keys: resident.len(),
        false_negatives_overload: fn_overload,
        hostile_deletes_accepted: accepted,
        false_negatives_after_deletes: fn_after,
    }
}

/// OCF arm (EOF mode, verified deletes).
pub fn run_ocf(n_target: usize, seed: u64) -> SafetyRow {
    let mut f = Ocf::new(OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 4096,
        seed,
        fp_bits: 12, // match the traditional arm's configuration
        ..OcfConfig::default()
    });
    let mut resident = Vec::new();
    for k in 0..n_target as u64 {
        f.insert(k).expect("ocf insert");
        resident.push(k);
    }
    let fn_overload = resident.iter().filter(|&&k| !f.contains(k)).count();
    let mut accepted = 0;
    for h in 0..n_target as u64 {
        if f.delete((1 << 42) + h) {
            accepted += 1;
        }
    }
    let fn_after = resident.iter().filter(|&&k| !f.contains(k)).count();
    SafetyRow {
        arm: "OCF-EOF (verified deletes)".into(),
        resident_keys: resident.len(),
        false_negatives_overload: fn_overload,
        hostile_deletes_accepted: accepted,
        false_negatives_after_deletes: fn_after,
    }
}

/// Full experiment.
pub fn run(scale: Scale) -> String {
    let n = scale.n(100_000, 4_000);
    let trad = run_traditional(n, 0x5AFE);
    let ocf = run_ocf(n, 0x5AFE);
    let mut t = Table::new(
        format!("E5 — membership-safety: overload false negatives & hostile deletes (n={n})"),
        &[
            "Arm",
            "Resident keys",
            "FNs at ~0.95 load",
            "Hostile deletes accepted",
            "FNs after hostile deletes",
        ],
    );
    for r in [&trad, &ocf] {
        t.rowd(&[
            r.arm.clone(),
            r.resident_keys.to_string(),
            r.false_negatives_overload.to_string(),
            r.hostile_deletes_accepted.to_string(),
            r.false_negatives_after_deletes.to_string(),
        ]);
    }
    t.note(format!(
        "paper §II/§IV shape: traditional shows FNs at high load ({}) and \
         accepts hostile deletes ({}) that damage residents ({} FNs); OCF \
         shows zero in all three columns ({}, {}, {}).",
        trad.false_negatives_overload,
        trad.hostile_deletes_accepted,
        trad.false_negatives_after_deletes,
        ocf.false_negatives_overload,
        ocf.hostile_deletes_accepted,
        ocf.false_negatives_after_deletes,
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traditional_is_unsafe_ocf_is_safe() {
        let trad = run_traditional(8_000, 1);
        let ocf = run_ocf(8_000, 1);
        assert!(
            trad.hostile_deletes_accepted > 0,
            "traditional must accept some hostile deletes"
        );
        assert!(
            trad.false_negatives_after_deletes > 0,
            "hostile deletes must damage residents"
        );
        assert_eq!(ocf.false_negatives_overload, 0);
        assert_eq!(ocf.hostile_deletes_accepted, 0);
        assert_eq!(ocf.false_negatives_after_deletes, 0);
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.05));
        assert!(md.contains("E5"));
        assert!(md.contains("OCF-EOF"));
    }
}

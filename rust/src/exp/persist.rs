//! E13 — the persistent frozen-filter tier: restart time and
//! mmap-vs-heap probe throughput.
//!
//! Two questions, both downstream of the on-disk format
//! (`store::frozen`):
//!
//! 1. **Restart cost.** Reopening a populated `persist_dir` with valid
//!    filter files (*recover*: validate + map, no table construction)
//!    vs with the filter files deleted (*rebuild*: re-insert every run
//!    key through the cuckoo build path). Recovery is the point of the
//!    persistence tier — the rebuild arm is the restart cost it
//!    removes. The [`NodeStats`] recovery counters
//!    (`filters_recovered` / `filters_rebuilt` /
//!    `filter_recovery_rejected`) are surfaced per arm so the report
//!    shows *which* path each restart actually took.
//! 2. **Probe parity.** Batched membership throughput on the same
//!    frozen generation served heap-backed vs mmap-backed. Both route
//!    through the identical [`BatchedFilter`] engine and kernel
//!    dispatch; once the mapping is warm the numbers should be
//!    indistinguishable — that is the claim that makes mmap-serving
//!    free.
//! 3. **WAL cost** (PR 7). Unflushed ingest throughput under each
//!    fsync policy (`off` / `always` / `every_64` / `os`) and the
//!    recovery cost of replaying those puts after a crash with no
//!    flush — the write-path price of "no acknowledged write is ever
//!    lost", and what the group-commit knob buys back.
//!
//! `measure()` is shared with `benches/persist.rs`, which emits the
//! `BENCH_persist.json` trajectory point.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{BatchedFilter, ProbeSession};
use crate::store::{
    Backing, FlushPolicy, FlushReason, FrozenStore, FsyncPolicy, NodeConfig, StorageNode,
    WalConfig,
};
use std::time::Instant;

/// Probe chunk size for the batched arms (matches E10).
pub const BATCH: usize = 4096;

/// One timed restart of the node.
#[derive(Debug, Clone)]
pub struct RestartArm {
    /// "recover" (valid filter files) | "rebuild" (filter files gone).
    pub arm: &'static str,
    /// Wallclock of `StorageNode::recover`.
    pub secs: f64,
    pub sstables: usize,
    pub filters_recovered: u64,
    pub filters_rebuilt: u64,
    pub filter_recovery_rejected: u64,
    /// Read-path FP feedback counters right after restart — always 0:
    /// adaptation state is never persisted (rebuild-on-recover), so a
    /// reopened node starts at the static baseline.
    pub fp_observed: u64,
    pub fp_remapped: u64,
}

/// One timed batched-probe loop over a frozen generation.
#[derive(Debug, Clone)]
pub struct ProbeArm {
    /// "heap" | "mmap".
    pub backing: &'static str,
    /// "neg" | "pos".
    pub workload: &'static str,
    pub probes: usize,
    pub secs: f64,
    pub hits: usize,
}

impl ProbeArm {
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.probes as f64 / self.secs / 1e6
        }
    }
}

/// One WAL fsync-policy arm: time `puts` unflushed puts, crash
/// (drop without flush), time the replaying recovery.
#[derive(Debug, Clone)]
pub struct WalArm {
    /// "off" | "always" | "every_64" | "os".
    pub policy: String,
    pub puts: usize,
    pub ingest_secs: f64,
    pub recover_secs: f64,
    /// Ops replayed at recovery — 0 for "off" (those puts are simply
    /// gone), `puts` for every enabled policy.
    pub wal_replayed: u64,
}

impl WalArm {
    pub fn ingest_kops(&self) -> f64 {
        if self.ingest_secs <= 0.0 {
            0.0
        } else {
            self.puts as f64 / self.ingest_secs / 1e3
        }
    }
}

/// Everything E13 measures.
#[derive(Debug, Clone)]
pub struct PersistOutcome {
    pub keys: usize,
    pub restarts: Vec<RestartArm>,
    pub probe_arms: Vec<ProbeArm>,
    pub wal_arms: Vec<WalArm>,
}

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let n = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("ocf-e13-{tag}-{}-{n}", std::process::id()))
}

fn restart(cfg: &NodeConfig, arm: &'static str) -> (StorageNode, RestartArm) {
    let t0 = Instant::now();
    let node = StorageNode::recover(cfg.clone()).expect("recover scratch dir");
    let secs = t0.elapsed().as_secs_f64();
    let point = RestartArm {
        arm,
        secs,
        sstables: node.sstable_count(),
        filters_recovered: node.stats.filters_recovered(),
        filters_rebuilt: node.stats.filters_rebuilt(),
        filter_recovery_rejected: node.stats.filter_recovery_rejected(),
        fp_observed: node.stats.fp_observed(),
        fp_remapped: node.stats.fp_remapped(),
    };
    (node, point)
}

fn time_probe_arm(
    filter: &crate::filter::FrozenTable,
    backing: &'static str,
    workload: &'static str,
    probes: &[u64],
) -> ProbeArm {
    let mut session = ProbeSession::with_capacity(BATCH);
    let mut answers: Vec<bool> = Vec::with_capacity(BATCH);
    let t0 = Instant::now();
    let mut hits = 0usize;
    for chunk in probes.chunks(BATCH) {
        answers.clear();
        filter.contains_batch_into(chunk, &mut session, &mut answers);
        hits += answers.iter().filter(|&&h| h).count();
    }
    ProbeArm {
        backing,
        workload,
        probes: probes.len(),
        secs: t0.elapsed().as_secs_f64(),
        hits,
    }
}

/// Measure restart (recover vs rebuild) and probe (heap vs mmap) arms
/// over a freshly persisted population of `n_keys`.
pub fn measure(n_keys: usize, n_probes: usize) -> PersistOutcome {
    let dir = scratch_dir("measure");
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = NodeConfig {
        persist_dir: Some(dir.to_str().expect("utf-8 temp path").to_string()),
        // One manual flush → one generation holding every key, so the
        // probe arms (and their positive workload) see the full set.
        flush: FlushPolicy::small(usize::MAX),
        // Group-commit the populate phase: the restart arms measure
        // filter recovery, not fsync latency (the WAL arms below
        // measure that, deliberately).
        wal: WalConfig {
            enabled: true,
            fsync: FsyncPolicy::EveryN(1024),
        },
        ..NodeConfig::default()
    };

    // Populate + freeze: one durable generation holding all keys.
    let mut node = StorageNode::new(cfg.clone());
    for k in 0..n_keys as u64 {
        node.put(k).expect("put");
    }
    node.flush(FlushReason::MemtableKeys);
    drop(node);

    let mut restarts = Vec::with_capacity(2);

    // Arm 1: recover — filter files valid, served in place.
    let (node, point) = restart(&cfg, "recover");
    assert_eq!(point.filters_rebuilt, 0, "recover arm must not rebuild");
    drop(node);
    restarts.push(point);

    // Arm 2: rebuild — filter files deleted (the crash window where
    // only runs survived); every filter reconstructed from its run.
    let store = FrozenStore::open(&dir).expect("open scratch store");
    for gen in store.generations().expect("list generations") {
        let _ = std::fs::remove_file(store.filter_path(gen));
    }
    let (node, point) = restart(&cfg, "rebuild");
    assert!(point.filters_rebuilt > 0, "rebuild arm must rebuild");
    assert_eq!(
        point.filter_recovery_rejected, 0,
        "missing files are not rejections"
    );
    drop(node);
    restarts.push(point);

    // Probe arms: the same (largest) generation, heap vs mmap backing.
    // The rebuild arm re-persisted healed filters, so loads succeed.
    let gen = *store
        .generations()
        .expect("list generations")
        .last()
        .expect("at least one generation");
    let heap = store
        .load_filter_with(gen, Backing::Heap)
        .expect("heap load");
    let neg: Vec<u64> = (0..n_probes as u64).map(|i| (1u64 << 40) + i).collect();
    let pos: Vec<u64> = (0..n_probes as u64)
        .map(|i| i % n_keys.max(1) as u64)
        .collect();
    let mut probe_arms = Vec::with_capacity(4);
    for (workload, probes) in [("neg", &neg), ("pos", &pos)] {
        probe_arms.push(time_probe_arm(&heap, "heap", workload, probes));
    }
    match store.load_filter_with(gen, Backing::Mmap) {
        Ok(mapped) => {
            assert!(mapped.is_mapped());
            for (workload, probes) in [("neg", &neg), ("pos", &pos)] {
                let arm = time_probe_arm(&mapped, "mmap", workload, probes);
                // parity anchor: identical answers off both backings
                let twin = probe_arms
                    .iter()
                    .find(|p| p.backing == "heap" && p.workload == arm.workload)
                    .expect("heap twin");
                assert_eq!(arm.hits, twin.hits, "{}: backings diverged", arm.workload);
                probe_arms.push(arm);
            }
        }
        Err(e) => {
            // Non-unix / big-endian targets: heap is the only backing.
            eprintln!("E13: mmap arm unavailable on this target ({e}); heap arms only");
        }
    }

    let _ = std::fs::remove_dir_all(&dir);

    // WAL arms: unflushed ingest + crash + replaying recovery, per
    // fsync policy. Capped — the `always` arm pays one fsync per put
    // by contract, and 10k of those already tell the story.
    let wal_arms = measure_wal(n_keys.min(10_000));

    PersistOutcome {
        keys: n_keys,
        restarts,
        probe_arms,
        wal_arms,
    }
}

/// Time `n_puts` unflushed puts under each fsync policy, crash (drop
/// with nothing flushed), and time the recovery that replays them.
pub fn measure_wal(n_puts: usize) -> Vec<WalArm> {
    let policies: [(&str, WalConfig); 4] = [
        (
            "off",
            WalConfig {
                enabled: false,
                fsync: FsyncPolicy::Always,
            },
        ),
        (
            "always",
            WalConfig {
                enabled: true,
                fsync: FsyncPolicy::Always,
            },
        ),
        (
            "every_64",
            WalConfig {
                enabled: true,
                fsync: FsyncPolicy::EveryN(64),
            },
        ),
        (
            "os",
            WalConfig {
                enabled: true,
                fsync: FsyncPolicy::Os,
            },
        ),
    ];
    let mut arms = Vec::with_capacity(policies.len());
    for (name, wal) in policies {
        let dir = scratch_dir(&format!("wal-{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = NodeConfig {
            persist_dir: Some(dir.to_str().expect("utf-8 temp path").to_string()),
            flush: FlushPolicy::small(usize::MAX), // never flush: WAL-only durability
            wal,
            ..NodeConfig::default()
        };
        let mut node = StorageNode::new(cfg.clone());
        let t0 = Instant::now();
        for k in 0..n_puts as u64 {
            node.put(k).expect("put");
        }
        let ingest_secs = t0.elapsed().as_secs_f64();
        assert_eq!(node.stats.wal_append_failed(), 0, "{name}: degraded ingest");
        drop(node); // crash analog: no flush, no shutdown hooks

        let t0 = Instant::now();
        let node = StorageNode::recover(cfg).expect("recover wal arm");
        let recover_secs = t0.elapsed().as_secs_f64();
        arms.push(WalArm {
            policy: name.to_string(),
            puts: n_puts,
            ingest_secs,
            recover_secs,
            wal_replayed: node.stats.wal_replayed(),
        });
        drop(node);
        let _ = std::fs::remove_dir_all(&dir);
    }
    arms
}

/// Render the two E13 tables (shared by the experiment driver and the
/// `persist` bench so their outputs cannot drift).
pub fn render(title: impl Into<String>, o: &PersistOutcome) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        title,
        &[
            "restart arm",
            "ms",
            "sstables",
            "recovered",
            "rebuilt",
            "rejected",
            "fp obs/remap",
        ],
    );
    for r in &o.restarts {
        t.row(&[
            r.arm.to_string(),
            f(r.secs * 1e3, 2),
            r.sstables.to_string(),
            r.filters_recovered.to_string(),
            r.filters_rebuilt.to_string(),
            r.filter_recovery_rejected.to_string(),
            format!("{}/{}", r.fp_observed, r.fp_remapped),
        ]);
    }
    t.note(
        "recover = validate + serve persisted filter files in place (mmap-backed \
         where supported); rebuild = filter files deleted, every table's filter \
         reconstructed from its run — the restart cost persistence removes. \
         Counters are the NodeStats recovery counters; the FP-feedback pair is \
         0/0 by construction after any restart — adaptation state is never \
         serialized (rebuild-on-recover; E14 measures the re-learning curve).",
    );
    out.push_str(&t.markdown());
    out.push('\n');

    let mut t = Table::new(
        format!("E13 — frozen-probe throughput by backing ({} keys)", o.keys),
        &["backing", "workload", "Mops/s", "vs heap"],
    );
    for p in &o.probe_arms {
        let ratio = if p.backing == "heap" {
            String::new()
        } else {
            o.probe_arms
                .iter()
                .find(|q| q.backing == "heap" && q.workload == p.workload)
                .filter(|q| q.mops() > 0.0)
                .map(|q| format!("{}x", f(p.mops() / q.mops(), 2)))
                .unwrap_or_default()
        };
        t.row(&[
            p.backing.to_string(),
            p.workload.to_string(),
            f(p.mops(), 2),
            ratio,
        ]);
    }
    t.note(
        "Same frozen generation, same BatchedFilter engine and kernel dispatch; \
         the mmap arms read the words straight off the page cache (zero-copy). \
         ≈1.0x is the expected (and desired) result.",
    );
    out.push_str(&t.markdown());
    out.push('\n');

    if let Some(puts) = o.wal_arms.first().map(|w| w.puts) {
        let mut t = Table::new(
            format!("E13 — WAL ingest cost and replay by fsync policy ({puts} unflushed puts)"),
            &["wal", "ingest kops/s", "recover ms", "replayed"],
        );
        for w in &o.wal_arms {
            t.row(&[
                w.policy.clone(),
                f(w.ingest_kops(), 1),
                f(w.recover_secs * 1e3, 2),
                w.wal_replayed.to_string(),
            ]);
        }
        t.note(
            "Puts are never flushed, then the node 'crashes' (drop) and recovers: \
             with the WAL off they are simply gone (replayed = 0); any enabled \
             policy replays all of them. `always` pays one fsync per put (the \
             zero-loss-on-power-failure contract); `every_64` group-commits \
             (≤63 records exposed to power loss, none to process death); `os` \
             never syncs from the WAL.",
        );
        out.push_str(&t.markdown());
    }
    out
}

/// The experiment driver (paper scale: 1M resident keys, 1M probes).
pub fn run(scale: Scale) -> String {
    let n_keys = scale.n(1_000_000, 20_000);
    let n_probes = scale.n(1_000_000, 20_000);
    let outcome = measure(n_keys, n_probes);
    render(
        format!("E13 — persistent tier: restart recover vs rebuild ({n_keys} keys)"),
        &outcome,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_cover_and_agree() {
        let o = measure(5_000, 5_000);
        assert_eq!(o.restarts.len(), 2);
        assert_eq!(o.restarts[0].arm, "recover");
        assert!(o.restarts[0].filters_recovered >= 1);
        assert_eq!(o.restarts[1].arm, "rebuild");
        assert!(o.restarts[1].filters_rebuilt >= 1);
        // heap arms always present; mmap arms on supported targets
        assert!(o.probe_arms.len() >= 2);
        if cfg!(all(unix, target_endian = "little")) {
            assert_eq!(o.probe_arms.len(), 4);
        }
        // positive probes must all hit (frozen tables keep the
        // no-false-negative invariant across persist/reopen)
        assert!(o
            .probe_arms
            .iter()
            .filter(|p| p.workload == "pos")
            .all(|p| p.hits == p.probes));
        // WAL arms: off loses unflushed puts, every policy replays all
        let policies: Vec<&str> = o.wal_arms.iter().map(|w| w.policy.as_str()).collect();
        assert_eq!(policies, ["off", "always", "every_64", "os"]);
        for w in &o.wal_arms {
            if w.policy == "off" {
                assert_eq!(w.wal_replayed, 0, "wal=off must not replay");
            } else {
                assert_eq!(w.wal_replayed, w.puts as u64, "{}: lost puts", w.policy);
            }
        }
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.005));
        assert!(md.contains("E13"));
        assert!(md.contains("recover"));
        assert!(md.contains("rebuild"));
        assert!(md.contains("| heap |"));
        assert!(md.contains("recovered"));
    }
}

//! E14 — adaptive fingerprints: sustained false-positive rate vs
//! workload skew, static vs adaptive backend.
//!
//! The paper's filters are *static*: a negative key that collides with
//! a stored fingerprint is a false positive **every time it is asked**.
//! Real read workloads repeat themselves (Zipf-skewed caches, hot-key
//! dashboards, retry storms), so the FP *rate you actually pay* is the
//! FP probability weighted by how often the colliding keys recur. The
//! adaptive backend (`filter::adaptive`) breaks exactly that product:
//! the first table-miss on a reported FP rotates the victim slot's
//! hash selector, so the *same* negative never costs a table read
//! twice.
//!
//! Three workload arms, each run against two [`StorageNode`]s that are
//! identical (capacity, fp bits, hash seed, resident keys — equal load
//! factor) except for the filter backend (`ocf` vs `adaptive`):
//!
//! 1. **Skew sweep.** Negative lookups drawn Zipf(s) from a finite
//!    universe, s ∈ {0, 0.9, 1.2} (s = 0 is uniform). A warmup window
//!    lets the adaptive filter learn, then a measurement window reads
//!    the *sustained* FP count off the node's ground-truth
//!    `fp_observed` counter. The acceptance gate asserts the adaptive
//!    arm sustains a ≥10× lower FP rate than static at s = 1.2.
//! 2. **Adversarial repeat-negative loop.** A fixed negative set
//!    hammered for `ROUNDS` rounds — the pathological client that
//!    re-asks the same missing keys forever. Static pays the full FP
//!    set every round; adaptive pays it once.
//! 3. **Zero-false-negative audit.** After every arm, every resident
//!    key is re-read and must still be found — adaptation must never
//!    turn a stored key invisible (the filter-level proptests pin the
//!    same invariant; this re-checks it end-to-end through the node).
//!
//! `KeyDist::zipf` (workload module) restricts itself to θ ∈ (0,1) for
//! its analytic approximation, so this experiment carries its own
//! exact finite-universe CDF sampler ([`ZipfCdf`]) valid for any
//! s ≥ 0.

use super::report::{f, Table};
use super::Scale;
use crate::filter::FilterBuilder;
use crate::store::{FlushPolicy, NodeConfig, StorageNode};
use crate::util::Xoshiro256pp;
use std::time::Instant;

/// Probe chunk size for the batched read path (matches E10/E13).
pub const BATCH: usize = 4096;

/// Rounds of the adversarial repeat-negative loop.
pub const ROUNDS: usize = 50;

/// Zipf exponents swept (0 = uniform).
pub const SKEWS: [f64; 3] = [0.0, 0.9, 1.2];

const SEED: u64 = 0xE14_AD_A9;
/// Negative universes live far above every resident key.
const ZIPF_NEG_BASE: u64 = 1 << 40;
const ADV_NEG_BASE: u64 = 1 << 41;

/// Exact finite-universe Zipf sampler: rank `r` (0-based) is drawn
/// with probability `(r+1)^-s / H(n,s)` via a precomputed CDF and
/// binary search. Valid for any `s >= 0`; `s = 0` is uniform.
#[derive(Debug, Clone)]
pub struct ZipfCdf {
    cdf: Vec<f64>,
}

impl ZipfCdf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "empty universe");
        assert!(s >= 0.0, "negative Zipf exponent");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for r in 0..n {
            acc += 1.0 / ((r + 1) as f64).powf(s);
            cdf.push(acc);
        }
        for c in &mut cdf {
            *c /= acc;
        }
        Self { cdf }
    }

    pub fn universe(&self) -> usize {
        self.cdf.len()
    }

    /// Draw a rank in `0..universe()`.
    #[inline]
    pub fn draw(&self, rng: &mut Xoshiro256pp) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

/// One (skew, backend) cell of the sweep.
#[derive(Debug, Clone)]
pub struct SkewArm {
    pub skew: f64,
    /// "ocf" (static) | "adaptive".
    pub backend: &'static str,
    /// Probes in the measurement window.
    pub probes: usize,
    /// Ground-truth FPs observed during warmup (the learning phase).
    pub warm_fps: u64,
    /// Ground-truth FPs observed during the measurement window — the
    /// sustained cost.
    pub fps: u64,
    /// Whole-run remap count (0 for the static backend).
    pub remapped: u64,
    /// Whole-run suppressed-probe count (0 for the static backend).
    pub suppressed: u64,
    /// Wallclock of the measurement window.
    pub secs: f64,
}

impl SkewArm {
    /// Sustained FP rate over the measurement window.
    pub fn fp_rate(&self) -> f64 {
        self.fps as f64 / self.probes.max(1) as f64
    }
}

/// One backend's run of the adversarial repeat-negative loop.
#[derive(Debug, Clone)]
pub struct AdvArm {
    pub backend: &'static str,
    pub rounds: usize,
    /// Size of the hammered negative set.
    pub set: usize,
    /// FPs observed in round 1 — the FP keys present in the set.
    pub first_round_fps: u64,
    /// FPs observed across all rounds.
    pub fps: u64,
    pub suppressed: u64,
}

/// Everything E14 measures.
#[derive(Debug, Clone)]
pub struct AdaptiveOutcome {
    pub keys: usize,
    pub universe: usize,
    pub warmup: usize,
    pub skew_arms: Vec<SkewArm>,
    pub adv_arms: Vec<AdvArm>,
}

/// Build a node with `n_keys` resident keys. Both arms get the same
/// capacity (4× keys → 25% load factor), fp bits, and hash seed, so
/// the *initial* FP key set is identical — only the backend differs.
fn mk_node(backend: &'static str, n_keys: usize) -> StorageNode {
    let mut filter = FilterBuilder::default()
        .with_initial_capacity((n_keys * 4).max(1024))
        .with_fp_bits(8)
        .with_seed(SEED);
    filter.set_backend(backend).expect("known backend");
    let mut node = StorageNode::new(NodeConfig {
        filter,
        flush: FlushPolicy::small(usize::MAX),
        ..NodeConfig::default()
    });
    let keys: Vec<u64> = (0..n_keys as u64).collect();
    for chunk in keys.chunks(BATCH) {
        for r in node.put_batch(chunk) {
            r.expect("ingest under 25% load never saturates");
        }
    }
    node
}

/// Probe `n` Zipf-drawn negatives through the node's batched read path
/// and return the ground-truth FPs observed in the window.
fn probe_window(node: &StorageNode, zipf: &ZipfCdf, rng: &mut Xoshiro256pp, n: usize) -> u64 {
    let before = node.stats.fp_observed();
    let mut buf: Vec<u64> = Vec::with_capacity(BATCH);
    let mut left = n;
    while left > 0 {
        let take = BATCH.min(left);
        buf.clear();
        for _ in 0..take {
            buf.push(ZIPF_NEG_BASE + zipf.draw(rng) as u64);
        }
        node.get_batch(&buf);
        left -= take;
    }
    node.stats.fp_observed() - before
}

/// Every resident key must still be found — adaptation never costs a
/// false negative.
fn assert_no_false_negatives(node: &StorageNode, n_keys: usize, ctx: &str) {
    let keys: Vec<u64> = (0..n_keys as u64).collect();
    for chunk in keys.chunks(BATCH) {
        for (&k, hit) in chunk.iter().zip(node.get_batch(chunk)) {
            assert!(hit, "{ctx}: false negative for resident key {k}");
        }
    }
}

/// Run the skew sweep and the adversarial loop over `n_keys` resident
/// keys, a `universe`-key negative universe, and `n_probes` measured
/// probes per arm.
pub fn measure(n_keys: usize, universe: usize, n_probes: usize) -> AdaptiveOutcome {
    // Warmup covers the universe many times over so the sustained
    // window measures the converged filter, not the learning slope.
    let warmup = universe * 32;

    let mut skew_arms = Vec::with_capacity(SKEWS.len() * 2);
    for &skew in &SKEWS {
        let zipf = ZipfCdf::new(universe, skew);
        for backend in ["ocf", "adaptive"] {
            let node = mk_node(backend, n_keys);
            // Same seed per skew → both backends see the same draws.
            let mut rng = Xoshiro256pp::new(SEED ^ skew.to_bits());
            let warm_fps = probe_window(&node, &zipf, &mut rng, warmup);
            let t0 = Instant::now();
            let fps = probe_window(&node, &zipf, &mut rng, n_probes);
            let secs = t0.elapsed().as_secs_f64();
            assert_no_false_negatives(&node, n_keys, &format!("s={skew} {backend}"));
            skew_arms.push(SkewArm {
                skew,
                backend,
                probes: n_probes,
                warm_fps,
                fps,
                remapped: node.stats.fp_remapped(),
                suppressed: node.fp_suppressed(),
                secs,
            });
        }
    }

    // Acceptance gate: ≥10× lower sustained FP rate at s = 1.2 (the
    // repeated-negative skew the tentpole targets). The `.max(100)`
    // floor keeps tiny smoke runs out of Poisson noise.
    let static_12 = skew_arms
        .iter()
        .find(|a| a.backend == "ocf" && (a.skew - 1.2).abs() < 1e-9)
        .expect("static s=1.2 arm");
    let adaptive_12 = skew_arms
        .iter()
        .find(|a| a.backend == "adaptive" && (a.skew - 1.2).abs() < 1e-9)
        .expect("adaptive s=1.2 arm");
    assert!(
        adaptive_12.fps * 10 <= static_12.fps.max(100),
        "adaptive must sustain a >=10x lower FP rate at s=1.2: adaptive={} static={}",
        adaptive_12.fps,
        static_12.fps,
    );

    // Adversarial loop: a fixed negative set re-asked ROUNDS times.
    let adv_set = (n_probes / 50).clamp(2_048, 8_192);
    let set: Vec<u64> = (0..adv_set as u64).map(|i| ADV_NEG_BASE + i).collect();
    let mut adv_arms = Vec::with_capacity(2);
    for backend in ["ocf", "adaptive"] {
        let node = mk_node(backend, n_keys);
        let before = node.stats.fp_observed();
        let mut first_round_fps = 0;
        for round in 0..ROUNDS {
            let b = node.stats.fp_observed();
            for chunk in set.chunks(BATCH) {
                node.get_batch(chunk);
            }
            if round == 0 {
                first_round_fps = node.stats.fp_observed() - b;
            }
        }
        let fps = node.stats.fp_observed() - before;
        assert_no_false_negatives(&node, n_keys, &format!("adversarial {backend}"));
        adv_arms.push(AdvArm {
            backend,
            rounds: ROUNDS,
            set: adv_set,
            first_round_fps,
            fps,
            suppressed: node.fp_suppressed(),
        });
    }
    // Static re-pays the set's FP keys every round; adaptive pays them
    // ~once (rare ambiguous slots — two fp-matching candidates — stay
    // static, hence the conservative 2× bound; the table shows the
    // real ratio, typically ≈ ROUNDS×).
    assert!(
        adv_arms[1].fps * 2 <= adv_arms[0].fps.max(ROUNDS as u64),
        "adaptive must beat static on the repeat-negative loop: adaptive={} static={}",
        adv_arms[1].fps,
        adv_arms[0].fps,
    );

    AdaptiveOutcome {
        keys: n_keys,
        universe,
        warmup,
        skew_arms,
        adv_arms,
    }
}

/// Render the two E14 tables.
pub fn render(title: impl Into<String>, o: &AdaptiveOutcome) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        title,
        &[
            "skew",
            "backend",
            "warm FPs",
            "window FPs",
            "FP/Mprobe",
            "remapped",
            "suppressed",
            "vs static",
        ],
    );
    for a in &o.skew_arms {
        let ratio = if a.backend == "adaptive" {
            o.skew_arms
                .iter()
                .find(|s| s.backend == "ocf" && (s.skew - a.skew).abs() < 1e-9)
                .map(|s| format!("{}x", f(s.fps as f64 / a.fps.max(1) as f64, 1)))
                .unwrap_or_default()
        } else {
            String::new()
        };
        t.row(&[
            f(a.skew, 1),
            a.backend.to_string(),
            a.warm_fps.to_string(),
            a.fps.to_string(),
            f(a.fp_rate() * 1e6, 1),
            a.remapped.to_string(),
            a.suppressed.to_string(),
            ratio,
        ]);
    }
    t.note(format!(
        "{} resident keys, {}-key negative universe, {}-probe warmup then \
         {}-probe measurement window; both backends share capacity, fp bits, \
         hash seed and draw sequence (equal load factor, identical initial FP \
         set). 'warm FPs' is the learning cost; 'window FPs' is the sustained \
         cost; 'remapped'/'suppressed' are whole-run adaptive counters \
         (identically 0 for static).",
        o.keys,
        o.universe,
        o.warmup,
        o.skew_arms.first().map_or(0, |a| a.probes),
    ));
    out.push_str(&t.markdown());
    out.push('\n');

    let mut t = Table::new(
        format!(
            "E14 — adversarial repeat-negative loop ({} negatives × {} rounds)",
            o.adv_arms.first().map_or(0, |a| a.set),
            ROUNDS,
        ),
        &["backend", "round-1 FPs", "total FPs", "suppressed", "vs static"],
    );
    for a in &o.adv_arms {
        let ratio = if a.backend == "adaptive" {
            o.adv_arms
                .iter()
                .find(|s| s.backend == "ocf")
                .map(|s| format!("{}x", f(s.fps as f64 / a.fps.max(1) as f64, 1)))
                .unwrap_or_default()
        } else {
            String::new()
        };
        t.row(&[
            a.backend.to_string(),
            a.first_round_fps.to_string(),
            a.fps.to_string(),
            a.suppressed.to_string(),
            ratio,
        ]);
    }
    t.note(
        "The same missing keys re-asked every round. Static pays the set's FP \
         keys every single round; adaptive pays each once (round-1 ≈ total), \
         then the remapped slots suppress the repeats. Zero false negatives \
         asserted for every arm after every workload.",
    );
    out.push_str(&t.markdown());
    out
}

/// The experiment driver (paper scale: 200k resident keys, 100k-key
/// negative universe, 1M measured probes per arm).
pub fn run(scale: Scale) -> String {
    let n_keys = scale.n(200_000, 4_096);
    let universe = scale.n(100_000, 2_000);
    let n_probes = scale.n(1_000_000, 20_000);
    let outcome = measure(n_keys, universe, n_probes);
    render(
        format!(
            "E14 — sustained FP rate vs workload skew, static vs adaptive ({n_keys} keys)"
        ),
        &outcome,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_cdf_is_exact_and_skewed() {
        let mut rng = Xoshiro256pp::new(7);
        // s = 0 is uniform: every rank reachable, roughly flat.
        let z = ZipfCdf::new(100, 0.0);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.draw(&mut rng)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 50), "uniform draw starved a rank");
        // s = 1.2 concentrates on the head: rank 0 beats rank 50 by a
        // wide margin.
        let z = ZipfCdf::new(100, 1.2);
        let mut counts = [0u32; 100];
        for _ in 0..20_000 {
            counts[z.draw(&mut rng)] += 1;
        }
        assert!(counts[0] > 10 * counts[50].max(1), "{:?}", &counts[..5]);
    }

    #[test]
    fn report_renders() {
        // Floors: 4096 keys, 2000-key universe, 20k-probe windows. The
        // acceptance asserts (>=10x at s=1.2, adversarial win, zero
        // false negatives) run inside measure().
        let md = run(Scale(0.002));
        assert!(md.contains("E14"));
        assert!(md.contains("| adaptive |"));
        assert!(md.contains("1.2"));
        assert!(md.contains("repeat-negative"));
    }
}

//! E10 — the memory-level-parallel probe engine: scalar vs batched.
//!
//! Measures lookup throughput of the scalar op-at-a-time path against
//! the prefetch-pipelined `contains_batch` engine on both bucket-table
//! backends ([`FlatTable`] one-`u32`-per-slot, [`PackedTable`] SWAR
//! bit-packed), on negative- and positive-lookup workloads. Negative
//! lookups are the paper's money shot (the read path's short-circuit)
//! and the worst case for a scalar probe: primary miss → a second
//! dependent cache miss on the alternate bucket. The batched engine
//! overlaps ~[`PREFETCH_DEPTH`](crate::filter::PREFETCH_DEPTH) of
//! those misses.
//!
//! `measure()` is shared with `benches/probe_throughput.rs`, which
//! emits the `BENCH_probe.json` trajectory point.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{BucketTable, CuckooFilter, CuckooParams, FlatTable, MembershipFilter, PackedTable};
use std::time::Instant;

/// One measured arm.
#[derive(Debug, Clone)]
pub struct ProbePoint {
    /// Bucket-table backend ("flat" | "packed").
    pub backend: &'static str,
    /// Probe mode ("scalar" | "batched").
    pub mode: &'static str,
    /// Workload ("neg" | "pos").
    pub workload: &'static str,
    /// Resident keys in the filter.
    pub keys: usize,
    /// Probes issued.
    pub probes: usize,
    /// Wallclock of the probe loop.
    pub secs: f64,
    /// Observed hits (sanity anchor: scalar and batched must agree).
    pub hits: usize,
}

impl ProbePoint {
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.probes as f64 / self.secs / 1e6
        }
    }
}

/// Probe chunk size for the batched arms: large enough to amortize the
/// bulk hash + pipeline warmup, small enough to model request batches.
pub const BATCH: usize = 4096;

fn build<T: BucketTable>(n_keys: usize) -> CuckooFilter<T> {
    let mut f = CuckooFilter::<T>::new(CuckooParams {
        capacity: n_keys * 2, // paper-recommended 2× headroom
        ..CuckooParams::default()
    });
    for k in 0..n_keys as u64 {
        f.insert(k).expect("insert at 0.5 load cannot fail");
    }
    f
}

fn run_arms<T: BucketTable>(
    backend: &'static str,
    n_keys: usize,
    n_probes: usize,
    out: &mut Vec<ProbePoint>,
) {
    let filter = build::<T>(n_keys);
    // negative probes: disjoint key range; positive probes: residents
    let neg: Vec<u64> = (0..n_probes as u64).map(|i| (1u64 << 40) + i).collect();
    let pos: Vec<u64> = (0..n_probes as u64).map(|i| i % n_keys as u64).collect();

    for (workload, probes) in [("neg", &neg), ("pos", &pos)] {
        // scalar: hash + two dependent bucket reads per key
        let t0 = Instant::now();
        let mut hits = 0usize;
        for &k in probes.iter() {
            hits += filter.contains(k) as usize;
        }
        let scalar_secs = t0.elapsed().as_secs_f64();
        out.push(ProbePoint {
            backend,
            mode: "scalar",
            workload,
            keys: n_keys,
            probes: probes.len(),
            secs: scalar_secs,
            hits,
        });

        // batched: bulk hash + prefetch-pipelined probes per chunk
        let t0 = Instant::now();
        let mut bhits = 0usize;
        for chunk in probes.chunks(BATCH) {
            let r = filter.contains_batch(chunk);
            bhits += r.iter().filter(|&&h| h).count();
        }
        let batched_secs = t0.elapsed().as_secs_f64();
        assert_eq!(hits, bhits, "{backend}/{workload}: batched answers diverged");
        out.push(ProbePoint {
            backend,
            mode: "batched",
            workload,
            keys: n_keys,
            probes: probes.len(),
            secs: batched_secs,
            hits: bhits,
        });
    }
}

/// Measure all arms: {flat, packed} × {scalar, batched} × {neg, pos}.
pub fn measure(n_keys: usize, n_probes: usize) -> Vec<ProbePoint> {
    let mut out = Vec::with_capacity(8);
    run_arms::<FlatTable>("flat", n_keys, n_probes, &mut out);
    run_arms::<PackedTable>("packed", n_keys, n_probes, &mut out);
    out
}

/// Speedup of the batched arm over its scalar twin (same backend and
/// workload); `None` if either arm is missing.
pub fn speedup(points: &[ProbePoint], backend: &str, workload: &str) -> Option<f64> {
    let find = |mode: &str| {
        points
            .iter()
            .find(|p| p.backend == backend && p.workload == workload && p.mode == mode)
    };
    let (s, b) = (find("scalar")?, find("batched")?);
    if s.mops() > 0.0 {
        Some(b.mops() / s.mops())
    } else {
        None
    }
}

/// Render measured points as the scalar-vs-batched markdown table
/// (shared by the experiment driver and the `probe_throughput` bench
/// so their outputs cannot drift).
pub fn render(title: impl Into<String>, points: &[ProbePoint]) -> String {
    let mut table = Table::new(title, &["backend", "workload", "mode", "Mops/s", "speedup"]);
    for p in points {
        let sp = if p.mode == "batched" {
            speedup(points, p.backend, p.workload)
                .map(|s| format!("{}x", f(s, 2)))
                .unwrap_or_default()
        } else {
            String::new()
        };
        table.row(&[
            p.backend.to_string(),
            p.workload.to_string(),
            p.mode.to_string(),
            f(p.mops(), 2),
            sp,
        ]);
    }
    table.note(
        "batched = bulk hash + depth-8 prefetch pipeline (alt bucket prefetched \
         only on primary miss); scalar = hash + 2 dependent bucket reads per key. \
         Negative lookups are the read path's short-circuit workload.",
    );
    table.markdown()
}

/// The experiment driver (paper scale: 1M resident keys, 1M probes).
pub fn run(scale: Scale) -> String {
    let n_keys = scale.n(1_000_000, 20_000);
    let n_probes = scale.n(1_000_000, 20_000);
    let points = measure(n_keys, n_probes);
    render(
        format!("E10 — probe engine scalar vs batched ({n_keys} keys, {n_probes} probes)"),
        &points,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_agree_and_cover_grid() {
        let points = measure(4_000, 4_000);
        assert_eq!(points.len(), 8);
        for backend in ["flat", "packed"] {
            for workload in ["neg", "pos"] {
                let arms: Vec<_> = points
                    .iter()
                    .filter(|p| p.backend == backend && p.workload == workload)
                    .collect();
                assert_eq!(arms.len(), 2, "{backend}/{workload}");
                assert_eq!(arms[0].hits, arms[1].hits, "{backend}/{workload}");
                assert!(speedup(&points, backend, workload).is_some());
            }
        }
        // positive probes must actually hit
        assert!(points
            .iter()
            .filter(|p| p.workload == "pos")
            .all(|p| p.hits == p.probes));
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.002));
        assert!(md.contains("E10"));
        assert!(md.contains("batched"));
        assert!(md.contains("| flat |"));
        assert!(md.contains("| packed |"));
    }
}

//! E10 — the memory-level-parallel probe engine: scalar vs batched,
//! now measured **through the capability traits**.
//!
//! Measures lookup throughput of the scalar op-at-a-time path against
//! the batched [`BatchedFilter`] path on three backends —
//! [`CuckooFilter<FlatTable>`], [`CuckooFilter<PackedTable>`] (both
//! engine-overridden) and [`BloomFilter`] (default scalar batch impls —
//! the baseline the trait redesign gave batch APIs for free) — on
//! negative- and positive-lookup workloads. Negative lookups are the
//! paper's money shot (the read path's short-circuit) and the worst
//! case for a scalar probe: primary miss → a second dependent cache
//! miss on the alternate bucket. The batched engine overlaps
//! ~[`PREFETCH_DEPTH`](crate::filter::PREFETCH_DEPTH) of those misses.
//!
//! The cuckoo backends additionally run a **`batched-dyn`** arm — the
//! identical batched probe driven through `&dyn BatchedFilter` — so
//! every trajectory point carries direct evidence of what the v2 trait
//! indirection costs (expected: nothing measurable; the virtual call is
//! per *batch*, the probes inside are monomorphic).
//!
//! `measure()` is shared with `benches/probe_throughput.rs`, which
//! emits the `BENCH_probe.json` trajectory point.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{
    BatchedFilter, BloomFilter, CuckooFilter, CuckooParams, FlatTable, MembershipFilter,
    PackedTable, ProbeSession,
};
use std::time::Instant;

/// One measured arm.
#[derive(Debug, Clone)]
pub struct ProbePoint {
    /// Backend ("flat" | "packed" | "bloom").
    pub backend: &'static str,
    /// Probe mode ("scalar" | "batched" | "batched-dyn").
    pub mode: &'static str,
    /// Workload ("neg" | "pos").
    pub workload: &'static str,
    /// Probe kernel the bucket scans dispatched to ("-" for backends
    /// outside the kernel layer, e.g. bloom).
    pub kernel: &'static str,
    /// Resident keys in the filter.
    pub keys: usize,
    /// Probes issued.
    pub probes: usize,
    /// Wallclock of the probe loop.
    pub secs: f64,
    /// Observed hits (sanity anchor: all modes must agree).
    pub hits: usize,
}

impl ProbePoint {
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.probes as f64 / self.secs / 1e6
        }
    }
}

/// Probe chunk size for the batched arms: large enough to amortize the
/// bulk hash + pipeline warmup, small enough to model request batches.
pub const BATCH: usize = 4096;

/// Time the scalar loop and the batched loop (through `F`'s
/// `BatchedFilter` impl, one reused [`ProbeSession`] — the zero-alloc
/// pattern) over one probe set; push both points.
fn time_arms<F: BatchedFilter + ?Sized>(
    filter: &F,
    backend: &'static str,
    workload: &'static str,
    kernel: &'static str,
    n_keys: usize,
    probes: &[u64],
    out: &mut Vec<ProbePoint>,
) -> usize {
    // scalar: hash + two dependent bucket reads per key
    let t0 = Instant::now();
    let mut hits = 0usize;
    for &k in probes {
        hits += filter.contains(k) as usize;
    }
    let scalar_secs = t0.elapsed().as_secs_f64();
    out.push(ProbePoint {
        backend,
        mode: "scalar",
        workload,
        kernel,
        keys: n_keys,
        probes: probes.len(),
        secs: scalar_secs,
        hits,
    });

    // batched: bulk hash + prefetch-pipelined probes per chunk
    let mut session = ProbeSession::with_capacity(BATCH);
    let mut answers: Vec<bool> = Vec::with_capacity(BATCH);
    let t0 = Instant::now();
    let mut bhits = 0usize;
    for chunk in probes.chunks(BATCH) {
        answers.clear();
        filter.contains_batch_into(chunk, &mut session, &mut answers);
        bhits += answers.iter().filter(|&&h| h).count();
    }
    let batched_secs = t0.elapsed().as_secs_f64();
    assert_eq!(hits, bhits, "{backend}/{workload}: batched answers diverged");
    out.push(ProbePoint {
        backend,
        mode: "batched",
        workload,
        kernel,
        keys: n_keys,
        probes: probes.len(),
        secs: batched_secs,
        hits: bhits,
    });
    hits
}

fn build_cuckoo<T: crate::filter::BucketTable>(n_keys: usize) -> CuckooFilter<T> {
    let mut f = CuckooFilter::<T>::new(CuckooParams {
        capacity: n_keys * 2, // paper-recommended 2× headroom
        ..CuckooParams::default()
    });
    for k in 0..n_keys as u64 {
        f.insert(k).expect("insert at 0.5 load cannot fail");
    }
    f
}

fn run_cuckoo_arms<T: crate::filter::BucketTable + 'static>(
    backend: &'static str,
    n_keys: usize,
    n_probes: usize,
    out: &mut Vec<ProbePoint>,
) {
    let filter = build_cuckoo::<T>(n_keys);
    // the runtime-dispatched kernel the table's bucket scans route to
    let kernel = filter.kernel().name();
    // negative probes: disjoint key range; positive probes: residents
    let neg: Vec<u64> = (0..n_probes as u64).map(|i| (1u64 << 40) + i).collect();
    let pos: Vec<u64> = (0..n_probes as u64).map(|i| i % n_keys as u64).collect();

    for (workload, probes) in [("neg", &neg), ("pos", &pos)] {
        let hits = time_arms(&filter, backend, workload, kernel, n_keys, probes, out);

        // batched through the trait object: same engine, virtual
        // dispatch per batch — the trait-indirection cost probe
        let dyn_filter: &dyn BatchedFilter = &filter;
        let mut session = ProbeSession::with_capacity(BATCH);
        let mut answers: Vec<bool> = Vec::with_capacity(BATCH);
        let t0 = Instant::now();
        let mut dhits = 0usize;
        for chunk in probes.chunks(BATCH) {
            answers.clear();
            dyn_filter.contains_batch_into(chunk, &mut session, &mut answers);
            dhits += answers.iter().filter(|&&h| h).count();
        }
        let dyn_secs = t0.elapsed().as_secs_f64();
        assert_eq!(hits, dhits, "{backend}/{workload}: dyn answers diverged");
        out.push(ProbePoint {
            backend,
            mode: "batched-dyn",
            workload,
            kernel,
            keys: n_keys,
            probes: probes.len(),
            secs: dyn_secs,
            hits: dhits,
        });
    }
}

fn run_bloom_arms(n_keys: usize, n_probes: usize, out: &mut Vec<ProbePoint>) {
    let mut f = BloomFilter::new(n_keys, 0.01, CuckooParams::default().seed);
    for k in 0..n_keys as u64 {
        f.insert(k).expect("bloom insert is infallible");
    }
    let neg: Vec<u64> = (0..n_probes as u64).map(|i| (1u64 << 40) + i).collect();
    let pos: Vec<u64> = (0..n_probes as u64).map(|i| i % n_keys as u64).collect();
    for (workload, probes) in [("neg", &neg), ("pos", &pos)] {
        // bloom sits outside the kernel layer (default scalar batch
        // impls) — recorded as "-" in the trajectory JSON
        time_arms(&f, "bloom", workload, "-", n_keys, probes, out);
    }
}

/// Measure all arms: {flat, packed} × {scalar, batched, batched-dyn}
/// × {neg, pos} plus bloom × {scalar, batched} × {neg, pos} — 16
/// points.
pub fn measure(n_keys: usize, n_probes: usize) -> Vec<ProbePoint> {
    let mut out = Vec::with_capacity(16);
    run_cuckoo_arms::<FlatTable>("flat", n_keys, n_probes, &mut out);
    run_cuckoo_arms::<PackedTable>("packed", n_keys, n_probes, &mut out);
    run_bloom_arms(n_keys, n_probes, &mut out);
    out
}

/// Speedup of the batched arm over its scalar twin (same backend and
/// workload); `None` if either arm is missing.
pub fn speedup(points: &[ProbePoint], backend: &str, workload: &str) -> Option<f64> {
    ratio(points, backend, workload, "batched", "scalar")
}

/// `batched-dyn` ÷ `batched` throughput — the trait-indirection cost
/// probe (≈ 1.0 means the v2 dispatch is free); `None` if either arm
/// is missing.
pub fn dyn_overhead(points: &[ProbePoint], backend: &str, workload: &str) -> Option<f64> {
    ratio(points, backend, workload, "batched-dyn", "batched")
}

fn ratio(
    points: &[ProbePoint],
    backend: &str,
    workload: &str,
    num: &str,
    den: &str,
) -> Option<f64> {
    let find = |mode: &str| {
        points
            .iter()
            .find(|p| p.backend == backend && p.workload == workload && p.mode == mode)
    };
    let (d, n) = (find(den)?, find(num)?);
    if d.mops() > 0.0 {
        Some(n.mops() / d.mops())
    } else {
        None
    }
}

/// Render measured points as the scalar-vs-batched markdown table
/// (shared by the experiment driver and the `probe_throughput` bench
/// so their outputs cannot drift).
pub fn render(title: impl Into<String>, points: &[ProbePoint]) -> String {
    let mut table = Table::new(title, &["backend", "workload", "mode", "Mops/s", "vs scalar"]);
    for p in points {
        let sp = if p.mode == "scalar" {
            String::new()
        } else {
            ratio(points, p.backend, p.workload, p.mode, "scalar")
                .map(|s| format!("{}x", f(s, 2)))
                .unwrap_or_default()
        };
        table.row(&[
            p.backend.to_string(),
            p.workload.to_string(),
            p.mode.to_string(),
            f(p.mops(), 2),
            sp,
        ]);
    }
    table.note(
        "batched = bulk hash + depth-8 prefetch pipeline (alt bucket prefetched \
         only on primary miss); batched-dyn = the same through &dyn BatchedFilter \
         (trait-indirection probe); bloom rides the default scalar batch impls. \
         Negative lookups are the read path's short-circuit workload.",
    );
    table.markdown()
}

/// The experiment driver (paper scale: 1M resident keys, 1M probes).
pub fn run(scale: Scale) -> String {
    let n_keys = scale.n(1_000_000, 20_000);
    let n_probes = scale.n(1_000_000, 20_000);
    let points = measure(n_keys, n_probes);
    render(
        format!("E10 — probe engine scalar vs batched ({n_keys} keys, {n_probes} probes)"),
        &points,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arms_agree_and_cover_grid() {
        let points = measure(4_000, 4_000);
        assert_eq!(points.len(), 16);
        for backend in ["flat", "packed"] {
            for workload in ["neg", "pos"] {
                let arms: Vec<_> = points
                    .iter()
                    .filter(|p| p.backend == backend && p.workload == workload)
                    .collect();
                assert_eq!(arms.len(), 3, "{backend}/{workload}");
                assert!(
                    arms.windows(2).all(|w| w[0].hits == w[1].hits),
                    "{backend}/{workload}"
                );
                assert!(speedup(&points, backend, workload).is_some());
                assert!(dyn_overhead(&points, backend, workload).is_some());
            }
        }
        for workload in ["neg", "pos"] {
            let arms: Vec<_> = points
                .iter()
                .filter(|p| p.backend == "bloom" && p.workload == workload)
                .collect();
            assert_eq!(arms.len(), 2, "bloom/{workload}");
            assert_eq!(arms[0].hits, arms[1].hits, "bloom/{workload}");
        }
        // positive probes must actually hit (all three backends have
        // zero false negatives)
        assert!(points
            .iter()
            .filter(|p| p.workload == "pos")
            .all(|p| p.hits == p.probes));
        // kernel attribution: cuckoo arms carry the dispatched kernel,
        // bloom (outside the kernel layer) is marked "-"
        assert!(points
            .iter()
            .all(|p| (p.backend == "bloom") == (p.kernel == "-")));
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.002));
        assert!(md.contains("E10"));
        assert!(md.contains("batched"));
        assert!(md.contains("batched-dyn"));
        assert!(md.contains("| flat |"));
        assert!(md.contains("| packed |"));
        assert!(md.contains("| bloom |"));
    }
}

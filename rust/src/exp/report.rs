//! Markdown/CSV table rendering for experiment reports.

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    notes: Vec<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from Display items.
    pub fn rowd<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let v: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&v)
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a markdown table with a heading.
    pub fn markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    /// Render as CSV (header + rows).
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (exp reports).
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown_and_csv() {
        let mut t = Table::new("T", &["a", "b"]);
        t.rowd(&[1, 2]).rowd(&[3, 4]).note("shape holds");
        let md = t.markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("> shape holds"));
        let csv = t.csv();
        assert_eq!(csv, "a,b\n1,2\n3,4\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("T", &["a", "b"]).row(&["1".into()]);
    }

    #[test]
    fn float_format() {
        assert_eq!(f(1.23456, 2), "1.23");
    }
}

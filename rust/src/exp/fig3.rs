//! E3 — Fig 3: capacity trendlines of EOF vs PRE over trials.
//!
//! Same drive as Fig 2 plus a delete phase, recording capacity `c(t)`:
//! PRE's doubling staircase overshoots demand and shrinks in slow 10%
//! steps; EOF's EWMA growth tracks demand ("EOF tends to maintain
//! optimality by utilizing maximum possible space").

use super::report::{f, Table};
use super::Scale;
use crate::filter::{MembershipFilter, Mode, Ocf, OcfConfig};

const FULL_TRIALS: usize = 2_500;
const INSERTS_PER_TRIAL: usize = 400;

/// Capacity trace point.
#[derive(Debug, Clone)]
pub struct TrendPoint {
    pub trial: usize,
    pub len: usize,
    pub capacity: usize,
    pub occupancy: f64,
}

/// Drive inserts then deletes; record capacity every `stride` trials.
pub fn run_arm(mode: Mode, trials: usize, stride: usize, seed: u64) -> Vec<TrendPoint> {
    let mut filter = Ocf::new(OcfConfig {
        mode,
        initial_capacity: 4096,
        seed,
        ..OcfConfig::default()
    });
    let mut out = Vec::new();
    let mut next_key = 0u64;
    // half inserts, half deletes: the delete phase fully drains the
    // filter so both shrink paths (PRE's 10% steps, EOF's c·α) show up
    // in the trendline.
    let insert_trials = trials / 2;
    for trial in 0..trials {
        if trial < insert_trials {
            for _ in 0..INSERTS_PER_TRIAL {
                filter.insert(next_key).expect("dynamic arm insert");
                next_key += 1;
            }
        } else {
            // delete phase: drain the oldest keys
            let start = (trial - insert_trials) as u64 * INSERTS_PER_TRIAL as u64;
            for i in 0..INSERTS_PER_TRIAL as u64 {
                let k = start + i;
                if k < next_key {
                    filter.delete(k);
                }
            }
        }
        if trial % stride == 0 || trial == trials - 1 {
            out.push(TrendPoint {
                trial,
                len: filter.len(),
                capacity: filter.capacity(),
                occupancy: filter.occupancy(),
            });
        }
    }
    out
}

/// Full experiment.
pub fn run(scale: Scale) -> String {
    let trials = scale.n(FULL_TRIALS, 90);
    let stride = (trials / 15).max(1);
    let eof = run_arm(Mode::Eof, trials, stride, 0xF16_3);
    let pre = run_arm(Mode::Pre, trials, stride, 0xF16_3);

    let mut t = Table::new(
        format!("E3 / Fig 3 — capacity trendlines ({trials} trials; inserts then deletes)"),
        &[
            "Trial",
            "Live keys",
            "EOF capacity",
            "PRE capacity",
            "EOF occ",
            "PRE occ",
            "PRE/EOF cap",
        ],
    );
    for i in 0..eof.len() {
        t.row(&[
            eof[i].trial.to_string(),
            eof[i].len.to_string(),
            eof[i].capacity.to_string(),
            pre[i].capacity.to_string(),
            f(eof[i].occupancy, 2),
            f(pre[i].occupancy, 2),
            f(pre[i].capacity as f64 / eof[i].capacity as f64, 2),
        ]);
    }
    // Trendline comparison over the *insert phase* (the delete phase is
    // mostly quiet-band for both arms, which dilutes the growth-dynamics
    // signal the paper's figure is about). Peak ratios at one stop point
    // are staircase-luck: PRE's overshoot at any instant is uniform in
    // [1, 2]×, EOF's in [1, 1+α]× — the mean is the robust statistic.
    let half = eof.len() / 2;
    let mean_occ = |v: &[TrendPoint]| {
        let pts = &v[v.len().min(2)..half.max(3)];
        pts.iter().map(|p| p.occupancy).sum::<f64>() / pts.len().max(1) as f64
    };
    let peak_eof = eof.iter().map(|p| p.capacity).max().unwrap();
    let peak_pre = pre.iter().map(|p| p.capacity).max().unwrap();
    t.note(format!(
        "shape check (insert phase): mean occupancy EOF {:.2} vs PRE {:.2} \
         (paper trendline: EOF 'maintains optimality by utilizing maximum \
         possible space'; PRE staircase overshoots — 'consumes almost twice \
         as much space' at 1M). peak capacity PRE/EOF at this scale = {:.2}× \
         (single-point peaks carry staircase variance; run --scale 1.0 for \
         the paper's regime).",
        mean_occ(&eof),
        mean_occ(&pre),
        peak_pre as f64 / peak_eof as f64,
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eof_tracks_demand_tighter_than_pre() {
        let eof = run_arm(Mode::Eof, 120, 1, 7);
        let pre = run_arm(Mode::Pre, 120, 1, 7);
        let mean_occ = |v: &[TrendPoint]| {
            // skip warmup trials where both are at min capacity
            let tail = &v[20..];
            tail.iter().map(|p| p.occupancy).sum::<f64>() / tail.len() as f64
        };
        assert!(
            mean_occ(&eof) > mean_occ(&pre),
            "EOF must run denser: {} vs {}",
            mean_occ(&eof),
            mean_occ(&pre)
        );
    }

    #[test]
    fn capacity_never_below_live_keys() {
        for mode in [Mode::Eof, Mode::Pre] {
            for p in run_arm(mode, 90, 1, 9) {
                assert!(p.capacity >= p.len, "{mode:?}: c={} s={}", p.capacity, p.len);
                assert!(p.occupancy <= 0.91, "{mode:?}: occ={}", p.occupancy);
            }
        }
    }

    #[test]
    fn delete_phase_shrinks_both() {
        for mode in [Mode::Eof, Mode::Pre] {
            let pts = run_arm(mode, 150, 1, 11);
            let peak = pts.iter().map(|p| p.capacity).max().unwrap();
            let last = pts.last().unwrap().capacity;
            assert!(last < peak, "{mode:?} must shrink: peak={peak} last={last}");
        }
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.04));
        assert!(md.contains("Fig 3"));
        assert!(md.contains("trendline"));
    }
}

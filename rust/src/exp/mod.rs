//! Experiment drivers: one module per paper table/figure + extensions.
//!
//! | id | paper artifact | module |
//! |----|----------------|--------|
//! | E1 | Table I (occupancy & false positives, EOF vs PRE) | [`table1`] |
//! | E2 | Fig 2 (throughput: EOF vs PRE vs traditional)     | [`fig2`]   |
//! | E3 | Fig 3 (capacity trendlines EOF vs PRE)            | [`fig3`]   |
//! | E4 | §III key-size sweep 10k…1M                        | [`sweep`]  |
//! | E5 | §II/§IV safety: false negatives & unsafe deletes  | [`safety`] |
//! | E6 | §I.A burst tolerance / premature flushes          | [`burst`]  |
//! | E7 | §I.B cartesian-product query fan-out              | [`cartesian`] |
//! | E8 | ablations (g, fp_bits, k-band)                    | [`ablation`] |
//! | E9 | sharded concurrent front-end scaling              | [`sharded`] |
//! | E10 | probe engine: scalar vs batched lookups          | [`probe`]  |
//! | E11 | pooled ingest: persistent workers vs scoped fan-out | [`pool`] |
//! | E12 | SIMD probe kernels × load factor                  | [`kernel`] |
//! | E13 | persistent tier: restart + mmap-vs-heap probes    | [`persist`] |
//! | E14 | adaptive fingerprints: sustained FP rate vs skew  | [`adaptive`] |
//! | E15 | chaos: availability & latency vs replica faults   | [`chaos`]  |
//! | E16 | membership: availability & transfer effort vs faults | [`membership`] |
//!
//! Every driver takes a [`Scale`] so the same code serves quick checks
//! (`--scale 0.01`), CI, and full paper-scale runs, and returns a
//! markdown report (printed by the CLI; benches re-use the same
//! functions).

pub mod ablation;
pub mod adaptive;
pub mod burst;
pub mod cartesian;
pub mod chaos;
pub mod fig2;
pub mod fig3;
pub mod kernel;
pub mod membership;
pub mod persist;
pub mod pool;
pub mod probe;
pub mod report;
pub mod safety;
pub mod sharded;
pub mod sweep;
pub mod table1;

pub use report::Table;

/// Scales every experiment's workload (1.0 = paper scale).
#[derive(Debug, Clone, Copy)]
pub struct Scale(pub f64);

impl Scale {
    pub fn full() -> Self {
        Scale(1.0)
    }

    /// Scale an op/key count, keeping a sane floor.
    pub fn n(&self, full: usize, floor: usize) -> usize {
        ((full as f64 * self.0) as usize).max(floor)
    }
}

/// Run one experiment (or `all`) by name; returns the markdown report.
pub fn run(name: &str, scale: Scale) -> Result<String, String> {
    let one = |n: &str| -> Result<String, String> {
        match n {
            "table1" => Ok(table1::run(scale)),
            "fig2" => Ok(fig2::run(scale)),
            "fig3" => Ok(fig3::run(scale)),
            "sweep" => Ok(sweep::run(scale)),
            "safety" => Ok(safety::run(scale)),
            "burst" => Ok(burst::run(scale)),
            "cartesian" => Ok(cartesian::run(scale)),
            "ablation" => Ok(ablation::run(scale)),
            "sharded" => Ok(sharded::run(scale)),
            "probe" => Ok(probe::run(scale)),
            "pool" => Ok(pool::run(scale)),
            "kernel" => Ok(kernel::run(scale)),
            "persist" => Ok(persist::run(scale)),
            "adaptive" => Ok(adaptive::run(scale)),
            "chaos" => Ok(chaos::run(scale)),
            "membership" => Ok(membership::run(scale)),
            other => Err(format!(
                "unknown experiment '{other}' (try: table1 fig2 fig3 sweep safety burst cartesian ablation sharded probe pool kernel persist adaptive chaos membership all)"
            )),
        }
    };
    if name == "all" {
        let mut out = String::new();
        for n in [
            "table1",
            "fig2",
            "fig3",
            "sweep",
            "safety",
            "burst",
            "cartesian",
            "ablation",
            "sharded",
            "probe",
            "pool",
            "kernel",
            "persist",
            "adaptive",
            "chaos",
            "membership",
        ] {
            out.push_str(&one(n)?);
            out.push('\n');
        }
        Ok(out)
    } else {
        one(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_math() {
        assert_eq!(Scale(1.0).n(1000, 10), 1000);
        assert_eq!(Scale(0.001).n(1000, 10), 10);
        assert_eq!(Scale(0.5).n(1000, 10), 500);
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run("nope", Scale(0.01)).is_err());
    }
}

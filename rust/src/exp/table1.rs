//! E1 — Table I: occupancy and average false positives, EOF vs PRE.
//!
//! Protocol (reconstructed from §III): insert N keys (paper: 1M; the
//! prose for the table says 100k — we run the scaled N and report
//! both the per-round FP count and the rate), then probe `ROUNDS`
//! batches of held-out keys and report the mean false-positive count
//! per round plus the final occupancy.
//!
//! Expected shape (paper Table I): EOF occupancy ≫ PRE (≈0.74 vs
//! ≈0.47 — PRE's doubling overshoots, EOF tracks demand); PRE slightly
//! fewer FPs *because* it wastes ~2× the memory (FPR ∝ occupancy).

use super::report::{f, Table};
use super::Scale;
use crate::filter::{MembershipFilter, Mode, Ocf, OcfConfig};

const FULL_KEYS: usize = 1_000_000;
const ROUNDS: usize = 100;
const PROBES_PER_ROUND: usize = 10_000;

/// One arm's measurements.
#[derive(Debug, Clone)]
pub struct Arm {
    pub mode: Mode,
    pub occupancy: f64,
    /// Mean occupancy sampled along the insert trajectory — the
    /// staircase-robust version of the single-point number (PRE's final
    /// occupancy depends on where N lands on its doubling staircase;
    /// the paper's 1M lands at 0.477).
    pub mean_occupancy: f64,
    pub avg_false_positives: f64,
    pub fp_rate: f64,
    pub capacity: usize,
    pub memory_bytes: usize,
    pub resizes: u64,
}

/// Run one arm at `n` keys.
pub fn run_arm(mode: Mode, n: usize, fp_bits: u32, seed: u64) -> Arm {
    let mut filter = Ocf::new(OcfConfig {
        mode,
        fp_bits,
        initial_capacity: 4096,
        min_capacity: 1024,
        seed,
        ..OcfConfig::default()
    });
    let sample_every = (n / 1000).max(1) as u64;
    let (mut occ_sum, mut occ_n) = (0.0, 0u64);
    for k in 0..n as u64 {
        filter
            .insert(k)
            .unwrap_or_else(|e| panic!("{mode:?} insert {k}: {e}"));
        if k % sample_every == sample_every - 1 {
            occ_sum += filter.occupancy();
            occ_n += 1;
        }
    }
    // held-out probes: keys disjoint from the inserted range
    let mut fp_total = 0u64;
    for round in 0..ROUNDS {
        let base = (1u64 << 40) + (round * PROBES_PER_ROUND) as u64;
        for i in 0..PROBES_PER_ROUND as u64 {
            if filter.contains(base + i) {
                fp_total += 1;
            }
        }
    }
    let probes = (ROUNDS * PROBES_PER_ROUND) as f64;
    Arm {
        mode,
        occupancy: filter.occupancy(),
        mean_occupancy: occ_sum / occ_n.max(1) as f64,
        avg_false_positives: fp_total as f64 / ROUNDS as f64,
        fp_rate: fp_total as f64 / probes,
        capacity: filter.capacity(),
        memory_bytes: filter.memory_bytes(),
        resizes: filter.stats().resizes(),
    }
}

/// Full experiment: both arms, markdown report.
pub fn run(scale: Scale) -> String {
    let n = scale.n(FULL_KEYS, 20_000);
    // fp_bits=12 puts the absolute FP-per-round numbers in the same
    // regime as the paper's 32–49 (see DESIGN.md E1); the *shape*
    // (EOF > PRE occupancy, PRE < EOF false positives) is fp_bits-
    // independent.
    let fp_bits = 12;
    let eof = run_arm(Mode::Eof, n, fp_bits, 0x7AB1E1);
    let pre = run_arm(Mode::Pre, n, fp_bits, 0x7AB1E1);

    let mut t = Table::new(
        format!("E1 / Table I — occupancy & false positives after {n} keys"),
        &[
            "Mode",
            "Occupancy",
            "Mean occ (trajectory)",
            "Avg FP / round (10k probes)",
            "FP rate",
            "Capacity",
            "Filter memory",
            "Resizes",
        ],
    );
    for arm in [&eof, &pre] {
        t.row(&[
            arm.mode.as_str().to_uppercase(),
            f(arm.occupancy, 2),
            f(arm.mean_occupancy, 2),
            f(arm.avg_false_positives, 1),
            format!("{:.2e}", arm.fp_rate),
            arm.capacity.to_string(),
            crate::util::fmt_bytes(arm.memory_bytes),
            arm.resizes.to_string(),
        ]);
    }
    t.note(format!(
        "paper Table I: EOF occ 0.74 / 49 FPs, PRE occ 0.47 / 32 FPs. \
         shape check: EOF/PRE trajectory-mean occupancy ratio = {:.2} \
         (paper's final-point ratio at 1M: 1.57), \
         PRE memory / EOF memory = {:.2}",
        eof.mean_occupancy / pre.mean_occupancy,
        pre.memory_bytes as f64 / eof.memory_bytes as f64
    ));
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_paper_at_small_scale() {
        let eof = run_arm(Mode::Eof, 30_000, 12, 1);
        let pre = run_arm(Mode::Pre, 30_000, 12, 1);
        // Table I shape: EOF denser than PRE along the trajectory
        // (final-point occupancy depends on where N lands on PRE's
        // doubling staircase — 30k lands sparse, which also matches)
        assert!(
            eof.mean_occupancy > pre.mean_occupancy,
            "eof={} pre={}",
            eof.mean_occupancy,
            pre.mean_occupancy
        );
        assert!(
            eof.occupancy > pre.occupancy,
            "at 30k PRE lands sparse: eof={} pre={}",
            eof.occupancy,
            pre.occupancy
        );
        // FP rate tracks occupancy (PRE ≤ EOF at this scale)
        assert!(pre.fp_rate <= eof.fp_rate * 1.1);
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.02));
        assert!(md.contains("Table I"));
        assert!(md.contains("EOF"));
        assert!(md.contains("PRE"));
    }
}

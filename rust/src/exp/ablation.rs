//! E8 — ablations over the design parameters DESIGN.md calls out.
//!
//! * **Estimation gain g** (paper default 1/16): smaller g = smoother
//!   α (fewer overshoots, more resizes); larger g = jumpier tracking.
//! * **K-marker band width** (EOF): narrow bands start marking earlier.
//! * **Fingerprint bits**: the FPR/memory trade (paper §II.B).
//!
//! Each row drives the same ramp-burst workload and reports resize
//! count, mean occupancy, rebuild work, and FP rate — the cost/benefit
//! frontier of the paper's defaults.

use super::report::{f, Table};
use super::Scale;
use crate::filter::{MembershipFilter, Mode, Ocf, OcfConfig};
use crate::workload::{BurstGenerator, Op};

/// One configuration's outcome.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub resizes: u64,
    pub rehashed_keys: u64,
    pub mean_occupancy: f64,
    pub fp_rate: f64,
    pub final_capacity: usize,
}

/// Drive one config with the shared ramp workload.
pub fn run_config(label: &str, cfg: OcfConfig, ops: usize) -> AblationRow {
    let mut filter = Ocf::new(cfg);
    let mut gen = BurstGenerator::ramp(ops / 32, 5, 1 << 30, 0xAB1A);
    let mut occ_sum = 0.0;
    let mut occ_n = 0u64;
    let mut done = 0;
    while done < ops {
        let op = match gen.next_op() {
            Some(op) => op,
            None => break,
        };
        match op {
            Op::Insert(k) => {
                let _ = filter.insert(k);
            }
            Op::Lookup(k) => {
                let _ = filter.contains(k);
            }
            Op::Delete(k) => {
                filter.delete(k);
            }
        }
        done += 1;
        if done % 64 == 0 {
            occ_sum += filter.occupancy();
            occ_n += 1;
        }
    }
    let mut fps = 0u64;
    let probes = 50_000u64;
    for k in 0..probes {
        if filter.contains((1 << 45) + k) {
            fps += 1;
        }
    }
    let stats = filter.stats();
    AblationRow {
        label: label.to_string(),
        resizes: stats.resizes(),
        rehashed_keys: stats.rehashed_keys,
        mean_occupancy: occ_sum / occ_n.max(1) as f64,
        fp_rate: fps as f64 / probes as f64,
        final_capacity: filter.capacity(),
    }
}

/// Full ablation grid.
pub fn run(scale: Scale) -> String {
    let ops = scale.n(300_000, 15_000);
    let base = OcfConfig {
        mode: Mode::Eof,
        initial_capacity: 4096,
        ..OcfConfig::default()
    };

    let mut t = Table::new(
        format!("E8 — ablations on the EOF ramp-burst workload ({ops} ops)"),
        &[
            "Config",
            "Resizes",
            "Rehashed keys",
            "Mean occupancy",
            "FP rate",
            "Final capacity",
        ],
    );
    let mut rows = Vec::new();
    for (label, g) in [("g=1/4", 0.25), ("g=1/16 (paper)", 1.0 / 16.0), ("g=1/64", 1.0 / 64.0)] {
        rows.push(run_config(label, OcfConfig { g, ..base }, ops));
    }
    for (label, k_min, k_max) in [
        ("k-band wide [0.25,0.8]", 0.25, 0.8),
        ("k-band paper [0.35,0.7]", 0.35, 0.7),
        ("k-band narrow [0.45,0.6]", 0.45, 0.6),
    ] {
        rows.push(run_config(label, OcfConfig { k_min, k_max, ..base }, ops));
    }
    for fp_bits in [8u32, 12, 16] {
        rows.push(run_config(
            &format!("fp_bits={fp_bits}"),
            OcfConfig { fp_bits, ..base },
            ops,
        ));
    }
    // PRE reference under the same drive
    rows.push(run_config("PRE (reference)", OcfConfig { mode: Mode::Pre, ..base }, ops));

    for r in &rows {
        t.row(&[
            r.label.clone(),
            r.resizes.to_string(),
            r.rehashed_keys.to_string(),
            f(r.mean_occupancy, 3),
            format!("{:.2e}", r.fp_rate),
            r.final_capacity.to_string(),
        ]);
    }
    t.note(
        "expected frontier: fp_bits drives FP rate ~2^-bits at equal occupancy; \
         larger g tracks bursts faster (α reacts harder → bigger final \
         capacity), smaller g runs denser; PRE takes fewer-but-doubling \
         resizes (less rebuild work at this scale, paid for in overshoot — \
         see final capacity vs mean occupancy against the EOF rows).",
    );
    t.markdown()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::Mode;

    #[test]
    fn fp_bits_ablation_shape() {
        let base = OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        };
        let r8 = run_config("8", OcfConfig { fp_bits: 8, ..base }, 20_000);
        let r16 = run_config("16", OcfConfig { fp_bits: 16, ..base }, 20_000);
        assert!(
            r8.fp_rate > r16.fp_rate * 4.0,
            "8-bit fp must be much leakier: {} vs {}",
            r8.fp_rate,
            r16.fp_rate
        );
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.08));
        assert!(md.contains("E8"));
        assert!(md.contains("g=1/16"));
    }
}

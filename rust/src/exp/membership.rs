//! E16 — membership: availability and transfer effort vs replica fault
//! rate during live topology changes.
//!
//! E15 measures what the cluster delivers when replicas fail; this
//! experiment measures what it delivers while the *ring itself* is
//! changing under those same faults. Each arm runs one seeded
//! membership schedule (see `testutil::chaos`): a 3–5 node rf=3
//! quorum/quorum cluster runs the scripted put/delete/get mix, a node
//! **joins** around a third of the way in and another **leaves** around
//! two thirds in, and both transfers stream captured ranges through the
//! same fault planes that are killing replicas — so donors and joiners
//! die mid-transfer at the swept fault density.
//!
//! Reported per arm: client-visible availability (ops answered at
//! quorum; transfers never surface as client errors — stalled ranges
//! route reads to the old owners), streaming volume and its split into
//! streamed vs superseded keys (the conservation law
//! `captured = streamed + superseded` is asserted in-run), transfer
//! retries caused by dead donors/joiners, hint traffic including hints
//! retired with the decommissioned leaver, and the drain rounds the
//! run needed before both transfer and hint queues hit zero.
//!
//! In-run gates (inherited from the harness, every arm): no acked
//! write lost, no deleted key resurrected, typed errors only, both
//! transfers complete, queues drain to zero with nothing dropped, and
//! every replica set converges to the *final* ring.

use std::time::Instant;

use super::report::{f, Table};
use super::Scale;
use crate::testutil::run_one_membership_schedule;

const SEED: u64 = 0xE16_C4A0;

/// Fault densities swept (0.0 is the control: a clean join + leave).
pub const FAULT_RATES: [f64; 4] = [0.0, 0.02, 0.1, 0.25];

/// One fault-rate cell: a full join + leave schedule at that density.
#[derive(Debug, Clone)]
pub struct MembershipArm {
    pub fault_rate: f64,
    pub ops: usize,
    /// Ops answered at quorum (answer codes 0/1; 2 is quorum lost).
    pub ok_ops: u64,
    pub keys_captured: u64,
    pub keys_streamed: u64,
    pub keys_superseded: u64,
    pub transfers_retried: u64,
    pub hints_queued: u64,
    pub hints_replayed: u64,
    pub hints_retired: u64,
    /// Clock advances the post-workload drain needed before transfer
    /// and hint queues both hit zero.
    pub drain_rounds: u64,
    /// Wall time of the whole schedule (workload + drain + audit).
    pub secs: f64,
}

impl MembershipArm {
    /// Fraction of ops served at quorum while the ring was changing.
    pub fn availability(&self) -> f64 {
        self.ok_ops as f64 / self.ops.max(1) as f64
    }

    /// Measured wall latency per op (µs), drain included.
    pub fn wall_us_per_op(&self) -> f64 {
        self.secs * 1e6 / self.ops.max(1) as f64
    }
}

/// Run one arm. The harness panics on any contract violation, so a
/// returned arm is a *proven-correct* run — the numbers describe cost,
/// not correctness.
pub fn run_arm(fault_rate: f64, ops: usize, arm_seed: u64) -> MembershipArm {
    let t0 = Instant::now();
    let out = run_one_membership_schedule(arm_seed, ops, fault_rate);
    let secs = t0.elapsed().as_secs_f64();
    MembershipArm {
        fault_rate,
        ops,
        ok_ops: out.answers.iter().filter(|&&a| a != 2).count() as u64,
        keys_captured: out.stats.keys_captured,
        keys_streamed: out.stats.keys_streamed,
        keys_superseded: out.stats.keys_superseded,
        transfers_retried: out.stats.transfers_retried,
        hints_queued: out.stats.hints_queued,
        hints_replayed: out.stats.hints_replayed,
        hints_retired: out.stats.hints_retired,
        drain_rounds: out.drain_rounds,
        secs,
    }
}

/// Run the full sweep: one join + leave schedule per fault rate.
pub fn measure(ops: usize) -> Vec<MembershipArm> {
    FAULT_RATES
        .iter()
        .enumerate()
        .map(|(i, &rate)| run_arm(rate, ops, SEED ^ ((i as u64 + 1) << 8)))
        .collect()
}

/// Render the E16 table.
pub fn render(title: impl Into<String>, arms: &[MembershipArm]) -> String {
    let mut t = Table::new(
        title,
        &[
            "fault rate",
            "availability",
            "wall µs/op",
            "keys captured",
            "streamed",
            "superseded",
            "xfer retries",
            "hints q→replay",
            "retired",
            "drain rounds",
        ],
    );
    for a in arms {
        t.row(&[
            f(a.fault_rate, 2),
            format!("{}%", f(a.availability() * 100.0, 2)),
            f(a.wall_us_per_op(), 2),
            a.keys_captured.to_string(),
            a.keys_streamed.to_string(),
            a.keys_superseded.to_string(),
            a.transfers_retried.to_string(),
            format!("{}→{}", a.hints_queued, a.hints_replayed),
            a.hints_retired.to_string(),
            a.drain_rounds.to_string(),
        ]);
    }
    t.note(format!(
        "3–5 nodes, rf=3, quorum reads+writes, {} ops per arm over a \
         512-key space (~50% put / 20% delete / 30% get); one node joins \
         around op/3 and one leaves around 2·op/3, streaming captured \
         ranges through the same fault planes that fail the replicas. \
         'superseded' keys were overtaken by client writes or pending \
         deletes during the stream (captured = streamed + superseded is \
         asserted in-run). 'retired' hints died with the decommissioned \
         leaver. Gates asserted in-run: no acked write lost, no deleted \
         key resurrected, typed errors only, both transfers complete, \
         queues drain to zero, and every replica set matches the final \
         ring.",
        arms.first().map_or(0, |a| a.ops),
    ));
    t.markdown()
}

/// The experiment driver (paper scale: 40k ops per arm × 4 arms).
pub fn run(scale: Scale) -> String {
    let ops = scale.n(40_000, 800);
    let arms = measure(ops);
    render(
        format!("E16 — availability & transfer effort vs fault rate across membership changes ({ops} ops/arm)"),
        &arms,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders() {
        // Floor scale: 800 ops per arm, 4 arms. Every contract gate
        // (control availability, conservation law, transfer completion,
        // drain-to-zero, final-ring convergence) runs inside measure().
        let md = run(Scale(0.002));
        assert!(md.contains("E16"));
        assert!(md.contains("0.25"));
        assert!(md.contains("100"));
    }

    #[test]
    fn faulted_arm_conserves_captured_keys() {
        let arm = run_arm(0.25, 1_200, SEED ^ 0x99);
        assert!(arm.keys_captured > 0, "join never captured a key: {arm:?}");
        assert_eq!(
            arm.keys_captured,
            arm.keys_streamed + arm.keys_superseded,
            "conservation law: {arm:?}"
        );
        assert!(arm.availability() > 0.5, "quorum should ride out most faults");
    }
}

//! E12 — per-kernel probe throughput: every runtime-dispatchable
//! [`ProbeKernel`] variant measured on contains / insert / delete at
//! three load factors.
//!
//! E10 answers "what does the batched pipeline buy over scalar loops";
//! E12 answers the orthogonal question the dispatch layer introduces:
//! "what does each *kernel* buy at a given occupancy". Load factor
//! matters because it shifts the primary-hit rate — at 0.3 most
//! negative probes short-circuit nowhere and both candidate buckets
//! are scanned, at 0.85 positive probes usually hit the primary — which is
//! exactly the regime difference between the fused pair compare and
//! the lazy-alternate pipeline.
//!
//! Reuses the E10 harness conventions: [`BATCH`]-sized chunks through
//! one reused [`ProbeSession`], hit counts asserted identical across
//! kernels (they are observationally identical by P14 — a divergence
//! here is a dispatch bug, not noise), and the shared
//! [`Table`](super::report::Table) renderer.

use super::probe::BATCH;
use super::report::{f, Table};
use super::Scale;
use crate::filter::kernel::{self, ProbeKernel};
use crate::filter::{
    BatchedFilter, CuckooFilter, CuckooParams, FlatTable, MembershipFilter, ProbeSession,
    VictimPolicy,
};
use std::time::Instant;

/// One measured cell of the kernel sweep.
#[derive(Debug, Clone)]
pub struct KernelPoint {
    /// Kernel variant.
    pub kernel: &'static str,
    /// Operation ("contains" | "insert" | "delete").
    pub op: &'static str,
    /// Target load factor the table was filled to.
    pub load: f64,
    /// Operations issued.
    pub ops: usize,
    /// Wallclock of the timed loop.
    pub secs: f64,
    /// Successful/hit operations (sanity anchor: must agree across
    /// kernels for the same op × load).
    pub hits: usize,
}

impl KernelPoint {
    pub fn mops(&self) -> f64 {
        if self.secs <= 0.0 {
            0.0
        } else {
            self.ops as f64 / self.secs / 1e6
        }
    }
}

/// The load factors swept (sparse / mid / near the paper's 0.9 cliff).
pub const LOADS: &[f64] = &[0.3, 0.6, 0.85];

fn build(cap: usize, k: &'static ProbeKernel, n_keys: usize) -> (CuckooFilter<FlatTable>, usize) {
    let mut filter = CuckooFilter::<FlatTable>::with_kernel(
        CuckooParams {
            capacity: cap,
            // Rollback: failed inserts near 0.85 load must not strand
            // state, so every kernel sees an identical table.
            victim_policy: VictimPolicy::Rollback,
            ..CuckooParams::default()
        },
        k,
    );
    let mut resident = 0usize;
    for key in 0..n_keys as u64 {
        if filter.insert(key).is_ok() {
            resident += 1;
        }
    }
    (filter, resident)
}

/// Measure {available kernel} × [`LOADS`] × {contains, insert, delete}
/// on a `cap`-slot flat-table filter, `n_ops` timed ops per cell.
pub fn measure(cap: usize, n_ops: usize) -> Vec<KernelPoint> {
    let kernels = kernel::available();
    let mut out = Vec::with_capacity(kernels.len() * LOADS.len() * 3);
    let mut session = ProbeSession::with_capacity(BATCH);
    for &load in LOADS {
        let n_keys = (cap as f64 * load) as usize;
        // The workloads depend only on (load, resident) and resident is
        // kernel-independent (P14) — build them once per load factor,
        // not once per kernel.
        let mut workloads: Option<(usize, Vec<u64>, Vec<u64>, Vec<u64>)> = None;
        for &k in &kernels {
            let (base, resident) = build(cap, k, n_keys);
            if workloads.is_none() {
                // contains: half resident, half absent probes (the
                // mixed read path); insert: fresh keys; delete:
                // resident keys (cycled).
                let probes: Vec<u64> = (0..n_ops as u64)
                    .map(|i| {
                        if i % 2 == 0 {
                            i % (resident.max(1) as u64)
                        } else {
                            (1u64 << 40) + i
                        }
                    })
                    .collect();
                let fresh: Vec<u64> = (0..n_ops as u64).map(|i| (1u64 << 41) + i).collect();
                let dels: Vec<u64> = (0..n_ops as u64)
                    .map(|i| i % (resident.max(1) as u64))
                    .collect();
                workloads = Some((resident, probes, fresh, dels));
            }
            let w = workloads.as_ref().expect("workloads just initialized");
            assert_eq!(w.0, resident, "{}: kernel-divergent resident count", k.name());
            let (probes, fresh, dels) = (&w.1, &w.2, &w.3);
            let mut answers: Vec<bool> = Vec::with_capacity(BATCH);
            let t0 = Instant::now();
            let mut hits = 0usize;
            for chunk in probes.chunks(BATCH) {
                answers.clear();
                base.contains_batch_into(chunk, &mut session, &mut answers);
                hits += answers.iter().filter(|&&h| h).count();
            }
            out.push(KernelPoint {
                kernel: k.name(),
                op: "contains",
                load,
                ops: probes.len(),
                secs: t0.elapsed().as_secs_f64(),
                hits,
            });

            // insert: fresh keys on a clone (each kernel starts from
            // its own — bit-identical — base table).
            let mut f = base.clone();
            let mut results = Vec::with_capacity(BATCH);
            let t0 = Instant::now();
            let mut ok = 0usize;
            for chunk in fresh.chunks(BATCH) {
                results.clear();
                f.insert_batch_into(chunk, &mut session, &mut results);
                ok += results.iter().filter(|r| r.is_ok()).count();
            }
            out.push(KernelPoint {
                kernel: k.name(),
                op: "insert",
                load,
                ops: fresh.len(),
                secs: t0.elapsed().as_secs_f64(),
                hits: ok,
            });

            // delete: resident keys on a clone (unverified raw-filter
            // deletes — the bucket-scan cost, not keystore walks).
            let mut f = base.clone();
            let mut deleted: Vec<bool> = Vec::with_capacity(BATCH);
            let t0 = Instant::now();
            let mut removed = 0usize;
            for chunk in dels.chunks(BATCH) {
                deleted.clear();
                f.delete_batch_into(chunk, &mut session, &mut deleted);
                removed += deleted.iter().filter(|&&d| d).count();
            }
            out.push(KernelPoint {
                kernel: k.name(),
                op: "delete",
                load,
                ops: dels.len(),
                secs: t0.elapsed().as_secs_f64(),
                hits: removed,
            });
        }
    }
    out
}

/// Render the sweep (kernels side by side per op × load, speedup vs
/// the scalar reference kernel).
pub fn render(title: impl Into<String>, points: &[KernelPoint]) -> String {
    let mut table = Table::new(title, &["load", "op", "kernel", "Mops/s", "vs scalar"]);
    for p in points {
        let vs = points
            .iter()
            .find(|q| q.kernel == "scalar" && q.op == p.op && q.load == p.load)
            .filter(|q| q.mops() > 0.0)
            .map(|q| format!("{}x", f(p.mops() / q.mops(), 2)))
            .unwrap_or_default();
        table.row(&[
            f(p.load, 2),
            p.op.to_string(),
            p.kernel.to_string(),
            f(p.mops(), 2),
            vs,
        ]);
    }
    table.note(
        "Flat-table filter, batched engine, mixed pos/neg contains probes; \
         insert/delete run on clones of one shared base table per kernel. \
         Kernels are observationally identical (P14) — hit counts are \
         asserted equal across kernels; only throughput may differ.",
    );
    table.markdown()
}

/// The experiment driver (full scale: 1M-slot table, 500k ops/cell).
pub fn run(scale: Scale) -> String {
    let cap = scale.n(1 << 20, 8_192);
    let n_ops = scale.n(500_000, 8_192);
    let points = measure(cap, n_ops);
    assert_hits_agree(&points);
    render(
        format!("E12 — probe kernels × load factor ({cap} slots, {n_ops} ops/cell)"),
        &points,
    )
}

/// Hit counts must be kernel-independent for every op × load cell.
pub fn assert_hits_agree(points: &[KernelPoint]) {
    for p in points {
        for q in points {
            if p.op == q.op && p.load == q.load {
                assert_eq!(
                    p.hits, q.hits,
                    "kernel divergence: {}/{} at load {} ({} vs {})",
                    p.op, q.op, p.load, p.kernel, q.kernel
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid_and_kernels_agree() {
        let points = measure(4_096, 4_096);
        let kernels = kernel::available();
        assert_eq!(points.len(), kernels.len() * LOADS.len() * 3);
        assert_hits_agree(&points);
        for k in &kernels {
            for &load in LOADS {
                for op in ["contains", "insert", "delete"] {
                    assert!(
                        points
                            .iter()
                            .any(|p| p.kernel == k.name() && p.load == load && p.op == op),
                        "missing cell {}×{load}×{op}",
                        k.name()
                    );
                }
            }
        }
        // deletes of resident keys must actually delete
        assert!(points
            .iter()
            .filter(|p| p.op == "delete")
            .all(|p| p.hits > 0));
    }

    #[test]
    fn report_renders() {
        let md = run(Scale(0.002));
        assert!(md.contains("E12"));
        assert!(md.contains("| scalar |") || md.contains("| scalar "));
        assert!(md.contains("contains"));
        assert!(md.contains("insert"));
        assert!(md.contains("delete"));
    }
}

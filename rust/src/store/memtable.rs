//! The in-memory write buffer (memtable) of a storage node.
//!
//! A sorted map from key to [`Entry`] (live value or tombstone).
//! This is also the "in-memory key-store" the paper's verified-delete
//! path consults (§IV) — [`Memtable::live_contains`] answers the
//! authoritative question for keys that haven't been flushed yet.
//!
//! Since PR 7 entries carry **real value bytes** (shared `Arc<[u8]>`
//! payloads, so cloning an entry is a refcount bump): the WAL logs
//! them, flush serializes them into run files, and recovery
//! round-trips them. `Entry` is therefore no longer `Copy`.

use std::collections::BTreeMap;
use std::sync::Arc;

/// A value payload. `Arc<[u8]>` so entries clone cheaply across the
/// memtable, the WAL record, and SSTable runs without copying bytes.
pub type Value = Arc<[u8]>;

/// Build a [`Value`] of `len` zero bytes — the payload shape used
/// when a caller puts a bare key (`NodeConfig::value_len` sizing).
pub fn zero_value(len: u32) -> Value {
    Arc::from(vec![0u8; len as usize].into_boxed_slice())
}

/// A memtable record: either a live key with its value bytes or a
/// tombstone shadowing older versions in SSTables.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Entry {
    Put { value: Value },
    Tombstone,
}

impl Entry {
    /// Construct a `Put` from a byte slice.
    pub fn put(value: &[u8]) -> Self {
        Entry::Put {
            value: Arc::from(value),
        }
    }

    /// Construct a `Put` holding `len` zero bytes (size-proxy
    /// payloads, the pre-PR-7 behaviour — used widely in tests).
    pub fn put_sized(len: u32) -> Self {
        Entry::Put {
            value: zero_value(len),
        }
    }

    /// Payload length in bytes (0 for tombstones).
    pub fn value_len(&self) -> usize {
        match self {
            Entry::Put { value } => value.len(),
            Entry::Tombstone => 0,
        }
    }
}

/// Sorted in-memory write buffer.
#[derive(Debug, Clone, Default)]
pub struct Memtable {
    map: BTreeMap<u64, Entry>,
    /// Approximate heap bytes (keys + entries + payloads).
    approx_bytes: usize,
    live: usize,
}

const ENTRY_OVERHEAD: usize = 8 + 8; // key + entry tag/ptr, BTree overhead elided

impl Memtable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Upsert a live key. Returns true if the key was not live before.
    pub fn put(&mut self, key: u64, value: Value) -> bool {
        let value_len = value.len();
        let was_live = matches!(self.map.get(&key), Some(Entry::Put { .. }));
        let old = self.map.insert(key, Entry::Put { value });
        match old {
            None => self.approx_bytes += ENTRY_OVERHEAD,
            Some(e) => self.approx_bytes = self.approx_bytes.saturating_sub(e.value_len()),
        }
        self.approx_bytes += value_len;
        if !was_live {
            self.live += 1;
        }
        !was_live
    }

    /// Write a tombstone. Returns true if the key was live *in this
    /// memtable* before (it may still shadow an SSTable version).
    pub fn delete(&mut self, key: u64) -> bool {
        let was_live = matches!(self.map.get(&key), Some(Entry::Put { .. }));
        match self.map.insert(key, Entry::Tombstone) {
            None => self.approx_bytes += ENTRY_OVERHEAD,
            Some(e) => self.approx_bytes = self.approx_bytes.saturating_sub(e.value_len()),
        }
        if was_live {
            self.live -= 1;
        }
        was_live
    }

    /// Three-valued read: `Some(Put)` live here, `Some(Tombstone)`
    /// deleted here (shadowing), `None` unknown — consult SSTables.
    pub fn get(&self, key: u64) -> Option<Entry> {
        self.map.get(&key).cloned()
    }

    /// Is the key live in this memtable?
    pub fn live_contains(&self, key: u64) -> bool {
        matches!(self.map.get(&key), Some(Entry::Put { .. }))
    }

    /// Total records (live + tombstones).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Live (non-tombstone) records.
    pub fn live_len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn approx_bytes(&self) -> usize {
        self.approx_bytes
    }

    /// Drain into a sorted run for flushing (leaves self empty).
    pub fn drain_sorted(&mut self) -> Vec<(u64, Entry)> {
        self.approx_bytes = 0;
        self.live = 0;
        std::mem::take(&mut self.map).into_iter().collect()
    }

    /// Iterate live keys (for filter rebuilds).
    pub fn live_keys(&self) -> impl Iterator<Item = u64> + '_ {
        self.map
            .iter()
            .filter(|(_, e)| matches!(e, Entry::Put { .. }))
            .map(|(&k, _)| k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete_cycle() {
        let mut m = Memtable::new();
        assert!(m.put(5, zero_value(100)));
        assert!(!m.put(5, zero_value(50)), "upsert of live key");
        assert_eq!(m.get(5), Some(Entry::put_sized(50)));
        assert!(m.live_contains(5));
        assert!(m.delete(5));
        assert_eq!(m.get(5), Some(Entry::Tombstone));
        assert!(!m.live_contains(5));
        assert!(!m.delete(5), "already tombstoned");
        assert_eq!(m.len(), 1, "tombstone still occupies a record");
        assert_eq!(m.live_len(), 0);
    }

    #[test]
    fn unknown_key_is_none() {
        let m = Memtable::new();
        assert_eq!(m.get(42), None);
    }

    #[test]
    fn tombstone_of_unknown_key_recorded() {
        // deleting a key that lives only in an SSTable must still write
        // a shadowing tombstone here
        let mut m = Memtable::new();
        assert!(!m.delete(7));
        assert_eq!(m.get(7), Some(Entry::Tombstone));
    }

    #[test]
    fn drain_sorted_is_sorted_and_empties() {
        let mut m = Memtable::new();
        for k in [5u64, 1, 9, 3, 7] {
            m.put(k, zero_value(10));
        }
        m.delete(3);
        let run = m.drain_sorted();
        assert_eq!(run.len(), 5);
        assert!(run.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(m.is_empty());
        assert_eq!(m.approx_bytes(), 0);
    }

    #[test]
    fn bytes_grow_with_payload() {
        let mut m = Memtable::new();
        m.put(1, zero_value(1000));
        let b1 = m.approx_bytes();
        m.put(2, zero_value(0));
        assert!(m.approx_bytes() > b1);
        assert!(b1 >= 1000);
    }

    #[test]
    fn upsert_accounts_replaced_payload() {
        let mut m = Memtable::new();
        m.put(1, zero_value(1000));
        let big = m.approx_bytes();
        m.put(1, zero_value(10));
        assert!(m.approx_bytes() < big, "shrinking upsert must shrink bytes");
        m.delete(1);
        assert!(m.approx_bytes() <= ENTRY_OVERHEAD + 10);
    }

    #[test]
    fn values_round_trip_bytes() {
        let mut m = Memtable::new();
        m.put(9, Arc::from(&b"payload-bytes"[..]));
        match m.get(9) {
            Some(Entry::Put { value }) => assert_eq!(&value[..], b"payload-bytes"),
            other => panic!("expected Put, got {other:?}"),
        }
    }

    #[test]
    fn live_keys_excludes_tombstones() {
        let mut m = Memtable::new();
        m.put(1, zero_value(0));
        m.put(2, zero_value(0));
        m.delete(2);
        m.delete(3);
        let live: Vec<u64> = m.live_keys().collect();
        assert_eq!(live, vec![1]);
    }
}

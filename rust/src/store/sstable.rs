//! Immutable sorted runs (SSTables) with frozen membership filters.
//!
//! An [`SsTable`] is created by a memtable flush, a compaction, or —
//! since the persistent tier landed — recovery from a
//! [`FrozenStore`](super::frozen::FrozenStore) directory. Its
//! [`FrozenFilter`] wraps a [`FrozenTable`]: the exact
//! `u32[nbuckets * SLOTS]` layout the probe kernels and the Pallas/XLA
//! `hash_probe` artifact consume, backed either by an owned heap
//! buffer (freshly frozen) or by an mmap of the persisted filter file
//! (recovered) — probes are served identically off both through the
//! same [`BatchedFilter`] engine.

use super::memtable::Entry;
use crate::filter::bucket::SLOTS;
use crate::filter::cuckoo::{CuckooFilter, CuckooParams};
use crate::filter::fingerprint::Hasher;
use crate::filter::{BatchedFilter, FrozenTable, MembershipFilter, ProbeSession};

/// An immutable, query-only cuckoo-table snapshot.
///
/// A thin store-facing wrapper over [`FrozenTable`] that pins the
/// build-time sizing policy (2× keys, pow2 buckets) and keeps the raw
/// `table() -> &[u32]` view the XLA probe path consumes.
#[derive(Debug, Clone)]
pub struct FrozenFilter {
    frozen: FrozenTable,
}

impl FrozenFilter {
    /// Freeze a filter built from `keys`. Capacity is sized at 2× keys
    /// (paper §II.B recommendation) rounded to a power-of-two bucket
    /// count — immutable tables never grow, and pow2 keeps the frozen
    /// layout bit-compatible with the AOT `hash_probe` artifact (which
    /// derives indices with the xor mapping).
    pub fn build(keys: &[u64], fp_bits: u32, seed: u64) -> Self {
        let nbuckets =
            crate::util::next_pow2(crate::util::ceil_div((keys.len() * 2).max(SLOTS * 4), SLOTS));
        let mut f = CuckooFilter::<crate::filter::FlatTable>::new(CuckooParams {
            capacity: nbuckets * SLOTS,
            fp_bits,
            seed,
            ..CuckooParams::default()
        });
        for &k in keys {
            // 2× headroom makes failure here practically impossible, but
            // the build loop stays total: grow-and-retry like resize::rebuild.
            if f.insert(k).is_err() {
                let mut ks = crate::filter::keystore::KeyStore::new();
                for &k2 in keys {
                    ks.insert(k2);
                }
                let (bigger, _) = crate::filter::resize::rebuild(
                    &ks,
                    f.capacity() * 2,
                    *f.params(),
                );
                f = bigger;
                break;
            }
        }
        Self {
            frozen: FrozenTable::snapshot(&f),
        }
    }

    /// Wrap an already-materialized frozen table (the recovery path:
    /// `FrozenStore::load_filter` hands back a heap- or mmap-backed
    /// [`FrozenTable`] decoded from disk).
    pub fn from_table(frozen: FrozenTable) -> Self {
        Self { frozen }
    }

    /// Membership probe (kernel-dispatched; bit-identical to the XLA
    /// `probe` artifact over the same `table()` buffer).
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        MembershipFilter::contains(&self.frozen, key)
    }

    /// Batched membership through the prefetch-pipelined probe engine —
    /// mmap-backed and heap-backed tables take the identical path.
    pub fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        self.frozen.contains_batch_into(keys, session, out)
    }

    /// The raw frozen table words (for the XLA probe path and the
    /// on-disk encoder).
    pub fn table(&self) -> &[u32] {
        self.frozen.words()
    }

    /// The underlying probe-ready table.
    pub fn frozen(&self) -> &FrozenTable {
        &self.frozen
    }

    pub fn nbuckets(&self) -> usize {
        self.frozen.nbuckets()
    }

    pub fn hasher(&self) -> Hasher {
        self.frozen.hasher()
    }

    /// Resident fingerprints (what the on-disk header records).
    pub fn len(&self) -> usize {
        MembershipFilter::len(&self.frozen)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether the words are served off a file mapping (recovered
    /// filters on unix/LE) instead of an owned heap buffer.
    pub fn is_mapped(&self) -> bool {
        self.frozen.is_mapped()
    }

    /// `"mmap"` or `"heap"` — for banners and stats lines.
    pub fn backing(&self) -> &'static str {
        self.frozen.backing()
    }

    /// Heap bytes attributable to the filter (0 when mmap-backed: the
    /// words live in the page cache, not the heap).
    pub fn memory_bytes(&self) -> usize {
        MembershipFilter::memory_bytes(&self.frozen)
    }
}

/// Immutable sorted run.
#[derive(Debug, Clone)]
pub struct SsTable {
    /// Sorted by key; tombstones included (dropped at bottom-level
    /// compaction).
    run: Vec<(u64, Entry)>,
    filter: FrozenFilter,
    /// Monotone creation stamp (newer tables shadow older ones).
    pub generation: u64,
}

impl SsTable {
    /// Build from a sorted run (as produced by `Memtable::drain_sorted`
    /// or a compaction merge).
    pub fn from_sorted_run(run: Vec<(u64, Entry)>, generation: u64, fp_bits: u32, seed: u64) -> Self {
        debug_assert!(run.windows(2).all(|w| w[0].0 < w[1].0), "run must be sorted+deduped");
        // The frozen filter indexes *all* records including tombstones:
        // a tombstone must be findable so reads stop at the shadowing
        // entry instead of resurrecting older versions below.
        let keys: Vec<u64> = run.iter().map(|&(k, _)| k).collect();
        let filter = FrozenFilter::build(&keys, fp_bits, seed);
        Self {
            run,
            filter,
            generation,
        }
    }

    /// Reassemble from persisted artifacts: the run decoded from a
    /// `.run` file plus a filter loaded (possibly mmap-backed) from the
    /// matching `.fltr` file. The caller is responsible for having
    /// validated both (`FrozenStore` does).
    pub fn from_recovered(run: Vec<(u64, Entry)>, filter: FrozenFilter, generation: u64) -> Self {
        debug_assert!(run.windows(2).all(|w| w[0].0 < w[1].0), "run must be sorted+deduped");
        Self {
            run,
            filter,
            generation,
        }
    }

    /// Number of records (live + tombstones).
    pub fn len(&self) -> usize {
        self.run.len()
    }

    pub fn is_empty(&self) -> bool {
        self.run.is_empty()
    }

    /// Probabilistic pre-check (the read path consults this before the
    /// binary search — Cassandra's per-SSTable bloom, here a frozen
    /// cuckoo snapshot).
    #[inline]
    pub fn might_contain(&self, key: u64) -> bool {
        self.filter.contains(key)
    }

    /// Exact lookup (entries clone cheaply — values are `Arc`-shared).
    pub fn get(&self, key: u64) -> Option<Entry> {
        self.run
            .binary_search_by_key(&key, |&(k, _)| k)
            .ok()
            .map(|i| self.run[i].1.clone())
    }

    pub fn filter(&self) -> &FrozenFilter {
        &self.filter
    }

    /// The full sorted run (what the persistence layer encodes as the
    /// generation's ground truth).
    pub fn run(&self) -> &[(u64, Entry)] {
        &self.run
    }

    /// Iterate records in key order.
    pub fn iter(&self) -> impl Iterator<Item = &(u64, Entry)> {
        self.run.iter()
    }

    /// On-disk size of the run payload: a 13-byte fixed prefix per
    /// record plus its value bytes (the `.run` file adds a 40-byte
    /// header on top).
    pub fn data_bytes(&self) -> usize {
        self.run
            .iter()
            .map(|(_, e)| 13 + e.value_len())
            .sum()
    }

    pub fn memory_bytes(&self) -> usize {
        self.filter.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_of(keys: &[u64]) -> SsTable {
        let mut run: Vec<(u64, Entry)> =
            keys.iter().map(|&k| (k, Entry::put_sized(8))).collect();
        run.sort_by_key(|&(k, _)| k);
        SsTable::from_sorted_run(run, 1, 16, 7)
    }

    #[test]
    fn get_finds_all_records() {
        let keys: Vec<u64> = (0..5000).map(|i| i * 3).collect();
        let t = table_of(&keys);
        for &k in &keys {
            assert!(t.might_contain(k), "filter must pass {k}");
            assert_eq!(t.get(k), Some(Entry::put_sized(8)));
        }
        assert_eq!(t.get(1), None);
        assert_eq!(t.len(), 5000);
    }

    #[test]
    fn filter_never_false_negative() {
        let keys: Vec<u64> = (0..20_000).collect();
        let t = table_of(&keys);
        for &k in &keys {
            assert!(t.might_contain(k), "{k}");
        }
    }

    #[test]
    fn filter_prunes_most_absent_keys() {
        let keys: Vec<u64> = (0..10_000).collect();
        let t = table_of(&keys);
        let passed = (1_000_000..1_100_000u64)
            .filter(|&k| t.might_contain(k))
            .count();
        assert!(passed < 1000, "filter pass rate too high: {passed}/100000");
    }

    #[test]
    fn tombstones_are_findable() {
        let run = vec![
            (1u64, Entry::put_sized(4)),
            (2, Entry::Tombstone),
            (3, Entry::put_sized(4)),
        ];
        let t = SsTable::from_sorted_run(run, 2, 16, 3);
        assert!(t.might_contain(2), "tombstone must be indexed by the filter");
        assert_eq!(t.get(2), Some(Entry::Tombstone));
    }

    #[test]
    fn frozen_filter_matches_source_layout() {
        let keys: Vec<u64> = (0..100).collect();
        let f = FrozenFilter::build(&keys, 16, 5);
        assert_eq!(f.table().len(), f.nbuckets() * SLOTS);
        let occupied = f.table().iter().filter(|&&x| x != 0).count();
        assert_eq!(occupied, 100);
        assert_eq!(f.len(), 100, "snapshot must carry the resident count");
        assert!(!f.is_mapped(), "freshly built filters are heap-backed");
        assert_eq!(f.backing(), "heap");
    }

    #[test]
    fn batched_probe_matches_scalar() {
        let keys: Vec<u64> = (0..4000).map(|i| i * 7 + 1).collect();
        let f = FrozenFilter::build(&keys, 13, 11);
        let probes: Vec<u64> = (0..30_000u64).collect();
        let mut session = ProbeSession::new();
        let mut batched = Vec::new();
        f.contains_batch_into(&probes, &mut session, &mut batched);
        let scalar: Vec<bool> = probes.iter().map(|&k| f.contains(k)).collect();
        assert_eq!(batched, scalar);
    }

    #[test]
    fn from_recovered_round_trips() {
        let keys: Vec<u64> = (0..3000).collect();
        let original = table_of(&keys);
        let rebuilt = SsTable::from_recovered(
            original.run().to_vec(),
            FrozenFilter::from_table(original.filter().frozen().clone()),
            original.generation,
        );
        assert_eq!(rebuilt.len(), original.len());
        for &k in &keys {
            assert_eq!(rebuilt.get(k), original.get(k));
            assert_eq!(rebuilt.might_contain(k), original.might_contain(k));
        }
    }

    #[test]
    fn empty_table() {
        let t = SsTable::from_sorted_run(vec![], 1, 16, 1);
        assert!(t.is_empty());
        assert_eq!(t.get(5), None);
    }
}

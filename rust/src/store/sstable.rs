//! Immutable sorted runs (SSTables) with frozen membership filters.
//!
//! An [`SsTable`] is created by a memtable flush or a compaction. Its
//! [`FrozenFilter`] is the serialized form of a cuckoo table at flush
//! time — the exact `u32[nbuckets * SLOTS]` layout the Pallas/XLA probe
//! kernel consumes, so batched read paths can probe SSTable filters on
//! the accelerator (see `runtime::executor`).

use super::memtable::Entry;
use crate::filter::bucket::SLOTS;
use crate::filter::cuckoo::{CuckooFilter, CuckooParams};
use crate::filter::fingerprint::Hasher;
use crate::filter::MembershipFilter;

/// An immutable, query-only cuckoo-table snapshot.
#[derive(Debug, Clone)]
pub struct FrozenFilter {
    table: Vec<u32>,
    nbuckets: usize,
    hasher: Hasher,
}

impl FrozenFilter {
    /// Freeze a filter built from `keys`. Capacity is sized at 2× keys
    /// (paper §II.B recommendation) rounded to a power-of-two bucket
    /// count — immutable tables never grow, and pow2 keeps the frozen
    /// layout bit-compatible with the AOT `hash_probe` artifact (which
    /// derives indices with the xor mapping).
    pub fn build(keys: &[u64], fp_bits: u32, seed: u64) -> Self {
        let nbuckets =
            crate::util::next_pow2(crate::util::ceil_div((keys.len() * 2).max(SLOTS * 4), SLOTS));
        let mut f = CuckooFilter::<crate::filter::FlatTable>::new(CuckooParams {
            capacity: nbuckets * SLOTS,
            fp_bits,
            seed,
            ..CuckooParams::default()
        });
        for &k in keys {
            // 2× headroom makes failure here practically impossible, but
            // the build loop stays total: grow-and-retry like resize::rebuild.
            if f.insert(k).is_err() {
                let mut ks = crate::filter::keystore::KeyStore::new();
                for &k2 in keys {
                    ks.insert(k2);
                }
                let (bigger, _) = crate::filter::resize::rebuild(
                    &ks,
                    f.capacity() * 2,
                    *f.params(),
                );
                f = bigger;
                break;
            }
        }
        Self {
            table: f.to_frozen(),
            nbuckets: f.nbuckets(),
            hasher: f.hasher(),
        }
    }

    /// Membership probe (pure rust path; bit-identical to the XLA
    /// `probe` artifact over the same `table()` buffer).
    #[inline]
    pub fn contains(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        let i1 = Hasher::primary_index(t, self.nbuckets);
        let i2 = Hasher::alt_index(i1, t.fp, self.nbuckets);
        let b1 = &self.table[i1 * SLOTS..i1 * SLOTS + SLOTS];
        let b2 = &self.table[i2 * SLOTS..i2 * SLOTS + SLOTS];
        b1.contains(&t.fp) || b2.contains(&t.fp)
    }

    /// The raw frozen table (for the XLA probe path).
    pub fn table(&self) -> &[u32] {
        &self.table
    }

    pub fn nbuckets(&self) -> usize {
        self.nbuckets
    }

    pub fn hasher(&self) -> Hasher {
        self.hasher
    }

    pub fn memory_bytes(&self) -> usize {
        self.table.len() * 4
    }
}

/// Immutable sorted run.
#[derive(Debug, Clone)]
pub struct SsTable {
    /// Sorted by key; tombstones included (dropped at bottom-level
    /// compaction).
    run: Vec<(u64, Entry)>,
    filter: FrozenFilter,
    /// Monotone creation stamp (newer tables shadow older ones).
    pub generation: u64,
}

impl SsTable {
    /// Build from a sorted run (as produced by `Memtable::drain_sorted`
    /// or a compaction merge).
    pub fn from_sorted_run(run: Vec<(u64, Entry)>, generation: u64, fp_bits: u32, seed: u64) -> Self {
        debug_assert!(run.windows(2).all(|w| w[0].0 < w[1].0), "run must be sorted+deduped");
        // The frozen filter indexes *all* records including tombstones:
        // a tombstone must be findable so reads stop at the shadowing
        // entry instead of resurrecting older versions below.
        let keys: Vec<u64> = run.iter().map(|&(k, _)| k).collect();
        let filter = FrozenFilter::build(&keys, fp_bits, seed);
        Self {
            run,
            filter,
            generation,
        }
    }

    /// Number of records (live + tombstones).
    pub fn len(&self) -> usize {
        self.run.len()
    }

    pub fn is_empty(&self) -> bool {
        self.run.is_empty()
    }

    /// Probabilistic pre-check (the read path consults this before the
    /// binary search — Cassandra's per-SSTable bloom, here a frozen
    /// cuckoo snapshot).
    #[inline]
    pub fn might_contain(&self, key: u64) -> bool {
        self.filter.contains(key)
    }

    /// Exact lookup.
    pub fn get(&self, key: u64) -> Option<Entry> {
        self.run
            .binary_search_by_key(&key, |&(k, _)| k)
            .ok()
            .map(|i| self.run[i].1)
    }

    pub fn filter(&self) -> &FrozenFilter {
        &self.filter
    }

    /// Iterate records in key order.
    pub fn iter(&self) -> impl Iterator<Item = &(u64, Entry)> {
        self.run.iter()
    }

    /// Simulated on-disk size.
    pub fn data_bytes(&self) -> usize {
        self.run.len() * (8 + 5)
    }

    pub fn memory_bytes(&self) -> usize {
        self.filter.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table_of(keys: &[u64]) -> SsTable {
        let mut run: Vec<(u64, Entry)> = keys
            .iter()
            .map(|&k| (k, Entry::Put { value_len: 8 }))
            .collect();
        run.sort_by_key(|&(k, _)| k);
        SsTable::from_sorted_run(run, 1, 16, 7)
    }

    #[test]
    fn get_finds_all_records() {
        let keys: Vec<u64> = (0..5000).map(|i| i * 3).collect();
        let t = table_of(&keys);
        for &k in &keys {
            assert!(t.might_contain(k), "filter must pass {k}");
            assert_eq!(t.get(k), Some(Entry::Put { value_len: 8 }));
        }
        assert_eq!(t.get(1), None);
        assert_eq!(t.len(), 5000);
    }

    #[test]
    fn filter_never_false_negative() {
        let keys: Vec<u64> = (0..20_000).collect();
        let t = table_of(&keys);
        for &k in &keys {
            assert!(t.might_contain(k), "{k}");
        }
    }

    #[test]
    fn filter_prunes_most_absent_keys() {
        let keys: Vec<u64> = (0..10_000).collect();
        let t = table_of(&keys);
        let passed = (1_000_000..1_100_000u64)
            .filter(|&k| t.might_contain(k))
            .count();
        assert!(passed < 1000, "filter pass rate too high: {passed}/100000");
    }

    #[test]
    fn tombstones_are_findable() {
        let run = vec![
            (1u64, Entry::Put { value_len: 4 }),
            (2, Entry::Tombstone),
            (3, Entry::Put { value_len: 4 }),
        ];
        let t = SsTable::from_sorted_run(run, 2, 16, 3);
        assert!(t.might_contain(2), "tombstone must be indexed by the filter");
        assert_eq!(t.get(2), Some(Entry::Tombstone));
    }

    #[test]
    fn frozen_filter_matches_source_layout() {
        let keys: Vec<u64> = (0..100).collect();
        let f = FrozenFilter::build(&keys, 16, 5);
        assert_eq!(f.table().len(), f.nbuckets() * SLOTS);
        let occupied = f.table().iter().filter(|&&x| x != 0).count();
        assert_eq!(occupied, 100);
    }

    #[test]
    fn empty_table() {
        let t = SsTable::from_sorted_run(vec![], 1, 16, 1);
        assert!(t.is_empty());
        assert_eq!(t.get(5), None);
    }
}

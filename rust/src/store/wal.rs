//! Write-ahead log for the memtable: no acknowledged write is ever
//! lost.
//!
//! Before any mutation is applied to the memtable, the node appends a
//! length-prefixed, FNV-checksummed record here and (per the fsync
//! policy) syncs it. On restart, [`replay_segment`] decodes the
//! surviving segments and `StorageNode::recover` re-applies exactly
//! the operations that had not yet reached a durable SSTable.
//!
//! ## Record format
//!
//! Every record is `len (u32) | checksum (u64, FNV-1a 64 over the
//! payload) | payload`. Payloads:
//!
//! | tag | record | payload layout |
//! |-----|--------|----------------|
//! | 0 | `Delete` | `tag (u8) \| key (u64)` |
//! | 1 | `Put` | `tag (u8) \| key (u64) \| value_len (u32) \| value bytes` |
//! | 2 | `FlushMarker` | `tag (u8) \| generation (u64)` |
//!
//! All integers little-endian. A decoder that hits a short length
//! prefix, a short payload, a bad checksum, or an unknown tag stops
//! **at that point** and reports the tail as torn — everything before
//! it is intact (records are append-ordered, and `atomic_write` is
//! deliberately *not* used here: a WAL wants cheap appends, and the
//! checksums give byte-precise torn-tail detection instead).
//!
//! ## Segments and their lifecycle
//!
//! One segment file per memtable incarnation, named
//! `wal-<seg:016x>.log`, starting with a 32-byte header (magic
//! `OCF1WALS`, version, segment id, header checksum). The active
//! segment receives appends; at a successful flush the node calls
//! [`Wal::commit_flush`], which appends a `FlushMarker` (proof the
//! flushed SSTable generation is durable — the marker is written
//! *after* the SSTable persists), rotates to a fresh segment, and
//! retires every segment whose contents the marker covers.
//!
//! Failure legs keep the invariant "a segment is deleted only once
//! its data is durable somewhere else":
//!
//! * flush persist **failed** → [`Wal::abandon_flush`]: rotate, but
//!   park the sealed segment as *orphaned* (its ops live only in a
//!   RAM SSTable now). Orphans are retired at the next successful
//!   compaction snapshot ([`Wal::commit_snapshot`]) — the snapshot
//!   re-persists every live key.
//! * rotation itself fails (disk dying) → stay on the current
//!   segment; replay handles mid-segment markers via per-segment
//!   staging.
//! * marker append fails → nothing is retired; replay re-applies ops
//!   that are also in the durable SSTable, which is idempotent.
//!
//! ## Group commit (fsync policy)
//!
//! [`FsyncPolicy`] trades durability-against-power-loss for
//! throughput: `Always` syncs every record, `EveryN(n)` syncs every
//! n-th, `Os` never syncs (the OS flushes when it pleases). Against
//! **process death** (SIGKILL) all three are equally safe — appends
//! are write-through to the page cache, which survives the process.
//! The policy only bounds loss when the *machine* dies.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::io::{read_via_handle, StoreIo};
use super::memtable::Value;
use crate::util::{fnv1a64, retry_transient};

/// Segment file magic.
pub const WAL_MAGIC: &[u8; 8] = b"OCF1WALS";
/// Segment format version.
pub const WAL_VERSION: u32 = 1;
/// Segment header length in bytes.
pub const WAL_HEADER_LEN: usize = 32;
/// Per-record prefix: len (u32) + payload checksum (u64).
pub const WAL_RECORD_PREFIX: usize = 4 + 8;
/// Sanity cap on a single record's payload (1 GiB).
const MAX_PAYLOAD: usize = 1 << 30;

/// When (and whether) appends reach stable storage. See module docs
/// for the exact durability contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every record — zero acknowledged loss even on
    /// power failure.
    Always,
    /// fsync every n-th record (group commit) — at most n-1
    /// acknowledged records lost on power failure.
    EveryN(u32),
    /// Never fsync from the WAL; the OS page cache decides.
    Os,
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::Always
    }
}

impl FsyncPolicy {
    /// Stable textual form (used by the serve banner and E13 arms).
    pub fn describe(&self) -> String {
        match self {
            FsyncPolicy::Always => "always".into(),
            FsyncPolicy::EveryN(n) => format!("every_{n}"),
            FsyncPolicy::Os => "os".into(),
        }
    }
}

/// Node-level WAL configuration (`[store] wal` / `fsync` /
/// `fsync_every` in config files).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalConfig {
    /// Log memtable mutations? Only meaningful with a `persist_dir`.
    pub enabled: bool,
    pub fsync: FsyncPolicy,
}

impl Default for WalConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            fsync: FsyncPolicy::Always,
        }
    }
}

/// One logged operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WalRecord {
    Put { key: u64, value: Value },
    Delete { key: u64 },
    /// SSTable generation `generation` is durable on disk; every
    /// record before this marker (in this segment) is covered by it.
    FlushMarker { generation: u64 },
}

impl WalRecord {
    fn encode_payload(&self) -> Vec<u8> {
        match self {
            WalRecord::Delete { key } => {
                let mut p = Vec::with_capacity(9);
                p.push(0u8);
                p.extend_from_slice(&key.to_le_bytes());
                p
            }
            WalRecord::Put { key, value } => {
                let mut p = Vec::with_capacity(13 + value.len());
                p.push(1u8);
                p.extend_from_slice(&key.to_le_bytes());
                p.extend_from_slice(&(value.len() as u32).to_le_bytes());
                p.extend_from_slice(value);
                p
            }
            WalRecord::FlushMarker { generation } => {
                let mut p = Vec::with_capacity(9);
                p.push(2u8);
                p.extend_from_slice(&generation.to_le_bytes());
                p
            }
        }
    }

    fn decode_payload(p: &[u8]) -> Option<WalRecord> {
        let (&tag, rest) = p.split_first()?;
        match tag {
            0 => {
                let key = u64::from_le_bytes(rest.get(0..8)?.try_into().ok()?);
                if rest.len() != 8 {
                    return None;
                }
                Some(WalRecord::Delete { key })
            }
            1 => {
                let key = u64::from_le_bytes(rest.get(0..8)?.try_into().ok()?);
                let vlen = u32::from_le_bytes(rest.get(8..12)?.try_into().ok()?) as usize;
                let bytes = rest.get(12..)?;
                if bytes.len() != vlen {
                    return None;
                }
                Some(WalRecord::Put {
                    key,
                    value: Arc::from(bytes),
                })
            }
            2 => {
                let generation = u64::from_le_bytes(rest.get(0..8)?.try_into().ok()?);
                if rest.len() != 8 {
                    return None;
                }
                Some(WalRecord::FlushMarker { generation })
            }
            _ => None,
        }
    }
}

fn encode_record(rec: &WalRecord) -> Vec<u8> {
    let payload = rec.encode_payload();
    let mut buf = Vec::with_capacity(WAL_RECORD_PREFIX + payload.len());
    buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    buf.extend_from_slice(&payload);
    buf
}

fn encode_header(segment: u64) -> [u8; WAL_HEADER_LEN] {
    let mut h = [0u8; WAL_HEADER_LEN];
    h[0..8].copy_from_slice(WAL_MAGIC);
    h[8..12].copy_from_slice(&WAL_VERSION.to_le_bytes());
    // 12..16 reserved (zero)
    h[16..24].copy_from_slice(&segment.to_le_bytes());
    let sum = fnv1a64(&h[0..24]);
    h[24..32].copy_from_slice(&sum.to_le_bytes());
    h
}

/// Segment file name for id `segment`.
pub fn segment_file_name(segment: u64) -> String {
    format!("wal-{segment:016x}.log")
}

fn segment_path(dir: &Path, segment: u64) -> PathBuf {
    dir.join(segment_file_name(segment))
}

/// List WAL segment ids present in `dir`, ascending. Stray names are
/// ignored, exactly like `FrozenStore::generations`.
pub fn list_segments(io: &dyn StoreIo, dir: &Path) -> io::Result<Vec<u64>> {
    let mut segs = Vec::new();
    for name in io.read_dir(dir)? {
        if let Some(hex) = name
            .strip_prefix("wal-")
            .and_then(|s| s.strip_suffix(".log"))
        {
            if hex.len() == 16 {
                if let Ok(id) = u64::from_str_radix(hex, 16) {
                    segs.push(id);
                }
            }
        }
    }
    segs.sort_unstable();
    Ok(segs)
}

/// A decoded segment: the records that validated, in append order,
/// plus whether the decode stopped early at a torn/corrupt tail.
#[derive(Debug)]
pub struct SegmentReplay {
    pub segment: u64,
    pub records: Vec<WalRecord>,
    pub torn: bool,
}

/// Decode one segment, tolerating a torn tail: decoding stops at the
/// first record whose length prefix, payload, or checksum doesn't
/// hold, and everything decoded up to that point is returned with
/// `torn = true`. A missing/short/corrupt *header* yields zero
/// records (also `torn` — the segment existed, so something was cut
/// short). Only real I/O errors (`ErrorKind` other than data
/// problems) propagate as `Err`.
pub fn replay_segment(io: &dyn StoreIo, dir: &Path, segment: u64) -> io::Result<SegmentReplay> {
    let bytes = read_via_handle(io, &segment_path(dir, segment))?;
    let mut out = SegmentReplay {
        segment,
        records: Vec::new(),
        torn: false,
    };
    if bytes.len() < WAL_HEADER_LEN {
        out.torn = true;
        return Ok(out);
    }
    let h = &bytes[..WAL_HEADER_LEN];
    let sum = u64::from_le_bytes(h[24..32].try_into().unwrap());
    if &h[0..8] != WAL_MAGIC
        || u32::from_le_bytes(h[8..12].try_into().unwrap()) != WAL_VERSION
        || u64::from_le_bytes(h[16..24].try_into().unwrap()) != segment
        || sum != fnv1a64(&h[0..24])
    {
        out.torn = true;
        return Ok(out);
    }
    let mut off = WAL_HEADER_LEN;
    while off < bytes.len() {
        if bytes.len() - off < WAL_RECORD_PREFIX {
            out.torn = true;
            break;
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        let want_sum = u64::from_le_bytes(bytes[off + 4..off + 12].try_into().unwrap());
        let start = off + WAL_RECORD_PREFIX;
        if len > MAX_PAYLOAD || bytes.len() - start < len {
            out.torn = true;
            break;
        }
        let payload = &bytes[start..start + len];
        if fnv1a64(payload) != want_sum {
            out.torn = true;
            break;
        }
        match WalRecord::decode_payload(payload) {
            Some(rec) => out.records.push(rec),
            None => {
                out.torn = true;
                break;
            }
        }
        off = start + len;
    }
    Ok(out)
}

/// The live write-ahead log of one `StorageNode`.
///
/// All methods absorb transient I/O errors via `util::retry`
/// (harvest the count with [`Wal::take_retries`] — the node feeds it
/// into `NodeStats::io_retries`).
#[derive(Debug)]
pub struct Wal {
    dir: PathBuf,
    io: Arc<dyn StoreIo>,
    policy: FsyncPolicy,
    /// Id of the segment currently receiving appends.
    active: u64,
    /// Records appended since the last sync (EveryN bookkeeping).
    unsynced: u32,
    /// Segments restored by recovery whose ops now live in the
    /// current memtable — retired at the next successful flush.
    replayed_pending: Vec<u64>,
    /// Segments whose flush persist *failed* (data exists only in a
    /// RAM SSTable) — retired at the next durable full snapshot.
    orphaned: Vec<u64>,
    appends: u64,
    retries: u64,
    segments_retired: u64,
}

impl Wal {
    /// Open a WAL in `dir`, creating segment `first_segment` as the
    /// active one. Recovery passes `max_existing + 1` so ids never
    /// collide with segments from earlier incarnations.
    pub fn open(
        dir: &Path,
        io: Arc<dyn StoreIo>,
        policy: FsyncPolicy,
        first_segment: u64,
    ) -> io::Result<Wal> {
        io.create_dir_all(dir)?;
        let mut wal = Wal {
            dir: dir.to_path_buf(),
            io,
            policy,
            active: first_segment,
            unsynced: 0,
            replayed_pending: Vec::new(),
            orphaned: Vec::new(),
            appends: 0,
            retries: 0,
            segments_retired: 0,
        };
        wal.create_segment(first_segment)?;
        Ok(wal)
    }

    fn create_segment(&mut self, segment: u64) -> io::Result<()> {
        let path = segment_path(&self.dir, segment);
        let header = encode_header(segment);
        let r = retry_transient(|| self.io.write(&path, &header));
        self.retries += r.retries as u64;
        r.result?;
        let r = retry_transient(|| self.io.sync(&path));
        self.retries += r.retries as u64;
        r.result
    }

    fn active_path(&self) -> PathBuf {
        segment_path(&self.dir, self.active)
    }

    /// Park segment ids as replayed-pending (set by recovery: their
    /// ops were re-applied into the live memtable).
    pub fn mark_replayed(&mut self, segments: Vec<u64>) {
        self.replayed_pending = segments;
    }

    /// Append one record and apply the fsync policy. On `Ok`, the
    /// record is durable to the degree the policy promises.
    pub fn append(&mut self, rec: &WalRecord) -> io::Result<()> {
        let buf = encode_record(rec);
        self.append_all(&buf)?;
        self.appends += 1;
        match self.policy {
            FsyncPolicy::Always => self.sync()?,
            FsyncPolicy::EveryN(n) => {
                self.unsynced += 1;
                if self.unsynced >= n.max(1) {
                    self.sync()?;
                }
            }
            FsyncPolicy::Os => {}
        }
        Ok(())
    }

    /// Write-through append that tolerates short writes (loops) and
    /// transient errors (retries).
    fn append_all(&mut self, buf: &[u8]) -> io::Result<()> {
        let path = self.active_path();
        let mut off = 0usize;
        while off < buf.len() {
            let r = retry_transient(|| self.io.append(&path, &buf[off..]));
            self.retries += r.retries as u64;
            let n = r.result?;
            if n == 0 {
                return Err(io::Error::new(
                    io::ErrorKind::WriteZero,
                    "wal append made no progress",
                ));
            }
            off += n;
        }
        Ok(())
    }

    /// fsync the active segment now, regardless of policy.
    pub fn sync(&mut self) -> io::Result<()> {
        let path = self.active_path();
        let r = retry_transient(|| self.io.sync(&path));
        self.retries += r.retries as u64;
        r.result?;
        self.unsynced = 0;
        Ok(())
    }

    /// The flushed memtable's SSTable generation `generation` is
    /// durable: append the marker, rotate to a fresh segment, and
    /// retire everything the marker covers (the sealed segment plus
    /// any replayed-pending ones).
    ///
    /// On error the WAL stays consistent but conservative: nothing is
    /// retired, and if rotation failed appends continue into the old
    /// segment (replay stages per-segment, so a mid-segment marker is
    /// handled).
    pub fn commit_flush(&mut self, generation: u64) -> io::Result<()> {
        let marker = WalRecord::FlushMarker { generation };
        let buf = encode_record(&marker);
        self.append_all(&buf)?;
        self.appends += 1;
        self.sync()?;
        let sealed = self.active;
        self.rotate()?;
        let mut retire = std::mem::take(&mut self.replayed_pending);
        retire.push(sealed);
        self.retire_segments(&retire);
        Ok(())
    }

    /// The flush's SSTable persist failed: the drained memtable now
    /// exists only in RAM. Rotate (best-effort) and keep the sealed
    /// segment as an orphan until a durable snapshot covers it.
    pub fn abandon_flush(&mut self) {
        let sealed = self.active;
        if self.rotate().is_ok() {
            self.orphaned.push(sealed);
        }
        // Rotation failure: stay on the segment; nothing is lost,
        // the next commit/abandon will try again.
    }

    /// A full compaction snapshot persisted durably: every live key
    /// is covered, so orphaned segments can finally go.
    pub fn commit_snapshot(&mut self) {
        let orphans = std::mem::take(&mut self.orphaned);
        self.retire_segments(&orphans);
    }

    fn rotate(&mut self) -> io::Result<()> {
        let next = self.active + 1;
        self.create_segment(next)?;
        self.active = next;
        self.unsynced = 0;
        Ok(())
    }

    /// Best-effort deletion; a segment that refuses to die is
    /// harmless (replay stages it and its marker clears it).
    pub fn retire_segments(&mut self, segments: &[u64]) {
        for &seg in segments {
            match self.io.remove_file(&segment_path(&self.dir, seg)) {
                Ok(()) => self.segments_retired += 1,
                Err(e) if e.kind() == io::ErrorKind::NotFound => {}
                Err(e) => {
                    eprintln!("ocf: wal: could not retire segment {seg:#018x}: {e}");
                }
            }
        }
    }

    /// Records appended over this WAL's lifetime (markers included).
    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Segments deleted after their contents became durable.
    pub fn segments_retired(&self) -> u64 {
        self.segments_retired
    }

    /// Id of the segment currently receiving appends.
    pub fn active_segment(&self) -> u64 {
        self.active
    }

    pub fn policy(&self) -> FsyncPolicy {
        self.policy
    }

    /// Drain the transient-retry counter (accumulates across every
    /// operation since the last take).
    pub fn take_retries(&mut self) -> u64 {
        std::mem::take(&mut self.retries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::io::RealIo;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ocf-wal-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rio() -> Arc<dyn StoreIo> {
        Arc::new(RealIo)
    }

    fn put(key: u64, v: &[u8]) -> WalRecord {
        WalRecord::Put {
            key,
            value: Arc::from(v),
        }
    }

    #[test]
    fn append_replay_roundtrip_all_record_kinds() {
        let dir = scratch("roundtrip");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::Always, 1).unwrap();
        let recs = vec![
            put(1, b"alpha"),
            put(2, b""),
            WalRecord::Delete { key: 1 },
            put(u64::MAX, b"max-key"),
        ];
        for r in &recs {
            wal.append(r).unwrap();
        }
        let seg = replay_segment(rio().as_ref(), &dir, 1).unwrap();
        assert!(!seg.torn);
        assert_eq!(seg.records, recs);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_tolerated_not_fatal() {
        let dir = scratch("torn");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::Os, 1).unwrap();
        wal.append(&put(10, b"kept")).unwrap();
        wal.append(&put(11, b"kept-too")).unwrap();
        // Simulate a torn final record: append garbage that parses as
        // a length prefix pointing past EOF.
        let path = segment_path(&dir, 1);
        RealIo.append(&path, &[0xff, 0x00, 0x00, 0x00, 1, 2, 3]).unwrap();
        let seg = replay_segment(rio().as_ref(), &dir, 1).unwrap();
        assert!(seg.torn, "tail damage must be reported");
        assert_eq!(seg.records.len(), 2, "intact prefix survives");
        assert_eq!(seg.records[0], put(10, b"kept"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_mid_record_checksum_stops_decode() {
        let dir = scratch("sum");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::Os, 3).unwrap();
        wal.append(&put(1, b"first")).unwrap();
        wal.append(&put(2, b"second")).unwrap();
        let path = segment_path(&dir, 3);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one payload byte of the first record.
        bytes[WAL_HEADER_LEN + WAL_RECORD_PREFIX + 2] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let seg = replay_segment(rio().as_ref(), &dir, 3).unwrap();
        assert!(seg.torn);
        assert!(
            seg.records.is_empty(),
            "nothing after corruption is trusted"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_header_yields_zero_records() {
        let dir = scratch("hdr");
        let path = segment_path(&dir, 9);
        std::fs::write(&path, b"NOTAWAL!").unwrap();
        let seg = replay_segment(rio().as_ref(), &dir, 9).unwrap();
        assert!(seg.torn);
        assert!(seg.records.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn commit_flush_rotates_and_retires() {
        let dir = scratch("commit");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::Always, 1).unwrap();
        wal.append(&put(1, b"v")).unwrap();
        wal.commit_flush(42).unwrap();
        assert_eq!(wal.active_segment(), 2);
        assert_eq!(wal.segments_retired(), 1);
        let segs = list_segments(rio().as_ref(), &dir).unwrap();
        assert_eq!(segs, vec![2], "sealed segment gone, fresh one live");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn abandon_flush_keeps_orphan_until_snapshot() {
        let dir = scratch("orphan");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::Always, 1).unwrap();
        wal.append(&put(5, b"ram-only")).unwrap();
        wal.abandon_flush();
        assert_eq!(
            list_segments(rio().as_ref(), &dir).unwrap(),
            vec![1, 2],
            "orphan survives the failed flush"
        );
        wal.append(&put(6, b"next-era")).unwrap();
        wal.commit_snapshot();
        assert_eq!(
            list_segments(rio().as_ref(), &dir).unwrap(),
            vec![2],
            "snapshot retires the orphan"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn every_n_groups_syncs() {
        let dir = scratch("groupsync");
        let mut wal = Wal::open(&dir, rio(), FsyncPolicy::EveryN(4), 1).unwrap();
        for k in 0..10 {
            wal.append(&put(k, b"grouped")).unwrap();
        }
        // Contents are write-through regardless of sync cadence.
        let seg = replay_segment(rio().as_ref(), &dir, 1).unwrap();
        assert_eq!(seg.records.len(), 10);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_segments_ignores_strays() {
        let dir = scratch("strays");
        let _ = Wal::open(&dir, rio(), FsyncPolicy::Os, 7).unwrap();
        std::fs::write(dir.join("wal-zzzz.log"), b"x").unwrap();
        std::fs::write(dir.join("sst-0000000000000001.run"), b"x").unwrap();
        assert_eq!(list_segments(rio().as_ref(), &dir).unwrap(), vec![7]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn policy_describe_strings() {
        assert_eq!(FsyncPolicy::Always.describe(), "always");
        assert_eq!(FsyncPolicy::EveryN(8).describe(), "every_8");
        assert_eq!(FsyncPolicy::Os.describe(), "os");
    }
}

//! Flush policy: when does a memtable freeze into an SSTable?
//!
//! Two triggers, matching the paper's framing (§I.A):
//!
//! * **MemtableBytes / MemtableKeys** — the healthy reason: the write
//!   buffer is actually full.
//! * **FilterPressure** — the pathological reason OCF exists to remove:
//!   a fixed-capacity filter near saturation forces a *premature* flush
//!   ("having too many misses is also an indication that the buckets in
//!   the filter are reaching capacity, which can warrant flushes …
//!   leading to a complete rebuild of the in-memory data structures").
//!
//! Experiment E6 runs the same burst workload under both configurations
//! and counts flushes + measures ingest latency.

/// Why a flush fired (recorded in node stats).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    MemtableBytes,
    MemtableKeys,
    FilterPressure,
}

/// Flush trigger configuration.
#[derive(Debug, Clone, Copy)]
pub struct FlushPolicy {
    /// Flush when the memtable's approximate bytes exceed this.
    pub max_memtable_bytes: usize,
    /// Flush when the memtable holds this many records.
    pub max_memtable_keys: usize,
    /// If set, flush when the node's live filter occupancy exceeds this
    /// (models the fixed-filter Cassandra behaviour; `None` = trust the
    /// filter to resize — the OCF configuration).
    pub filter_pressure: Option<f64>,
}

impl Default for FlushPolicy {
    fn default() -> Self {
        Self {
            max_memtable_bytes: 64 << 20,
            max_memtable_keys: 1 << 20,
            filter_pressure: None,
        }
    }
}

impl FlushPolicy {
    /// A small-memtable policy for tests/experiments.
    pub fn small(max_keys: usize) -> Self {
        Self {
            max_memtable_bytes: usize::MAX,
            max_memtable_keys: max_keys,
            filter_pressure: None,
        }
    }

    /// The fixed-filter arm: flush under filter pressure too.
    pub fn with_filter_pressure(mut self, occupancy: f64) -> Self {
        self.filter_pressure = Some(occupancy);
        self
    }

    /// Evaluate the triggers.
    pub fn should_flush(
        &self,
        memtable_bytes: usize,
        memtable_keys: usize,
        filter_occupancy: f64,
    ) -> Option<FlushReason> {
        if memtable_bytes > self.max_memtable_bytes {
            return Some(FlushReason::MemtableBytes);
        }
        if memtable_keys > self.max_memtable_keys {
            return Some(FlushReason::MemtableKeys);
        }
        if let Some(p) = self.filter_pressure {
            if filter_occupancy > p {
                return Some(FlushReason::FilterPressure);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_flush_when_under_all_thresholds() {
        let p = FlushPolicy::default();
        assert_eq!(p.should_flush(1024, 10, 0.5), None);
    }

    #[test]
    fn bytes_trigger() {
        let p = FlushPolicy {
            max_memtable_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(
            p.should_flush(1001, 0, 0.0),
            Some(FlushReason::MemtableBytes)
        );
    }

    #[test]
    fn keys_trigger() {
        let p = FlushPolicy::small(100);
        assert_eq!(p.should_flush(0, 101, 0.0), Some(FlushReason::MemtableKeys));
        assert_eq!(p.should_flush(0, 100, 0.0), None, "strict >");
    }

    #[test]
    fn filter_pressure_only_when_configured() {
        let without = FlushPolicy::small(1_000_000);
        assert_eq!(without.should_flush(0, 0, 0.99), None);
        let with = without.with_filter_pressure(0.8);
        assert_eq!(
            with.should_flush(0, 0, 0.85),
            Some(FlushReason::FilterPressure)
        );
        assert_eq!(with.should_flush(0, 0, 0.75), None);
    }

    #[test]
    fn priority_order_bytes_first() {
        let p = FlushPolicy {
            max_memtable_bytes: 10,
            max_memtable_keys: 10,
            filter_pressure: Some(0.1),
        };
        assert_eq!(
            p.should_flush(100, 100, 0.9),
            Some(FlushReason::MemtableBytes)
        );
    }
}

//! The persistent frozen-filter tier: a versioned on-disk format for
//! frozen cuckoo tables plus the [`FrozenStore`] that owns
//! encode/decode/open. See `rust/src/store/README.md` for the full
//! format spec, recovery state machine and compaction-swap protocol.
//!
//! Two files per SSTable generation, both checksummed (FNV-1a 64):
//!
//! * `sst-<gen>.run` — the sorted run (ground truth: keys + entry
//!   kinds). Present + valid ⇒ the generation exists.
//! * `sst-<gen>.fltr` — the frozen filter (derived artifact): a fixed
//!   64-byte header, zero padding to a 4096-byte boundary, then the
//!   row-major `u32[nbuckets * SLOTS]` table words little-endian. The
//!   page-aligned payload is served **zero-copy via mmap** on unix
//!   little-endian targets (heap read elsewhere), straight into
//!   [`FrozenTable`] and the batch probe engine.
//!
//! Writes are atomic (temp file + `rename` in the same directory), and
//! the run is written before the filter so every crash point leaves a
//! recoverable state: a valid run with a missing/torn filter rebuilds
//! the filter from the run ([`StorageNode`](super::StorageNode)
//! recovery counts it in `filters_rebuilt`).
//!
//! ## Filter file layout (version 1)
//!
//! | offset | size | field |
//! |--------|------|-------|
//! | 0      | 8    | magic `"OCF1FRZN"` |
//! | 8      | 4    | format version (u32 LE) |
//! | 12     | 4    | fp_bits (u32 LE) |
//! | 16     | 8    | nbuckets (u64 LE) |
//! | 24     | 8    | hash seed (u64 LE) |
//! | 32     | 8    | resident fingerprints (u64 LE) |
//! | 40     | 8    | payload_len bytes (u64 LE) |
//! | 48     | 8    | payload checksum (FNV-1a 64, u64 LE) |
//! | 56     | 8    | header checksum over bytes 0..56 (u64 LE) |
//! | 64     | —    | zero padding to [`PAYLOAD_OFFSET`] |
//! | 4096   | payload_len | table words, u32 LE each |

use super::io::{RealIo, StoreIo};
use super::memtable::Entry;
use super::sstable::{FrozenFilter, SsTable};
use crate::filter::bucket::SLOTS;
use crate::filter::frozen::{FrozenBytes, FrozenTable};
use crate::util::{fnv1a64, retry_transient, MmapRegion};
use std::io::{self, Read};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Magic of a frozen-filter file.
pub const FILTER_MAGIC: [u8; 8] = *b"OCF1FRZN";
/// Magic of a sorted-run file.
pub const RUN_MAGIC: [u8; 8] = *b"OCF1RUNS";
/// Current *filter*-file format version. Readers reject any other
/// version (forward *and* backward): a version bump means the layout
/// changed, and a rejected filter file falls back to rebuild-from-run,
/// so bumping is cheap — there is no silent cross-version
/// reinterpretation.
pub const FORMAT_VERSION: u32 = 1;
/// Current *run*-file format version. Bumped to 2 when run records
/// gained inline value bytes (variable-length records). Runs are
/// ground truth, so unlike the filter file the old version is still
/// *readable*: a version-1 run (13-byte fixed records carrying only a
/// value length) decodes with its values materialized as that many
/// zero bytes — the explicit read-old/write-new migration the
/// versioning policy requires.
pub const RUN_FORMAT_VERSION: u32 = 2;
const RUN_VERSION_LEGACY: u32 = 1;
/// Byte offset of the filter payload. One page on every common page
/// size's divisor chain (4 KiB pages, and 4096 divides 16 KiB/64 KiB
/// pages' interior alignment since the file is mapped from offset 0),
/// so the `u32` words are always naturally aligned in the mapping.
pub const PAYLOAD_OFFSET: u64 = 4096;

const FILTER_HEADER_LEN: usize = 64;
const RUN_HEADER_LEN: usize = 40;
/// Fixed bytes per run record: key (8) + tag (1) + value_len (4).
/// Version-2 records append `value_len` payload bytes after this
/// prefix; version-1 records were exactly this long.
const RUN_RECORD_LEN: usize = 13;
/// Sanity cap on a single record's value payload (1 GiB).
const MAX_VALUE_LEN: u32 = 1 << 30;

/// Run-header flag: this generation is a **full-state snapshot** (a
/// compaction output that merged *every* older generation), so all
/// older generations are obsolete. Recovery discards generations below
/// the newest full snapshot — without this, a crash between a
/// compaction's persist and its input cleanup could resurrect keys
/// whose tombstones the merge dropped (the old generations' `Put`s
/// would no longer be shadowed by anything).
pub const RUN_FLAG_FULL_SNAPSHOT: u32 = 1;
/// All run-header flag bits this reader understands. Unknown bits are
/// rejected (`BadParams` → the generation is skipped): a flag changes
/// recovery semantics, so serving data under an ununderstood flag is
/// not safe.
const RUN_FLAGS_KNOWN: u32 = RUN_FLAG_FULL_SNAPSHOT;

/// Why a persisted artifact was rejected at open time.
#[derive(Debug)]
pub enum RecoverError {
    /// Filesystem error (including a missing file).
    Io(io::Error),
    /// File shorter than its header/payload claims.
    Truncated { expected: u64, found: u64 },
    /// Not a frozen-filter / run file at all.
    BadMagic,
    /// A format version this reader does not speak.
    BadVersion { found: u32 },
    /// Header bytes fail their own checksum.
    BadHeader,
    /// Header decodes but the parameters are inconsistent.
    BadParams(String),
    /// Payload bytes fail the recorded checksum (torn write, bit rot).
    ChecksumMismatch { expected: u64, found: u64 },
}

impl RecoverError {
    /// Was an artifact *present but rejected* (vs simply absent)?
    /// Recovery counts rejections separately
    /// (`NodeStats::filter_recovery_rejected`): a rejected filter file
    /// is a durability event worth alerting on, a missing one is the
    /// normal crash-between-run-and-filter window.
    pub fn is_rejection(&self) -> bool {
        !matches!(self, RecoverError::Io(e) if e.kind() == io::ErrorKind::NotFound)
    }
}

impl std::fmt::Display for RecoverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecoverError::Io(e) => write!(f, "io error: {e}"),
            RecoverError::Truncated { expected, found } => {
                write!(f, "truncated: need {expected} bytes, file has {found}")
            }
            RecoverError::BadMagic => write!(f, "bad magic (not an OCF artifact)"),
            RecoverError::BadVersion { found } => {
                write!(
                    f,
                    "unsupported format version {found} (reader speaks filter v{FORMAT_VERSION}, \
                     run v{RUN_VERSION_LEGACY}-v{RUN_FORMAT_VERSION})"
                )
            }
            RecoverError::BadHeader => write!(f, "header checksum mismatch"),
            RecoverError::BadParams(msg) => write!(f, "inconsistent parameters: {msg}"),
            RecoverError::ChecksumMismatch { expected, found } => write!(
                f,
                "payload checksum mismatch: header says {expected:#018x}, bytes hash to {found:#018x}"
            ),
        }
    }
}

impl std::error::Error for RecoverError {}

impl From<io::Error> for RecoverError {
    fn from(e: io::Error) -> Self {
        RecoverError::Io(e)
    }
}

/// How to back a loaded filter's words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backing {
    /// mmap where supported (unix, little-endian), heap elsewhere.
    Auto,
    /// Force an owned heap copy (the portable path; also the
    /// mmap-vs-heap bench arm).
    Heap,
    /// Require a mapping; error where unsupported.
    Mmap,
}

/// Directory of persisted frozen filters + runs, one pair per SSTable
/// generation. All writes are temp-file + rename atomic and absorb
/// transient I/O errors with bounded retry (`util::retry`); every
/// file operation goes through a [`StoreIo`] so faults can be
/// injected deterministically in tests.
#[derive(Debug, Clone)]
pub struct FrozenStore {
    dir: PathBuf,
    io: Arc<dyn StoreIo>,
    /// Transient retries absorbed by this store's writes (shared
    /// across clones); the node drains it into `NodeStats::io_retries`.
    retries: Arc<AtomicU64>,
}

impl FrozenStore {
    /// Open (creating if needed) a persistence directory on the real
    /// filesystem.
    pub fn open(dir: impl Into<PathBuf>) -> io::Result<Self> {
        Self::open_with(dir, Arc::new(RealIo))
    }

    /// [`FrozenStore::open`] over an explicit I/O layer (fault
    /// injection).
    pub fn open_with(dir: impl Into<PathBuf>, io: Arc<dyn StoreIo>) -> io::Result<Self> {
        let dir = dir.into();
        io.create_dir_all(&dir)?;
        Ok(Self {
            dir,
            io,
            retries: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Drain the transient-retry counter.
    pub fn take_retries(&self) -> u64 {
        self.retries.swap(0, Ordering::Relaxed)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path of generation `gen`'s filter file.
    pub fn filter_path(&self, gen: u64) -> PathBuf {
        self.dir.join(format!("sst-{gen:016x}.fltr"))
    }

    /// Path of generation `gen`'s run file.
    pub fn run_path(&self, gen: u64) -> PathBuf {
        self.dir.join(format!("sst-{gen:016x}.run"))
    }

    /// Persist one SSTable: run first (ground truth), then filter
    /// (derived). Any crash point leaves either nothing, a run alone
    /// (→ filter rebuilt on recovery), or both.
    pub fn persist(&self, t: &SsTable) -> io::Result<()> {
        self.persist_with_flags(t, 0)
    }

    /// [`FrozenStore::persist`] with [`RUN_FLAG_FULL_SNAPSHOT`] set:
    /// for compaction outputs that merged every older generation, so
    /// recovery knows the inputs are obsolete even if their cleanup
    /// never ran.
    pub fn persist_full(&self, t: &SsTable) -> io::Result<()> {
        self.persist_with_flags(t, RUN_FLAG_FULL_SNAPSHOT)
    }

    fn persist_with_flags(&self, t: &SsTable, flags: u32) -> io::Result<()> {
        let r = write_run_file(
            self.io.as_ref(),
            &self.run_path(t.generation),
            t.run(),
            flags,
        )?;
        self.retries.fetch_add(r as u64, Ordering::Relaxed);
        self.persist_filter(t.generation, t.filter())
    }

    /// (Re-)persist just the filter file of generation `gen` — the
    /// recovery path uses this to heal a rejected filter file after
    /// rebuilding from the run.
    pub fn persist_filter(&self, gen: u64, filter: &FrozenFilter) -> io::Result<()> {
        let r = write_filter_file(
            self.io.as_ref(),
            &self.filter_path(gen),
            filter.table(),
            filter.nbuckets(),
            filter.hasher().fp_mask.count_ones(),
            filter.hasher().seed,
            filter.len(),
        )?;
        self.retries.fetch_add(r as u64, Ordering::Relaxed);
        Ok(())
    }

    /// Remove both files of generation `gen` (missing files are fine —
    /// removal must be idempotent so a crashed compaction swap can be
    /// re-run). The filter (derived) goes first: a crash between the
    /// two leaves a run-only generation, which recovery handles.
    pub fn remove(&self, gen: u64) -> io::Result<()> {
        for path in [self.filter_path(gen), self.run_path(gen)] {
            match self.io.remove_file(&path) {
                Ok(()) => {}
                Err(e) if e.kind() == io::ErrorKind::NotFound => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Generations present in the store (those with a run file —
    /// the run is what makes a generation exist), ascending.
    pub fn generations(&self) -> io::Result<Vec<u64>> {
        let mut gens = Vec::new();
        for name in self.io.read_dir(&self.dir)? {
            if let Some(hex) = name.strip_prefix("sst-").and_then(|s| s.strip_suffix(".run")) {
                if let Ok(gen) = u64::from_str_radix(hex, 16) {
                    gens.push(gen);
                }
            }
        }
        gens.sort_unstable();
        Ok(gens)
    }

    /// Open generation `gen`'s filter, auto-backed (mmap where
    /// supported).
    pub fn load_filter(&self, gen: u64) -> Result<FrozenTable, RecoverError> {
        self.load_filter_with(gen, Backing::Auto)
    }

    /// [`FrozenStore::load_filter`] with an explicit backing choice.
    pub fn load_filter_with(&self, gen: u64, backing: Backing) -> Result<FrozenTable, RecoverError> {
        read_filter_file(self.io.as_ref(), &self.filter_path(gen), backing)
    }

    /// Open and validate generation `gen`'s sorted run.
    pub fn load_run(&self, gen: u64) -> Result<RunFile, RecoverError> {
        read_run_file(self.io.as_ref(), &self.run_path(gen))
    }
}

/// A decoded sorted-run file.
#[derive(Debug, Clone, PartialEq)]
pub struct RunFile {
    /// Header flags ([`RUN_FLAG_FULL_SNAPSHOT`], ...).
    pub flags: u32,
    /// The records, strictly ascending by key.
    pub records: Vec<(u64, Entry)>,
}

impl RunFile {
    /// Does this generation supersede every older one?
    pub fn is_full_snapshot(&self) -> bool {
        self.flags & RUN_FLAG_FULL_SNAPSHOT != 0
    }
}

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// fsync, rename over the target. Transient errors (`EINTR`/`EAGAIN`)
/// are absorbed with bounded retry; returns how many retries it took
/// (callers surface that as `io_retries`). On error the temp file is
/// cleaned up best-effort.
fn atomic_write(io: &dyn StoreIo, path: &Path, bytes: &[u8]) -> io::Result<u32> {
    let tmp = path.with_extension("tmp");
    let mut retries = 0u32;
    let r = retry_transient(|| io.write(&tmp, bytes));
    retries += r.retries;
    if let Err(e) = r.result {
        let _ = io.remove_file(&tmp);
        return Err(e);
    }
    // Durability point: the rename only publishes fsynced bytes.
    let r = retry_transient(|| io.sync(&tmp));
    retries += r.retries;
    if let Err(e) = r.result {
        let _ = io.remove_file(&tmp);
        return Err(e);
    }
    match io.rename(&tmp, path) {
        Ok(()) => Ok(retries),
        Err(e) => {
            let _ = io.remove_file(&tmp);
            Err(e)
        }
    }
}

/// Encode + atomically write a frozen filter file (format v1).
/// Returns the transient-retry count absorbed by the write.
pub fn write_filter_file(
    io: &dyn StoreIo,
    path: &Path,
    words: &[u32],
    nbuckets: usize,
    fp_bits: u32,
    seed: u64,
    len: usize,
) -> io::Result<u32> {
    assert_eq!(words.len(), nbuckets * SLOTS, "words must match geometry");
    let payload_len = words.len() * 4;
    let mut bytes = Vec::with_capacity(PAYLOAD_OFFSET as usize + payload_len);
    bytes.extend_from_slice(&FILTER_MAGIC);
    bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&fp_bits.to_le_bytes());
    bytes.extend_from_slice(&(nbuckets as u64).to_le_bytes());
    bytes.extend_from_slice(&seed.to_le_bytes());
    bytes.extend_from_slice(&(len as u64).to_le_bytes());
    bytes.extend_from_slice(&(payload_len as u64).to_le_bytes());
    let mut payload = Vec::with_capacity(payload_len);
    for w in words {
        payload.extend_from_slice(&w.to_le_bytes());
    }
    bytes.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    let header_sum = fnv1a64(&bytes); // bytes 0..56
    bytes.extend_from_slice(&header_sum.to_le_bytes());
    debug_assert_eq!(bytes.len(), FILTER_HEADER_LEN);
    bytes.resize(PAYLOAD_OFFSET as usize, 0);
    bytes.extend_from_slice(&payload);
    atomic_write(io, path, &bytes)
}

/// Decoded filter-file header.
struct FilterHeader {
    fp_bits: u32,
    nbuckets: usize,
    seed: u64,
    len: usize,
    payload_len: u64,
    payload_sum: u64,
}

fn u32_at(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn decode_filter_header(h: &[u8]) -> Result<FilterHeader, RecoverError> {
    if h.len() < FILTER_HEADER_LEN {
        return Err(RecoverError::Truncated {
            expected: FILTER_HEADER_LEN as u64,
            found: h.len() as u64,
        });
    }
    if h[0..8] != FILTER_MAGIC {
        return Err(RecoverError::BadMagic);
    }
    let version = u32_at(h, 8);
    if version != FORMAT_VERSION {
        return Err(RecoverError::BadVersion { found: version });
    }
    if fnv1a64(&h[0..56]) != u64_at(h, 56) {
        return Err(RecoverError::BadHeader);
    }
    let fp_bits = u32_at(h, 12);
    let nbuckets = u64_at(h, 16);
    let payload_len = u64_at(h, 40);
    if !(1..=32).contains(&fp_bits) {
        return Err(RecoverError::BadParams(format!("fp_bits {fp_bits}")));
    }
    if nbuckets == 0 || nbuckets > (usize::MAX as u64) / (SLOTS as u64) / 4 {
        return Err(RecoverError::BadParams(format!("nbuckets {nbuckets}")));
    }
    if payload_len != nbuckets * SLOTS as u64 * 4 {
        return Err(RecoverError::BadParams(format!(
            "payload_len {payload_len} != nbuckets {nbuckets} * {SLOTS} slots * 4"
        )));
    }
    Ok(FilterHeader {
        fp_bits,
        nbuckets: nbuckets as usize,
        seed: u64_at(h, 24),
        len: u64_at(h, 32) as usize,
        payload_len,
        payload_sum: u64_at(h, 48),
    })
}

/// Open, validate and decode a frozen filter file into a probe-ready
/// [`FrozenTable`]. Every failure is a typed [`RecoverError`]; nothing
/// here panics on malformed input.
pub fn read_filter_file(
    io: &dyn StoreIo,
    path: &Path,
    backing: Backing,
) -> Result<FrozenTable, RecoverError> {
    let mut file = io.open_read(path)?;
    let file_len = file.metadata()?.len();
    let mut header = [0u8; FILTER_HEADER_LEN];
    let mut got = 0;
    while got < header.len() {
        match file.read(&mut header[got..])? {
            0 => {
                return Err(RecoverError::Truncated {
                    expected: FILTER_HEADER_LEN as u64,
                    found: got as u64,
                })
            }
            n => got += n,
        }
    }
    let h = decode_filter_header(&header)?;
    let total = PAYLOAD_OFFSET + h.payload_len;
    if file_len < total {
        return Err(RecoverError::Truncated {
            expected: total,
            found: file_len,
        });
    }
    let words = (h.payload_len / 4) as usize;

    // The mapped path requires native little-endian (words are read in
    // place, no byte-swap pass) and an OS mmap; otherwise fall back to
    // an owned heap decode, which works everywhere.
    let want_map = match backing {
        Backing::Mmap => true,
        Backing::Heap => false,
        Backing::Auto => MmapRegion::supported() && cfg!(target_endian = "little"),
    };
    let bytes = if want_map {
        let region = MmapRegion::map_file(&file, total as usize)?;
        let payload = &region.as_bytes()[PAYLOAD_OFFSET as usize..];
        let found = fnv1a64(payload);
        if found != h.payload_sum {
            return Err(RecoverError::ChecksumMismatch {
                expected: h.payload_sum,
                found,
            });
        }
        FrozenBytes::Mapped {
            region: Arc::new(region),
            offset_bytes: PAYLOAD_OFFSET as usize,
            words,
        }
    } else {
        use std::io::Seek;
        file.seek(io::SeekFrom::Start(PAYLOAD_OFFSET))?;
        let mut payload = vec![0u8; h.payload_len as usize];
        file.read_exact(&mut payload)?;
        let found = fnv1a64(&payload);
        if found != h.payload_sum {
            return Err(RecoverError::ChecksumMismatch {
                expected: h.payload_sum,
                found,
            });
        }
        let decoded: Vec<u32> = payload
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        FrozenBytes::Heap(decoded.into())
    };
    Ok(FrozenTable::from_bytes(bytes, h.nbuckets, h.fp_bits, h.seed, h.len))
}

/// Encode + atomically write a sorted-run file (format v2: each
/// record is a 13-byte prefix `key | tag | value_len` followed by the
/// value bytes). Returns the transient-retry count absorbed.
pub fn write_run_file(
    io: &dyn StoreIo,
    path: &Path,
    run: &[(u64, Entry)],
    flags: u32,
) -> io::Result<u32> {
    debug_assert_eq!(flags & !RUN_FLAGS_KNOWN, 0, "unknown run flags");
    let payload: usize = run.iter().map(|(_, e)| RUN_RECORD_LEN + e.value_len()).sum();
    let mut records = Vec::with_capacity(payload);
    for (k, e) in run {
        records.extend_from_slice(&k.to_le_bytes());
        match e {
            Entry::Put { value } => {
                records.push(1);
                records.extend_from_slice(&(value.len() as u32).to_le_bytes());
                records.extend_from_slice(value);
            }
            Entry::Tombstone => {
                records.push(0);
                records.extend_from_slice(&0u32.to_le_bytes());
            }
        }
    }
    let mut bytes = Vec::with_capacity(RUN_HEADER_LEN + records.len());
    bytes.extend_from_slice(&RUN_MAGIC);
    bytes.extend_from_slice(&RUN_FORMAT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&flags.to_le_bytes());
    bytes.extend_from_slice(&(run.len() as u64).to_le_bytes());
    bytes.extend_from_slice(&fnv1a64(&records).to_le_bytes());
    let header_sum = fnv1a64(&bytes); // bytes 0..32
    bytes.extend_from_slice(&header_sum.to_le_bytes());
    debug_assert_eq!(bytes.len(), RUN_HEADER_LEN);
    bytes.extend_from_slice(&records);
    atomic_write(io, path, &bytes)
}

/// Open, validate and decode a sorted-run file (v2, or legacy v1 with
/// values materialized as zeroes).
pub fn read_run_file(io: &dyn StoreIo, path: &Path) -> Result<RunFile, RecoverError> {
    let bytes = io.read(path)?;
    if bytes.len() < RUN_HEADER_LEN {
        return Err(RecoverError::Truncated {
            expected: RUN_HEADER_LEN as u64,
            found: bytes.len() as u64,
        });
    }
    if bytes[0..8] != RUN_MAGIC {
        return Err(RecoverError::BadMagic);
    }
    let version = u32_at(&bytes, 8);
    if version != RUN_FORMAT_VERSION && version != RUN_VERSION_LEGACY {
        return Err(RecoverError::BadVersion { found: version });
    }
    if fnv1a64(&bytes[0..32]) != u64_at(&bytes, 32) {
        return Err(RecoverError::BadHeader);
    }
    let flags = u32_at(&bytes, 12);
    if flags & !RUN_FLAGS_KNOWN != 0 {
        return Err(RecoverError::BadParams(format!(
            "unknown run flags {flags:#010x}"
        )));
    }
    let count = u64_at(&bytes, 16);

    // Pass 1 — extent: find where the records region ends. Fixed
    // arithmetic for v1; a bounds-checked prefix walk for v2 (records
    // are variable-length, so the extent is data-dependent). Length
    // problems surface as `Truncated` *before* the checksum runs, per
    // the outside-in validation order.
    let file_len = bytes.len() as u64;
    let need = if version == RUN_VERSION_LEGACY {
        let need = RUN_HEADER_LEN as u64 + count.saturating_mul(RUN_RECORD_LEN as u64);
        if file_len < need {
            return Err(RecoverError::Truncated {
                expected: need,
                found: file_len,
            });
        }
        need
    } else {
        let mut need = RUN_HEADER_LEN as u64;
        for _ in 0..count {
            let prefix_end = need.saturating_add(RUN_RECORD_LEN as u64);
            if file_len < prefix_end {
                return Err(RecoverError::Truncated {
                    expected: prefix_end,
                    found: file_len,
                });
            }
            let vlen = u32_at(&bytes, need as usize + 9);
            if vlen > MAX_VALUE_LEN {
                return Err(RecoverError::BadParams(format!("value_len {vlen}")));
            }
            need = prefix_end + vlen as u64;
            if file_len < need {
                return Err(RecoverError::Truncated {
                    expected: need,
                    found: file_len,
                });
            }
        }
        need
    };
    if file_len != need {
        return Err(RecoverError::BadParams(format!(
            "{} trailing bytes after {count} records",
            file_len - need
        )));
    }

    // Pass 2 — integrity: the records checksum over the whole region.
    let records = &bytes[RUN_HEADER_LEN..need as usize];
    let found = fnv1a64(records);
    let expected = u64_at(&bytes, 24);
    if found != expected {
        return Err(RecoverError::ChecksumMismatch { expected, found });
    }

    // Pass 3 — decode, validating tags and strict key order.
    let mut run = Vec::with_capacity(count as usize);
    let mut prev: Option<u64> = None;
    let mut off = 0usize;
    for _ in 0..count {
        let rec = &records[off..];
        let k = u64_at(rec, 0);
        let vlen = u32_at(rec, 9) as usize;
        let entry = match rec[8] {
            1 => {
                if version == RUN_VERSION_LEGACY {
                    // v1 carried only the length; materialize zeroes.
                    Entry::put_sized(vlen as u32)
                } else {
                    Entry::put(&rec[RUN_RECORD_LEN..RUN_RECORD_LEN + vlen])
                }
            }
            0 => {
                if version != RUN_VERSION_LEGACY && vlen != 0 {
                    return Err(RecoverError::BadParams(format!(
                        "tombstone with value_len {vlen}"
                    )));
                }
                Entry::Tombstone
            }
            tag => return Err(RecoverError::BadParams(format!("record tag {tag}"))),
        };
        if let Some(p) = prev {
            if k <= p {
                return Err(RecoverError::BadParams(format!(
                    "run not strictly sorted: {k} after {p}"
                )));
            }
        }
        prev = Some(k);
        run.push((k, entry));
        off += RUN_RECORD_LEN;
        if version != RUN_VERSION_LEGACY && rec[8] == 1 {
            off += vlen;
        }
    }
    Ok(RunFile { flags, records: run })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{BatchedFilter, MembershipFilter};
    use std::fs;

    /// Unique scratch dir per test (no tempfile crate offline).
    fn scratch(tag: &str) -> PathBuf {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "ocf-frozen-{tag}-{}-{n}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_table(n: u64, gen: u64) -> SsTable {
        let mut run: Vec<(u64, Entry)> = (0..n)
            .map(|k| (k * 3, Entry::put(&(k * 3).to_le_bytes())))
            .collect();
        run.push((n * 3 + 1, Entry::Tombstone));
        run.sort_by_key(|&(k, _)| k);
        SsTable::from_sorted_run(run, gen, 16, 0xFEED ^ gen)
    }

    #[test]
    fn persist_load_round_trip() {
        let dir = scratch("roundtrip");
        let store = FrozenStore::open(&dir).unwrap();
        let t = sample_table(2000, 3);
        store.persist(&t).unwrap();
        assert_eq!(store.generations().unwrap(), vec![3]);

        let run = store.load_run(3).unwrap();
        assert_eq!(run.records, t.run());
        assert!(!run.is_full_snapshot(), "plain persist writes no flags");

        let loaded = store.load_filter(3).unwrap();
        assert_eq!(loaded.words(), t.filter().table(), "bit-identical words");
        assert_eq!(loaded.nbuckets(), t.filter().nbuckets());
        for &(k, _) in t.run() {
            assert!(loaded.contains(k), "key {k}");
        }
        for k in (9_000_000..9_010_000u64).step_by(7) {
            assert_eq!(loaded.contains(k), t.filter().contains(k), "key {k}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn heap_and_mmap_backings_agree() {
        let dir = scratch("backing");
        let store = FrozenStore::open(&dir).unwrap();
        let t = sample_table(5000, 1);
        store.persist(&t).unwrap();
        let heap = store.load_filter_with(1, Backing::Heap).unwrap();
        assert!(!heap.is_mapped());
        let auto = store.load_filter(1).unwrap();
        if cfg!(all(unix, target_endian = "little")) {
            assert!(auto.is_mapped(), "auto must map on unix/LE");
            assert_eq!(auto.backing(), "mmap");
        }
        assert_eq!(heap.words(), auto.words());
        let probes: Vec<u64> = (0..20_000u64).collect();
        assert_eq!(heap.contains_batch(&probes), auto.contains_batch(&probes));
        // mapped tables cost no heap for their words
        if auto.is_mapped() {
            assert_eq!(MembershipFilter::memory_bytes(&auto), 0);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_filter_rejected() {
        let dir = scratch("trunc");
        let store = FrozenStore::open(&dir).unwrap();
        let t = sample_table(500, 1);
        store.persist(&t).unwrap();
        let path = store.filter_path(1);
        let full = fs::read(&path).unwrap();
        // cut mid-payload
        fs::write(&path, &full[..full.len() - 100]).unwrap();
        match store.load_filter(1) {
            Err(RecoverError::Truncated { .. }) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        // cut mid-header
        fs::write(&path, &full[..32]).unwrap();
        match store.load_filter(1) {
            Err(RecoverError::Truncated { .. }) => {}
            other => panic!("want Truncated, got {other:?}"),
        }
        // empty file
        fs::write(&path, b"").unwrap();
        assert!(store.load_filter(1).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn flipped_payload_byte_rejected() {
        let dir = scratch("flip");
        let store = FrozenStore::open(&dir).unwrap();
        store.persist(&sample_table(500, 1)).unwrap();
        let path = store.filter_path(1);
        let mut bytes = fs::read(&path).unwrap();
        let mid = PAYLOAD_OFFSET as usize + (bytes.len() - PAYLOAD_OFFSET as usize) / 2;
        bytes[mid] ^= 0x40;
        fs::write(&path, &bytes).unwrap();
        for backing in [Backing::Heap, Backing::Auto] {
            match store.load_filter_with(1, backing) {
                Err(RecoverError::ChecksumMismatch { .. }) => {}
                other => panic!("want ChecksumMismatch ({backing:?}), got {other:?}"),
            }
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bumped_version_rejected() {
        let dir = scratch("version");
        let store = FrozenStore::open(&dir).unwrap();
        store.persist(&sample_table(200, 1)).unwrap();
        let path = store.filter_path(1);
        let mut bytes = fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
        // re-seal the header checksum so ONLY the version differs —
        // the version check must fire on its own
        let sum = fnv1a64(&bytes[0..56]);
        bytes[56..64].copy_from_slice(&sum.to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        match store.load_filter(1) {
            Err(RecoverError::BadVersion { found }) => {
                assert_eq!(found, FORMAT_VERSION + 1)
            }
            other => panic!("want BadVersion, got {other:?}"),
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_magic_and_header_corruption_rejected() {
        let dir = scratch("magic");
        let store = FrozenStore::open(&dir).unwrap();
        store.persist(&sample_table(100, 1)).unwrap();
        let path = store.filter_path(1);
        let good = fs::read(&path).unwrap();

        let mut bad = good.clone();
        bad[0] = b'X';
        fs::write(&path, &bad).unwrap();
        assert!(matches!(store.load_filter(1), Err(RecoverError::BadMagic)));

        // corrupt a header field without re-sealing → BadHeader
        let mut bad = good.clone();
        bad[16] ^= 0xFF; // nbuckets
        fs::write(&path, &bad).unwrap();
        assert!(matches!(store.load_filter(1), Err(RecoverError::BadHeader)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_filter_is_not_a_rejection() {
        let dir = scratch("missing");
        let store = FrozenStore::open(&dir).unwrap();
        let err = store.load_filter(42).unwrap_err();
        assert!(!err.is_rejection(), "absent file is not a rejection");
        store.persist(&sample_table(100, 1)).unwrap();
        let path = store.filter_path(1);
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        fs::write(&path, &bytes).unwrap();
        assert!(store.load_filter(1).unwrap_err().is_rejection());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_file_corruption_rejected() {
        let dir = scratch("run");
        let store = FrozenStore::open(&dir).unwrap();
        store.persist(&sample_table(300, 1)).unwrap();
        let path = store.run_path(1);
        let good = fs::read(&path).unwrap();

        // flip a key byte (the first record's first byte): the extent
        // walk is unaffected, so the records checksum must catch it
        let mut bad = good.clone();
        bad[RUN_HEADER_LEN] ^= 0x80;
        fs::write(&path, &bad).unwrap();
        assert!(matches!(
            store.load_run(1),
            Err(RecoverError::ChecksumMismatch { .. })
        ));

        fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(matches!(store.load_run(1), Err(RecoverError::Truncated { .. })));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_run_round_trips() {
        let dir = scratch("empty");
        let path = dir.join("empty.run");
        write_run_file(&RealIo, &path, &[], 0).unwrap();
        assert_eq!(read_run_file(&RealIo, &path).unwrap().records, vec![]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_values_round_trip_bytes() {
        let dir = scratch("values");
        let path = dir.join("vals.run");
        let run = vec![
            (1u64, Entry::put(b"alpha")),
            (2, Entry::Tombstone),
            (3, Entry::put(b"")),
            (4, Entry::put(b"a much longer payload with \x00 bytes \xff inside")),
        ];
        write_run_file(&RealIo, &path, &run, 0).unwrap();
        let decoded = read_run_file(&RealIo, &path).unwrap();
        assert_eq!(decoded.records, run, "values must survive the disk trip");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_v1_run_still_decodes_with_zeroed_values() {
        // Hand-build a version-1 run file (fixed 13-byte records, no
        // value bytes) exactly as the PR-6 writer laid it out: the
        // migration contract is read-old/write-new.
        let dir = scratch("legacy");
        let path = dir.join("v1.run");
        let mut records = Vec::new();
        for (k, tag, vlen) in [(5u64, 1u8, 8u32), (9, 0, 0), (12, 1, 0)] {
            records.extend_from_slice(&k.to_le_bytes());
            records.push(tag);
            records.extend_from_slice(&vlen.to_le_bytes());
        }
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&RUN_MAGIC);
        bytes.extend_from_slice(&RUN_VERSION_LEGACY.to_le_bytes());
        bytes.extend_from_slice(&0u32.to_le_bytes()); // flags
        bytes.extend_from_slice(&3u64.to_le_bytes()); // count
        bytes.extend_from_slice(&fnv1a64(&records).to_le_bytes());
        let header_sum = fnv1a64(&bytes);
        bytes.extend_from_slice(&header_sum.to_le_bytes());
        bytes.extend_from_slice(&records);
        fs::write(&path, &bytes).unwrap();

        let decoded = read_run_file(&RealIo, &path).unwrap();
        assert_eq!(
            decoded.records,
            vec![
                (5, Entry::put_sized(8)),
                (9, Entry::Tombstone),
                (12, Entry::put_sized(0)),
            ],
            "v1 values materialize as zeroes of the recorded length"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_snapshot_flag_round_trips_and_unknown_flags_rejected() {
        let dir = scratch("flags");
        let store = FrozenStore::open(&dir).unwrap();
        let t = sample_table(100, 5);
        store.persist_full(&t).unwrap();
        assert!(store.load_run(5).unwrap().is_full_snapshot());

        // forge an unknown flag bit (re-sealing the header so only the
        // flags check can fire) → rejected, not misinterpreted
        let path = store.run_path(5);
        let mut bytes = fs::read(&path).unwrap();
        bytes[12..16].copy_from_slice(&(RUN_FLAG_FULL_SNAPSHOT | 0x8000_0000u32).to_le_bytes());
        let sum = fnv1a64(&bytes[0..32]);
        bytes[32..40].copy_from_slice(&sum.to_le_bytes());
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(store.load_run(5), Err(RecoverError::BadParams(_))));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn generations_lists_runs_sorted() {
        let dir = scratch("gens");
        let store = FrozenStore::open(&dir).unwrap();
        for gen in [7u64, 2, 11] {
            store.persist(&sample_table(50, gen)).unwrap();
        }
        // stray files are ignored
        fs::write(dir.join("notes.txt"), b"hi").unwrap();
        fs::write(dir.join("sst-zzzz.run"), b"junk").unwrap();
        assert_eq!(store.generations().unwrap(), vec![2, 7, 11]);
        store.remove(7).unwrap();
        store.remove(7).unwrap(); // idempotent
        assert_eq!(store.generations().unwrap(), vec![2, 11]);
        assert!(!store.filter_path(7).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn payload_offset_is_page_aligned_in_file() {
        let dir = scratch("align");
        let store = FrozenStore::open(&dir).unwrap();
        let t = sample_table(100, 1);
        store.persist(&t).unwrap();
        let bytes = fs::read(store.filter_path(1)).unwrap();
        assert_eq!(PAYLOAD_OFFSET % 4096, 0);
        assert_eq!(
            bytes.len() as u64,
            PAYLOAD_OFFSET + (t.filter().table().len() * 4) as u64
        );
        // padding is zeroed
        assert!(bytes[FILTER_HEADER_LEN..PAYLOAD_OFFSET as usize]
            .iter()
            .all(|&b| b == 0));
        let _ = fs::remove_dir_all(&dir);
    }
}

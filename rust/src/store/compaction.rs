//! Size-tiered compaction: merge sorted runs, newest-wins, drop
//! tombstones at the bottom level.

use super::memtable::Entry;
use super::sstable::SsTable;

/// Compaction trigger/shape parameters.
#[derive(Debug, Clone, Copy)]
pub struct CompactionPolicy {
    /// Compact when the node holds more than this many SSTables.
    pub max_tables: usize,
    /// Drop tombstones during compaction (safe when compacting down to
    /// one table — nothing older can be shadowed).
    pub drop_tombstones: bool,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        Self {
            max_tables: 4,
            drop_tombstones: true,
        }
    }
}

/// K-way merge of SSTables into one sorted run. `tables` must be in
/// generation order (oldest first); for duplicate keys the *newest*
/// version wins. Tombstones are dropped if `drop_tombstones`.
pub fn merge_tables(tables: &[SsTable], drop_tombstones: bool) -> Vec<(u64, Entry)> {
    // collect newest-wins via reverse iteration: later (newer) tables
    // overwrite earlier entries in the map
    let mut merged: std::collections::BTreeMap<u64, Entry> = std::collections::BTreeMap::new();
    for t in tables {
        // tables is oldest→newest, so straight insertion overwrites
        for (k, e) in t.iter() {
            merged.insert(*k, e.clone());
        }
    }
    merged
        .into_iter()
        .filter(|(_, e)| !(drop_tombstones && matches!(e, Entry::Tombstone)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sst(gen: u64, entries: Vec<(u64, Entry)>) -> SsTable {
        SsTable::from_sorted_run(entries, gen, 16, gen ^ 0xABCD)
    }

    #[test]
    fn newest_version_wins() {
        let old = sst(1, vec![(1, Entry::put_sized(1)), (2, Entry::put_sized(1))]);
        let new = sst(2, vec![(2, Entry::put_sized(99))]);
        let merged = merge_tables(&[old, new], true);
        assert_eq!(
            merged,
            vec![(1, Entry::put_sized(1)), (2, Entry::put_sized(99))]
        );
    }

    #[test]
    fn merged_values_are_the_newest_bytes() {
        let old = sst(1, vec![(7, Entry::put(b"stale"))]);
        let new = sst(2, vec![(7, Entry::put(b"fresh"))]);
        let merged = merge_tables(&[old, new], true);
        assert_eq!(merged, vec![(7, Entry::put(b"fresh"))]);
    }

    #[test]
    fn tombstones_shadow_then_drop() {
        let old = sst(1, vec![(5, Entry::put_sized(1))]);
        let new = sst(2, vec![(5, Entry::Tombstone)]);
        let merged = merge_tables(&[old.clone(), new.clone()], true);
        assert!(merged.is_empty(), "tombstone must erase the old put");
        let kept = merge_tables(&[old, new], false);
        assert_eq!(kept, vec![(5, Entry::Tombstone)]);
    }

    #[test]
    fn merge_preserves_sort_order() {
        let a = sst(1, vec![(1, Entry::put_sized(0)), (5, Entry::put_sized(0))]);
        let b = sst(2, vec![(2, Entry::put_sized(0)), (9, Entry::put_sized(0))]);
        let merged = merge_tables(&[a, b], true);
        let keys: Vec<u64> = merged.iter().map(|&(k, _)| k).collect();
        assert_eq!(keys, vec![1, 2, 5, 9]);
    }

    #[test]
    fn empty_merge() {
        assert!(merge_tables(&[], true).is_empty());
    }
}

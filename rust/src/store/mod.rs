//! The Cassandra-like per-node storage substrate (paper §I.A/§I.B).
//!
//! Write path: ops land in a [`Memtable`]; when the flush policy fires
//! the memtable is frozen into an immutable [`SsTable`] with a *frozen*
//! membership filter snapshot, and a fresh memtable starts. Size-tiered
//! [`compaction`] merges tables and drops tombstones.
//!
//! The paper's burst-tolerance claim lives exactly here: with a
//! fixed-capacity filter, filter saturation forces **premature
//! flushes** ("can warrant flushes in databases like Cassandra, leading
//! to a complete rebuild of the in-memory data structures"); with OCF
//! the filter resizes in place and flushes happen only when the
//! *memtable* is actually full. [`FlushPolicy`] captures both triggers
//! so experiments can measure the difference (E6).
//!
//! Durability is opt-in per node: with [`NodeConfig::persist_dir`]
//! unset, SSTables are in-memory sorted runs with the same read
//! amplification and filter behaviour a disk-backed implementation
//! would show (the pre-persistence behaviour, still the default for
//! experiments). With it set, the [`frozen`] module persists every
//! frozen generation — a checksummed run file (ground truth) plus a
//! versioned, page-aligned filter file served back **zero-copy via
//! mmap** on recovery — and [`StorageNode::recover`] reopens a node
//! from disk, rebuilding only what fails validation. See
//! `rust/src/store/README.md` for the on-disk format and the recovery
//! state machine.
//!
//! Since PR 7 the persistent tier closes the acknowledged-write gap:
//! a [`wal`] (write-ahead log) records every put/delete *before* the
//! memtable applies it, so [`StorageNode::recover`] replays exactly
//! the acknowledged operations that had not reached a durable
//! SSTable — no acknowledged write is ever lost to a crash. All file
//! operations go through the [`StoreIo`] seam ([`io`] module), whose
//! deterministic [`FaultyIo`] injector powers the systematic
//! crash-point sweep in `testutil::crash`.

pub mod compaction;
pub mod flush;
pub mod frozen;
pub mod io;
pub mod memtable;
pub mod node;
pub mod sstable;
pub mod wal;

pub use flush::{FlushPolicy, FlushReason};
pub use frozen::{Backing, FrozenStore, RecoverError, RunFile};
pub use io::{FaultConfig, FaultyIo, RealIo, StoreIo};
pub use memtable::{Entry, Memtable, Value};
pub use node::{NodeConfig, NodeStats, StorageNode};
pub use sstable::{FrozenFilter, SsTable};
pub use wal::{FsyncPolicy, Wal, WalConfig, WalRecord};

//! The Cassandra-like per-node storage substrate (paper §I.A/§I.B).
//!
//! Write path: ops land in a [`Memtable`]; when the flush policy fires
//! the memtable is frozen into an immutable [`SsTable`] with a *frozen*
//! membership filter snapshot, and a fresh memtable starts. Size-tiered
//! [`compaction`] merges tables and drops tombstones.
//!
//! The paper's burst-tolerance claim lives exactly here: with a
//! fixed-capacity filter, filter saturation forces **premature
//! flushes** ("can warrant flushes in databases like Cassandra, leading
//! to a complete rebuild of the in-memory data structures"); with OCF
//! the filter resizes in place and flushes happen only when the
//! *memtable* is actually full. [`FlushPolicy`] captures both triggers
//! so experiments can measure the difference (E6).
//!
//! The "disk" is simulated in-memory (this container has no durable
//! store requirement; DESIGN.md §substitutions) — SSTables are
//! immutable sorted runs with the same read amplification and filter
//! behaviour a disk-backed implementation would show.

pub mod compaction;
pub mod flush;
pub mod memtable;
pub mod node;
pub mod sstable;

pub use flush::{FlushPolicy, FlushReason};
pub use memtable::{Entry, Memtable};
pub use node::{NodeConfig, NodeStats, StorageNode};
pub use sstable::{FrozenFilter, SsTable};

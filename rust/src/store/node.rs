//! A storage node: memtable + SSTables + the node-level OCF filter.
//!
//! This is the unit the paper's experiments live on. The node-level
//! filter tracks the node's *live key population* (memtable + SSTables,
//! net of deletes) and short-circuits reads for definitely-absent keys;
//! each SSTable additionally carries its own frozen filter, Cassandra
//! style, to prune run probes.
//!
//! Read path for `get(k)`:
//! 1. node OCF says "absent" → done (no memtable/SSTable work);
//! 2. memtable (put → found, tombstone → absent);
//! 3. SSTables newest→oldest, each gated by its frozen filter.
//!
//! Write path: memtable upsert + OCF insert; then the [`FlushPolicy`]
//! decides whether to freeze (premature flushes are exactly what a
//! pressured fixed filter causes — experiment E6).

use super::compaction::{merge_tables, CompactionPolicy};
use super::flush::{FlushPolicy, FlushReason};
use super::memtable::{Entry, Memtable};
use super::sstable::SsTable;
use crate::filter::{FilterError, FilterStats, MembershipFilter, Mode, Ocf, OcfConfig, ShardedOcf};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Node configuration.
#[derive(Debug, Clone, Copy)]
pub struct NodeConfig {
    pub node_id: u64,
    pub filter: OcfConfig,
    /// Shards for the node-level filter: 1 = plain single-threaded
    /// [`Ocf`]; > 1 = the concurrent [`ShardedOcf`] front-end (rounded
    /// up to a power of two).
    pub filter_shards: usize,
    pub flush: FlushPolicy,
    pub compaction: CompactionPolicy,
    /// Value-size proxy for puts (bytes accounted in the memtable).
    pub value_len: u32,
}

impl Default for NodeConfig {
    fn default() -> Self {
        Self {
            node_id: 0,
            filter: OcfConfig::default(),
            filter_shards: 1,
            flush: FlushPolicy::default(),
            compaction: CompactionPolicy::default(),
            value_len: 64,
        }
    }
}

/// The node-level live-set filter: plain OCF or the sharded concurrent
/// front-end, selected by [`NodeConfig::filter_shards`]. Both variants
/// expose the same surface, so the node's read/write paths are
/// agnostic to the choice.
#[derive(Debug)]
pub enum NodeFilter {
    Single(Box<Ocf>),
    Sharded(ShardedOcf),
}

impl NodeFilter {
    fn build(cfg: &NodeConfig, initial_capacity: usize) -> Self {
        let fcfg = OcfConfig {
            initial_capacity,
            ..cfg.filter
        };
        if cfg.filter_shards > 1 {
            NodeFilter::Sharded(ShardedOcf::with_shards(cfg.filter_shards, fcfg))
        } else {
            NodeFilter::Single(Box::new(Ocf::new(fcfg)))
        }
    }

    pub fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        match self {
            NodeFilter::Single(f) => f.insert(key),
            NodeFilter::Sharded(f) => f.insert_one(key),
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        match self {
            NodeFilter::Single(f) => f.contains(key),
            NodeFilter::Sharded(f) => f.contains_one(key),
        }
    }

    /// Exact membership via the authoritative keystore(s).
    pub fn contains_exact(&self, key: u64) -> bool {
        match self {
            NodeFilter::Single(f) => f.contains_exact(key),
            NodeFilter::Sharded(f) => f.contains_exact(key),
        }
    }

    pub fn delete(&mut self, key: u64) -> bool {
        match self {
            NodeFilter::Single(f) => f.delete(key),
            NodeFilter::Sharded(f) => f.delete_one(key),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            NodeFilter::Single(f) => f.len(),
            NodeFilter::Sharded(f) => f.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        match self {
            NodeFilter::Single(f) => f.capacity(),
            NodeFilter::Sharded(f) => f.capacity(),
        }
    }

    pub fn occupancy(&self) -> f64 {
        match self {
            NodeFilter::Single(f) => f.occupancy(),
            NodeFilter::Sharded(f) => f.occupancy(),
        }
    }

    pub fn memory_bytes(&self) -> usize {
        match self {
            NodeFilter::Single(f) => f.memory_bytes(),
            NodeFilter::Sharded(f) => f.memory_bytes(),
        }
    }

    /// Aggregated filter stats (merged across shards when sharded).
    pub fn stats(&self) -> FilterStats {
        match self {
            NodeFilter::Single(f) => f.stats(),
            NodeFilter::Sharded(f) => f.stats(),
        }
    }

    /// Batched membership through the prefetch-pipelined probe engine
    /// (positionally aligned with `keys`).
    pub fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        match self {
            NodeFilter::Single(f) => f.contains_batch(keys),
            NodeFilter::Sharded(f) => f.contains_batch(keys),
        }
    }
}

impl NodeConfig {
    /// The fixed-filter ("traditional Cassandra") arm: static filter,
    /// flush under filter pressure.
    pub fn fixed_filter(capacity: usize) -> Self {
        Self {
            filter: OcfConfig {
                mode: Mode::Static,
                initial_capacity: capacity,
                ..OcfConfig::default()
            },
            flush: FlushPolicy::default().with_filter_pressure(0.85),
            ..Self::default()
        }
    }
}

/// Node operation counters. Write-path counters stay plain `u64` (the
/// write path holds `&mut self`); read-path counters are relaxed
/// atomics so `get`/`get_batch` take `&self` and concurrent readers can
/// drive the node filter directly (ROADMAP "sharded store read path").
#[derive(Debug, Default)]
pub struct NodeStats {
    pub puts: u64,
    pub deletes: u64,
    gets: AtomicU64,
    /// Reads answered "absent" by the node filter alone.
    filter_short_circuits: AtomicU64,
    /// SSTable probes skipped thanks to per-table frozen filters.
    sstable_probes_skipped: AtomicU64,
    /// SSTable probes that went to binary search.
    sstable_probes: AtomicU64,
    pub flushes: u64,
    pub flushes_premature: u64,
    pub compactions: u64,
}

impl NodeStats {
    pub fn gets(&self) -> u64 {
        self.gets.load(Relaxed)
    }

    /// Reads answered "absent" by the node filter alone.
    pub fn filter_short_circuits(&self) -> u64 {
        self.filter_short_circuits.load(Relaxed)
    }

    /// SSTable probes skipped thanks to per-table frozen filters.
    pub fn sstable_probes_skipped(&self) -> u64 {
        self.sstable_probes_skipped.load(Relaxed)
    }

    /// SSTable probes that went to binary search.
    pub fn sstable_probes(&self) -> u64 {
        self.sstable_probes.load(Relaxed)
    }
}

impl Clone for NodeStats {
    fn clone(&self) -> Self {
        Self {
            puts: self.puts,
            deletes: self.deletes,
            gets: AtomicU64::new(self.gets()),
            filter_short_circuits: AtomicU64::new(self.filter_short_circuits()),
            sstable_probes_skipped: AtomicU64::new(self.sstable_probes_skipped()),
            sstable_probes: AtomicU64::new(self.sstable_probes()),
            flushes: self.flushes,
            flushes_premature: self.flushes_premature,
            compactions: self.compactions,
        }
    }
}

/// A single storage node.
#[derive(Debug)]
pub struct StorageNode {
    cfg: NodeConfig,
    memtable: Memtable,
    sstables: Vec<SsTable>,
    /// Node-level live-set filter (the paper's OCF; optionally sharded).
    filter: NodeFilter,
    next_generation: u64,
    pub stats: NodeStats,
}

impl StorageNode {
    pub fn new(cfg: NodeConfig) -> Self {
        Self {
            memtable: Memtable::new(),
            sstables: Vec::new(),
            filter: NodeFilter::build(&cfg, cfg.filter.initial_capacity),
            next_generation: 1,
            cfg,
            stats: NodeStats::default(),
        }
    }

    pub fn config(&self) -> &NodeConfig {
        &self.cfg
    }

    pub fn filter(&self) -> &NodeFilter {
        &self.filter
    }

    pub fn sstable_count(&self) -> usize {
        self.sstables.len()
    }

    pub fn memtable_len(&self) -> usize {
        self.memtable.len()
    }

    /// Total live keys on the node (exact, via the filter's keystore).
    pub fn live_keys(&self) -> usize {
        self.filter.len()
    }

    /// Insert/overwrite a key. Returns Err only in Static filter mode
    /// when the filter is wedged *and* flushing can't relieve it.
    pub fn put(&mut self, key: u64) -> Result<(), crate::filter::FilterError> {
        self.stats.puts += 1;
        self.memtable.put(key, self.cfg.value_len);
        match self.filter.insert(key) {
            Ok(()) => {}
            Err(e) => {
                // Fixed-filter node: saturation → forced (premature)
                // flush, then retry once after the flush cleared the
                // memtable; the filter itself stays static so the
                // failure is visible to stats/experiments.
                self.flush(FlushReason::FilterPressure);
                if self.filter.insert(key).is_err() {
                    return Err(e);
                }
            }
        }
        self.maybe_flush();
        Ok(())
    }

    /// Delete a key (verified against the node's authoritative state —
    /// the paper's safe-delete path).
    pub fn delete(&mut self, key: u64) -> bool {
        self.stats.deletes += 1;
        // authority: the OCF keystore tracks the node's live set exactly
        if !self.filter.contains_exact(key) {
            return false;
        }
        self.memtable.delete(key);
        self.filter.delete(key);
        self.maybe_flush();
        true
    }

    /// Membership-test read. Takes `&self` (read-path stats are
    /// relaxed atomics), so any number of reader threads can probe the
    /// node concurrently with each other.
    pub fn get(&self, key: u64) -> bool {
        self.stats.gets.fetch_add(1, Relaxed);
        if !self.filter.contains(key) {
            self.stats.filter_short_circuits.fetch_add(1, Relaxed);
            return false;
        }
        self.read_tables(key)
    }

    /// Batched membership reads: one bulk hash + the prefetch-pipelined
    /// filter probe short-circuit definitely-absent keys (the node's
    /// negative-lookup fast path), then only survivors walk the
    /// memtable/SSTable read path. Positionally aligned with `keys`;
    /// answer-identical to calling [`StorageNode::get`] per key.
    pub fn get_batch(&self, keys: &[u64]) -> Vec<bool> {
        self.stats.gets.fetch_add(keys.len() as u64, Relaxed);
        let pass = self.filter.contains_batch(keys);
        let mut short = 0u64;
        let out = keys
            .iter()
            .zip(&pass)
            .map(|(&k, &p)| {
                if p {
                    self.read_tables(k)
                } else {
                    short += 1;
                    false
                }
            })
            .collect();
        self.stats.filter_short_circuits.fetch_add(short, Relaxed);
        out
    }

    /// The post-filter read path: memtable, then SSTables newest→oldest
    /// gated by their frozen per-table filters.
    fn read_tables(&self, key: u64) -> bool {
        match self.memtable.get(key) {
            Some(Entry::Put { .. }) => return true,
            Some(Entry::Tombstone) => return false,
            None => {}
        }
        for t in self.sstables.iter().rev() {
            if !t.might_contain(key) {
                self.stats.sstable_probes_skipped.fetch_add(1, Relaxed);
                continue;
            }
            self.stats.sstable_probes.fetch_add(1, Relaxed);
            match t.get(key) {
                Some(Entry::Put { .. }) => return true,
                Some(Entry::Tombstone) => return false,
                None => {}
            }
        }
        false
    }

    fn maybe_flush(&mut self) {
        if let Some(reason) = self.cfg.flush.should_flush(
            self.memtable.approx_bytes(),
            self.memtable.len(),
            self.filter.occupancy(),
        ) {
            self.flush(reason);
        }
    }

    /// Freeze the memtable into an SSTable.
    pub fn flush(&mut self, reason: FlushReason) {
        if self.memtable.is_empty() {
            return;
        }
        self.stats.flushes += 1;
        if reason == FlushReason::FilterPressure {
            self.stats.flushes_premature += 1;
        }
        let run = self.memtable.drain_sorted();
        let gen = self.next_generation;
        self.next_generation += 1;
        let seed = self.cfg.filter.seed ^ gen;
        self.sstables
            .push(SsTable::from_sorted_run(run, gen, self.cfg.filter.fp_bits, seed));
        // Fixed-filter nodes rebuild their node filter from the live set
        // after a pressure flush ("complete rebuild of the in-memory
        // data structures" — the cost the paper wants to avoid).
        if reason == FlushReason::FilterPressure {
            self.rebuild_node_filter();
        }
        self.maybe_compact();
    }

    fn rebuild_node_filter(&mut self) {
        let mut fresh = NodeFilter::build(
            &self.cfg,
            (self.filter.len() * 2).max(self.cfg.filter.initial_capacity),
        );
        // live set = current filter keystore (exact)
        let mut keys: Vec<u64> = Vec::with_capacity(self.filter.len());
        self.for_each_live_key(|k| keys.push(k));
        for k in keys {
            let _ = fresh.insert(k);
        }
        self.filter = fresh;
    }

    /// Enumerate the node's live keys (memtable ∪ sstables, minus
    /// tombstones). Exactness is guaranteed by replaying newest-first.
    fn for_each_live_key(&self, mut f: impl FnMut(u64)) {
        let mut seen = std::collections::HashSet::new();
        for k in self.memtable.live_keys() {
            if seen.insert(k) {
                f(k);
            }
        }
        // memtable tombstones (and older-table tombstones, walked
        // newest-first) shadow sstable versions
        let mut dead: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for t in self.sstables.iter().rev() {
            for &(k, e) in t.iter() {
                if seen.contains(&k) || dead.contains(&k) {
                    continue;
                }
                match e {
                    Entry::Put { .. } => {
                        if self.memtable.get(k) != Some(Entry::Tombstone) {
                            seen.insert(k);
                            f(k);
                        } else {
                            dead.insert(k);
                        }
                    }
                    Entry::Tombstone => {
                        dead.insert(k);
                    }
                }
            }
        }
    }

    fn maybe_compact(&mut self) {
        if self.sstables.len() > self.cfg.compaction.max_tables {
            self.compact();
        }
    }

    /// Merge all SSTables into one.
    pub fn compact(&mut self) {
        if self.sstables.len() < 2 {
            return;
        }
        self.stats.compactions += 1;
        let merged = merge_tables(&self.sstables, self.cfg.compaction.drop_tombstones);
        let gen = self.next_generation;
        self.next_generation += 1;
        let seed = self.cfg.filter.seed ^ gen;
        self.sstables = vec![SsTable::from_sorted_run(
            merged,
            gen,
            self.cfg.filter.fp_bits,
            seed,
        )];
    }

    /// Filter memory (node-level) + per-SSTable frozen filters.
    pub fn filter_memory_bytes(&self) -> usize {
        self.filter.memory_bytes()
            + self
                .sstables
                .iter()
                .map(|t| t.memory_bytes())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> StorageNode {
        StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        })
    }

    #[test]
    fn put_get_roundtrip() {
        let mut n = node();
        for k in 0..500u64 {
            n.put(k).unwrap();
        }
        for k in 0..500u64 {
            assert!(n.get(k), "{k}");
        }
        assert!(!n.get(10_000));
    }

    #[test]
    fn reads_survive_flushes() {
        let mut n = node();
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.flushes > 0, "small policy must have flushed");
        assert!(n.sstable_count() >= 1);
        for k in (0..5000u64).step_by(13) {
            assert!(n.get(k), "{k}");
        }
    }

    #[test]
    fn delete_shadows_flushed_data() {
        let mut n = node();
        for k in 0..3000u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        assert!(n.delete(7));
        assert!(!n.get(7), "tombstone must shadow the SSTable version");
        assert!(n.get(8));
    }

    #[test]
    fn delete_of_absent_key_rejected() {
        let mut n = node();
        n.put(1).unwrap();
        assert!(!n.delete(99));
        assert!(n.get(1));
        assert_eq!(n.stats.deletes, 1);
    }

    #[test]
    fn filter_short_circuits_absent_reads() {
        let mut n = node();
        for k in 0..1000u64 {
            n.put(k).unwrap();
        }
        let before = n.stats.filter_short_circuits();
        for k in 1_000_000..1_001_000u64 {
            n.get(k);
        }
        let hits = n.stats.filter_short_circuits() - before;
        assert!(hits > 950, "filter should kill most absent reads: {hits}");
    }

    #[test]
    fn get_batch_matches_scalar_gets() {
        for shards in [1usize, 4] {
            let mut n = StorageNode::new(NodeConfig {
                filter_shards: shards,
                flush: FlushPolicy::small(500),
                ..NodeConfig::default()
            });
            for k in 0..3000u64 {
                n.put(k).unwrap();
            }
            for k in 0..500u64 {
                n.delete(k);
            }
            let probes: Vec<u64> = (0..4000u64).chain(9_000_000..9_001_000).collect();
            let batched = n.get_batch(&probes);
            for (&k, &b) in probes.iter().zip(&batched) {
                assert_eq!(b, n.get(k), "shards={shards} key {k}");
            }
            // batch counted once per key, and absent keys short-circuit
            assert!(n.stats.gets() >= probes.len() as u64 * 2);
            assert!(n.stats.filter_short_circuits() > 1000);
        }
    }

    #[test]
    fn concurrent_readers_share_the_node() {
        // the ROADMAP "sharded store read path" item: get takes &self,
        // so reader threads drive the (sharded) node filter directly
        let mut n = StorageNode::new(NodeConfig {
            filter_shards: 4,
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        let n = &n;
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    for k in 0..5000u64 {
                        assert!(n.get(k), "reader {t} key {k}");
                    }
                    let absent: Vec<u64> = (8_000_000..8_001_000).collect();
                    assert!(n.get_batch(&absent).iter().all(|&b| !b));
                });
            }
        });
        assert_eq!(n.stats.gets(), 4 * (5000 + 1000));
    }

    #[test]
    fn compaction_merges_and_preserves() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(100),
            compaction: CompactionPolicy {
                max_tables: 3,
                drop_tombstones: true,
            },
            ..NodeConfig::default()
        });
        for k in 0..2000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.compactions > 0);
        assert!(n.sstable_count() <= 4);
        for k in (0..2000u64).step_by(37) {
            assert!(n.get(k), "{k}");
        }
    }

    #[test]
    fn deleted_keys_stay_dead_through_compaction() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(100),
            ..NodeConfig::default()
        });
        for k in 0..500u64 {
            n.put(k).unwrap();
        }
        for k in 0..250u64 {
            assert!(n.delete(k), "{k}");
        }
        n.flush(FlushReason::MemtableKeys);
        n.compact();
        for k in 0..250u64 {
            assert!(!n.get(k), "{k} resurrected");
        }
        for k in 250..500u64 {
            assert!(n.get(k), "{k} lost");
        }
    }

    #[test]
    fn fixed_filter_node_flushes_prematurely() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1_000_000).with_filter_pressure(0.8),
            filter: OcfConfig {
                mode: Mode::Static,
                initial_capacity: 2048,
                ..OcfConfig::default()
            },
            ..NodeConfig::default()
        });
        for k in 0..10_000u64 {
            let _ = n.put(k);
        }
        assert!(
            n.stats.flushes_premature > 0,
            "fixed filter under load must premature-flush"
        );
        // OCF node under the same load: zero premature flushes
        let mut o = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1_000_000),
            ..NodeConfig::default()
        });
        for k in 0..10_000u64 {
            o.put(k).unwrap();
        }
        assert_eq!(o.stats.flushes_premature, 0);
    }

    #[test]
    fn sharded_filter_node_roundtrip() {
        let mut n = StorageNode::new(NodeConfig {
            filter_shards: 4,
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.flushes > 0, "small policy must have flushed");
        for k in (0..5000u64).step_by(13) {
            assert!(n.get(k), "{k}");
        }
        assert!(!n.get(10_000_000));
        assert!(n.delete(7));
        assert!(!n.get(7));
        assert!(!n.delete(9_999_999), "absent delete rejected");
        assert_eq!(n.live_keys(), 4999);
        // same put/get/delete semantics as the single-filter node
        let mut single = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            single.put(k).unwrap();
        }
        single.delete(7);
        assert_eq!(n.live_keys(), single.live_keys());
    }

    #[test]
    fn live_keys_tracks_population() {
        let mut n = node();
        for k in 0..100u64 {
            n.put(k).unwrap();
        }
        assert_eq!(n.live_keys(), 100);
        for k in 0..50u64 {
            n.delete(k);
        }
        assert_eq!(n.live_keys(), 50);
    }
}

//! A storage node: memtable + SSTables + the node-level membership
//! filter.
//!
//! This is the unit the paper's experiments live on. The node-level
//! filter tracks the node's *live key population* (memtable + SSTables,
//! net of deletes) and short-circuits reads for definitely-absent keys;
//! each SSTable additionally carries its own frozen filter, Cassandra
//! style, to prune run probes.
//!
//! Since the Filter API v2 redesign the node is **filter-generic**: it
//! holds a [`DynFilter`] (`Box<dyn BatchedFilter + Send + Sync>`) built
//! by the [`FilterBuilder`] in [`NodeConfig::filter`], so any backend —
//! plain [`Ocf`](crate::filter::Ocf), the sharded concurrent front-end,
//! a raw cuckoo, or a bloom baseline — drops in by name with no
//! node-side dispatch (the old `NodeFilter` enum's hand-written
//! method-by-method match is gone). Capability probes keep semantics
//! exact for every backend:
//!
//! * delete verification uses [`MembershipFilter::contains_exact`] when
//!   the filter carries an authoritative key store (the OCF family) and
//!   falls back to the node's own ground truth (memtable + SSTables)
//!   otherwise, so verified deletes stay safe even on a bloom filter
//!   that cannot verify anything — and only exact filters delete their
//!   own entries, so a probabilistic backend can go stale but can never
//!   produce a false-negative read;
//! * [`StorageNode::live_keys`] uses [`MembershipFilter::exact_len`]
//!   when available and counts the live set directly when not.
//!
//! Read path for `get(k)`:
//! 1. node filter says "absent" → done (no memtable/SSTable work);
//! 2. memtable (put → found, tombstone → absent);
//! 3. SSTables newest→oldest, each gated by its frozen filter.
//!
//! Write path: WAL append first (when a persistent tier is
//! configured — see [`Wal`]), then memtable upsert + filter insert;
//! then the [`FlushPolicy`] decides whether to freeze (premature
//! flushes are exactly what a pressured fixed filter causes —
//! experiment E6). The WAL append happening *before* the memtable
//! apply is the durability contract: once `put`/`delete` returns,
//! the operation is on disk and [`StorageNode::recover`] will replay
//! it — no acknowledged write is ever lost to a crash.

use super::compaction::{merge_tables, CompactionPolicy};
use super::flush::{FlushPolicy, FlushReason};
use super::frozen::FrozenStore;
use super::io::{RealIo, StoreIo};
use super::memtable::{zero_value, Entry, Memtable, Value};
use super::sstable::{FrozenFilter, SsTable};
use super::wal::{self, FsyncPolicy, Wal, WalConfig, WalRecord};
use crate::filter::{
    BatchedFilter, DynFilter, FilterBuilder, FilterFeedback, MembershipFilter, Mode, OcfConfig,
    ProbeSession,
};
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Arc;

/// Node configuration.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub node_id: u64,
    /// Node-filter construction: backend, capacity, shards, seeds —
    /// the whole surface (`FilterBuilder::from(ocf_config)` migrates
    /// pre-v2 call sites; `.with_shards(n)` replaces the old
    /// `filter_shards` field).
    pub filter: FilterBuilder,
    pub flush: FlushPolicy,
    pub compaction: CompactionPolicy,
    /// Value-size proxy for puts (bytes accounted in the memtable).
    pub value_len: u32,
    /// Directory of the persistent frozen-filter tier
    /// ([`FrozenStore`]). `None` (the default) keeps the node fully
    /// in-memory, exactly as before the tier existed. When set, every
    /// flush/compaction persists its SSTable (run + frozen filter) and
    /// [`StorageNode::recover`] can reopen the node from disk, serving
    /// recovered filters straight off the file mapping.
    pub persist_dir: Option<String>,
    /// Memtable write-ahead logging (only meaningful together with
    /// [`NodeConfig::persist_dir`]): enabled/fsync-policy knobs.
    pub wal: WalConfig,
    /// The I/O layer the persistent tier (FrozenStore + WAL) runs on.
    /// `None` means the real filesystem; the crash-sweep harness
    /// injects a seeded [`FaultyIo`](super::io::FaultyIo) here.
    pub io: Option<Arc<dyn StoreIo>>,
}

impl Default for NodeConfig {
    fn default() -> Self {
        Self {
            node_id: 0,
            filter: FilterBuilder::default(),
            flush: FlushPolicy::default(),
            compaction: CompactionPolicy::default(),
            value_len: 64,
            persist_dir: None,
            wal: WalConfig::default(),
            io: None,
        }
    }
}

impl NodeConfig {
    /// The fixed-filter ("traditional Cassandra") arm: static filter,
    /// flush under filter pressure.
    pub fn fixed_filter(capacity: usize) -> Self {
        Self {
            filter: OcfConfig {
                mode: Mode::Static,
                initial_capacity: capacity,
                ..OcfConfig::default()
            }
            .into(),
            flush: FlushPolicy::default().with_filter_pressure(0.85),
            ..Self::default()
        }
    }
}

/// Node operation counters. Write-path counters stay plain `u64` (the
/// write path holds `&mut self`); read-path counters are relaxed
/// atomics so `get`/`get_batch` take `&self` and concurrent readers can
/// drive the node filter directly (ROADMAP "sharded store read path").
#[derive(Debug, Default)]
pub struct NodeStats {
    pub puts: u64,
    pub deletes: u64,
    gets: AtomicU64,
    /// Reads answered "absent" by the node filter alone.
    filter_short_circuits: AtomicU64,
    /// SSTable probes skipped thanks to per-table frozen filters.
    sstable_probes_skipped: AtomicU64,
    /// SSTable probes that went to binary search.
    sstable_probes: AtomicU64,
    /// Ground-truth false positives observed on the read path: the
    /// node filter said "present" but memtable + SSTables had no live
    /// version. Every one is reported to the filter through
    /// [`FilterFeedback`]; adaptive backends learn from it.
    fp_observed: AtomicU64,
    /// Reported FPs the filter accepted (an adaptive backend rotated
    /// the offending entry's selector — that key stops repeat-missing).
    /// Zero on non-adaptive backends, whose report is a no-op.
    fp_remapped: AtomicU64,
    pub flushes: u64,
    pub flushes_premature: u64,
    pub compactions: u64,
    /// SSTable filters reopened from disk (validated, served in place —
    /// possibly mmap-backed) during [`StorageNode::recover`].
    filters_recovered: u64,
    /// SSTable filters rebuilt from their run because the persisted
    /// filter file was absent or rejected.
    filters_rebuilt: u64,
    /// Persisted filter files *present but rejected* at validation
    /// (truncation, checksum mismatch, version skew) — a durability
    /// event worth alerting on, unlike a merely-missing file.
    filter_recovery_rejected: u64,
    /// Payload records (puts/deletes, not flush markers) appended to
    /// the WAL.
    wal_appends: u64,
    /// Payload records whose WAL append *failed* — the write was
    /// acknowledged without its durability promise. Degraded, loud,
    /// never silent.
    wal_append_failed: u64,
    /// Operations re-applied from the WAL by [`StorageNode::recover`].
    wal_replayed: u64,
    /// WAL segments whose decode stopped at a torn/corrupt tail
    /// during recovery (the intact prefix was still replayed).
    wal_torn_tail: u64,
    /// Transient I/O errors absorbed by bounded retry
    /// (`util::retry`) across the WAL and the frozen tier.
    io_retries: u64,
    /// The node is in read-only degraded mode: a WAL append hit
    /// ENOSPC, so further writes would be acknowledged without any
    /// durability path to recover them. Writes are refused
    /// ([`crate::filter::FilterError::Unavailable`]) until an operator
    /// intervenes; reads keep serving.
    degraded: bool,
}

impl NodeStats {
    pub fn gets(&self) -> u64 {
        self.gets.load(Relaxed)
    }

    /// Reads answered "absent" by the node filter alone.
    pub fn filter_short_circuits(&self) -> u64 {
        self.filter_short_circuits.load(Relaxed)
    }

    /// SSTable probes skipped thanks to per-table frozen filters.
    pub fn sstable_probes_skipped(&self) -> u64 {
        self.sstable_probes_skipped.load(Relaxed)
    }

    /// SSTable probes that went to binary search.
    pub fn sstable_probes(&self) -> u64 {
        self.sstable_probes.load(Relaxed)
    }

    /// Ground-truth false positives observed (and reported) on reads.
    pub fn fp_observed(&self) -> u64 {
        self.fp_observed.load(Relaxed)
    }

    /// Reported FPs the filter remapped (adaptive backends only).
    pub fn fp_remapped(&self) -> u64 {
        self.fp_remapped.load(Relaxed)
    }

    /// SSTable filters reopened from disk without a rebuild.
    pub fn filters_recovered(&self) -> u64 {
        self.filters_recovered
    }

    /// SSTable filters rebuilt from their run at recovery.
    pub fn filters_rebuilt(&self) -> u64 {
        self.filters_rebuilt
    }

    /// Persisted filter files rejected by validation at recovery.
    pub fn filter_recovery_rejected(&self) -> u64 {
        self.filter_recovery_rejected
    }

    /// Payload records appended to the WAL.
    pub fn wal_appends(&self) -> u64 {
        self.wal_appends
    }

    /// Acknowledged writes whose WAL append failed (durability
    /// degraded to freeze-time persistence for those ops).
    pub fn wal_append_failed(&self) -> u64 {
        self.wal_append_failed
    }

    /// Operations re-applied from the WAL at recovery.
    pub fn wal_replayed(&self) -> u64 {
        self.wal_replayed
    }

    /// WAL segments with a torn/corrupt tail tolerated at recovery.
    pub fn wal_torn_tail(&self) -> u64 {
        self.wal_torn_tail
    }

    /// Transient I/O errors absorbed by bounded retry.
    pub fn io_retries(&self) -> u64 {
        self.io_retries
    }

    /// Read-only degraded mode (WAL out of disk space; writes refused).
    pub fn degraded(&self) -> bool {
        self.degraded
    }
}

impl Clone for NodeStats {
    fn clone(&self) -> Self {
        Self {
            puts: self.puts,
            deletes: self.deletes,
            gets: AtomicU64::new(self.gets()),
            filter_short_circuits: AtomicU64::new(self.filter_short_circuits()),
            sstable_probes_skipped: AtomicU64::new(self.sstable_probes_skipped()),
            sstable_probes: AtomicU64::new(self.sstable_probes()),
            fp_observed: AtomicU64::new(self.fp_observed()),
            fp_remapped: AtomicU64::new(self.fp_remapped()),
            flushes: self.flushes,
            flushes_premature: self.flushes_premature,
            compactions: self.compactions,
            filters_recovered: self.filters_recovered,
            filters_rebuilt: self.filters_rebuilt,
            filter_recovery_rejected: self.filter_recovery_rejected,
            wal_appends: self.wal_appends,
            wal_append_failed: self.wal_append_failed,
            wal_replayed: self.wal_replayed,
            wal_torn_tail: self.wal_torn_tail,
            io_retries: self.io_retries,
            degraded: self.degraded,
        }
    }
}

/// A single storage node, generic over its live-set filter through the
/// [`BatchedFilter`] trait object (see the module docs).
#[derive(Debug)]
pub struct StorageNode {
    cfg: NodeConfig,
    memtable: Memtable,
    sstables: Vec<SsTable>,
    /// Node-level live-set filter (any backend; built by name).
    filter: DynFilter,
    /// The persistent frozen-filter tier, when
    /// [`NodeConfig::persist_dir`] is set.
    frozen_store: Option<FrozenStore>,
    /// Memtable write-ahead log (persist_dir set + wal enabled).
    /// `None` while configured-on means the WAL could not be opened —
    /// the node serves on, counting every unlogged acknowledgement in
    /// `wal_append_failed`.
    wal: Option<Wal>,
    /// The shared payload for bare-key puts (`value_len` zero bytes;
    /// one allocation, refcounted per entry).
    default_value: Value,
    next_generation: u64,
    pub stats: NodeStats,
}

/// Open the WAL, degrading loudly (not fatally) when the directory
/// is unwritable: the node still serves, and `wal_append_failed`
/// counts every acknowledgement whose durability promise was broken.
/// Out-of-space detection across real errors (`raw_os_error` 28) and
/// the injected kind [`super::io::FaultyIo`] produces.
fn is_enospc(e: &std::io::Error) -> bool {
    e.raw_os_error() == Some(28) || e.to_string().contains("ENOSPC")
}

/// The refusal every write path returns while degraded.
fn degraded_refusal() -> crate::filter::FilterError {
    crate::filter::FilterError::Unavailable(
        "node is read-only degraded (WAL out of disk space)".to_string(),
    )
}

fn open_wal(dir: &Path, io: Arc<dyn StoreIo>, policy: FsyncPolicy, first: u64) -> Option<Wal> {
    match Wal::open(dir, io, policy, first) {
        Ok(w) => Some(w),
        Err(e) => {
            eprintln!("ocf: wal: open failed (writes will not be logged): {e}");
            None
        }
    }
}

impl StorageNode {
    /// Build a node, constructing the filter from
    /// [`NodeConfig::filter`].
    ///
    /// # Panics
    /// If the filter builder fails validation (config-file and CLI
    /// paths validate earlier with a proper error; programmatic
    /// construction with invalid knobs is a bug).
    pub fn new(cfg: NodeConfig) -> Self {
        let filter = cfg
            .filter
            .build()
            .unwrap_or_else(|e| panic!("NodeConfig::filter: {e}"));
        Self::with_filter(cfg, filter)
    }

    /// Build a node around an already-constructed filter (typed
    /// callers that want to keep a handle on the concrete type can
    /// box their own).
    ///
    /// # Panics
    /// If [`NodeConfig::persist_dir`] is set but the directory cannot
    /// be created/opened (use [`StorageNode::recover`] for a fallible
    /// open that also reloads existing state).
    pub fn with_filter(cfg: NodeConfig, filter: DynFilter) -> Self {
        let io: Arc<dyn StoreIo> = cfg.io.clone().unwrap_or_else(|| Arc::new(RealIo));
        let frozen_store = cfg.persist_dir.as_ref().map(|dir| {
            FrozenStore::open_with(dir, io.clone())
                .unwrap_or_else(|e| panic!("persist_dir {dir:?}: {e}"))
        });
        let wal = match &cfg.persist_dir {
            Some(dir) if cfg.wal.enabled => {
                open_wal(Path::new(dir), io, cfg.wal.fsync, 1)
            }
            _ => None,
        };
        Self {
            memtable: Memtable::new(),
            sstables: Vec::new(),
            filter,
            frozen_store,
            wal,
            default_value: zero_value(cfg.value_len),
            next_generation: 1,
            cfg,
            stats: NodeStats::default(),
        }
    }

    /// Reopen a node from its persistent tier instead of starting
    /// empty: every generation in [`NodeConfig::persist_dir`] is
    /// reloaded — its run decoded (ground truth) and its frozen filter
    /// *recovered* from the persisted file when it validates (served in
    /// place, mmap-backed where supported) or *rebuilt* from the run
    /// when it is missing or rejected (checksum/version/truncation),
    /// with the healed filter re-persisted. The node-level live-set
    /// filter is always rebuilt from the recovered live keys (it is
    /// derived state over data this tier does persist); for an
    /// adaptive backend that rebuild is also the persistence policy
    /// for adaptation state — selector/extension sidecars are
    /// workload-learned, never serialized, and re-learn from live
    /// traffic after recovery (see `filter/adaptive.rs`).
    ///
    /// Counters: `filters_recovered` / `filters_rebuilt` /
    /// `filter_recovery_rejected` on [`NodeStats`] record what
    /// happened; a run file that itself fails validation is skipped
    /// with a warning (filters are derived from runs, so a lost run is
    /// lost data — there is nothing to rebuild it from).
    ///
    /// # Panics
    /// Like [`StorageNode::new`], if the filter builder fails
    /// validation.
    pub fn recover(cfg: NodeConfig) -> io::Result<Self> {
        let Some(dir) = cfg.persist_dir.clone() else {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "StorageNode::recover requires NodeConfig::persist_dir",
            ));
        };
        let io: Arc<dyn StoreIo> = cfg.io.clone().unwrap_or_else(|| Arc::new(RealIo));
        let store = FrozenStore::open_with(&dir, io.clone())?;
        let mut node = Self {
            memtable: Memtable::new(),
            sstables: Vec::new(),
            filter: cfg
                .filter
                .build()
                .unwrap_or_else(|e| panic!("NodeConfig::filter: {e}")),
            frozen_store: None,
            wal: None,
            default_value: zero_value(cfg.value_len),
            next_generation: 1,
            cfg,
            stats: NodeStats::default(),
        };
        // Pass 1: decode every generation's run (ground truth). A torn
        // run is unrecoverable from this tier (the filter is derived
        // from it, not vice versa): skip the generation rather than
        // serving corrupt data.
        let mut runs: Vec<(u64, super::frozen::RunFile)> = Vec::new();
        for gen in store.generations()? {
            match store.load_run(gen) {
                Ok(run) => runs.push((gen, run)),
                Err(e) => {
                    eprintln!("ocf: persist: skipping generation {gen:#x}: run file invalid: {e}");
                }
            }
        }
        // A full-snapshot generation (compaction output) supersedes
        // everything older; generations below the newest one are
        // leftovers of an interrupted swap. Drop them — recovering them
        // could resurrect keys whose tombstones the merge dropped.
        let cutoff = runs
            .iter()
            .filter(|(_, r)| r.is_full_snapshot())
            .map(|&(gen, _)| gen)
            .max();
        for (gen, run) in runs {
            if let Some(cutoff) = cutoff {
                if gen < cutoff {
                    if let Err(e) = store.remove(gen) {
                        eprintln!("ocf: persist: generation {gen:#x}: stale-input cleanup failed: {e}");
                    }
                    continue;
                }
            }
            let run = run.records;
            let filter = match store.load_filter(gen) {
                Ok(table) => {
                    node.stats.filters_recovered += 1;
                    FrozenFilter::from_table(table)
                }
                Err(e) => {
                    if e.is_rejection() {
                        node.stats.filter_recovery_rejected += 1;
                        eprintln!(
                            "ocf: persist: generation {gen:#x}: filter file rejected ({e}); rebuilding from run"
                        );
                    }
                    node.stats.filters_rebuilt += 1;
                    let keys: Vec<u64> = run.iter().map(|&(k, _)| k).collect();
                    let rebuilt = FrozenFilter::build(
                        &keys,
                        node.cfg.filter.ocf.fp_bits,
                        node.cfg.filter.ocf.seed ^ gen,
                    );
                    // Heal the on-disk artifact so the next restart
                    // recovers instead of rebuilding again.
                    if let Err(e) = store.persist_filter(gen, &rebuilt) {
                        eprintln!("ocf: persist: generation {gen:#x}: re-persist failed: {e}");
                    }
                    rebuilt
                }
            };
            node.next_generation = node.next_generation.max(gen + 1);
            node.sstables.push(SsTable::from_recovered(run, filter, gen));
        }
        // generations() is ascending, but make the newest-shadows-oldest
        // invariant explicit rather than inherited.
        node.sstables.sort_by_key(|t| t.generation);
        node.stats.io_retries += store.take_retries();
        node.frozen_store = Some(store);
        // Pass 3: WAL replay — re-apply every acknowledged operation
        // that had not reached a durable SSTable at the crash. Each
        // segment is staged independently: a FlushMarker inside it
        // proves everything staged before the marker is covered by a
        // persisted generation, so only the ops *after* the last
        // marker re-enter the memtable.
        let mut replayed_segments: Vec<u64> = Vec::new();
        let mut max_segment = 0u64;
        if node.cfg.wal.enabled {
            for seg in wal::list_segments(io.as_ref(), Path::new(&dir))? {
                max_segment = max_segment.max(seg);
                match wal::replay_segment(io.as_ref(), Path::new(&dir), seg) {
                    Ok(replay) => {
                        if replay.torn {
                            node.stats.wal_torn_tail += 1;
                            eprintln!(
                                "ocf: wal: segment {seg:#018x}: torn tail; intact prefix replayed"
                            );
                        }
                        let mut staged: Vec<WalRecord> = Vec::new();
                        for rec in replay.records {
                            match rec {
                                WalRecord::FlushMarker { .. } => staged.clear(),
                                op => staged.push(op),
                            }
                        }
                        for rec in staged {
                            match rec {
                                WalRecord::Put { key, value } => {
                                    node.memtable.put(key, value);
                                }
                                WalRecord::Delete { key } => {
                                    node.memtable.delete(key);
                                }
                                WalRecord::FlushMarker { .. } => unreachable!("cleared above"),
                            }
                            node.stats.wal_replayed += 1;
                        }
                        replayed_segments.push(seg);
                    }
                    Err(e) => {
                        eprintln!("ocf: wal: segment {seg:#018x}: replay failed: {e}");
                    }
                }
            }
            match Wal::open(Path::new(&dir), io, node.cfg.wal.fsync, max_segment + 1) {
                Ok(mut w) => {
                    if node.memtable.is_empty() {
                        // Nothing survived staging: the old segments
                        // carry no live ops, so they can go now.
                        w.retire_segments(&replayed_segments);
                    } else {
                        // The replayed ops live only in the memtable
                        // until the next successful flush commits —
                        // keep their segments until then.
                        w.mark_replayed(replayed_segments);
                    }
                    node.wal = Some(w);
                }
                Err(e) => {
                    eprintln!("ocf: wal: open failed (new writes will not be logged): {e}");
                }
            }
        }
        if !node.sstables.is_empty() || !node.memtable.is_empty() {
            node.rebuild_node_filter();
        }
        Ok(node)
    }

    /// The persistent tier, when configured.
    pub fn frozen_store(&self) -> Option<&FrozenStore> {
        self.frozen_store.as_ref()
    }

    /// The live write-ahead log, when configured and healthy.
    pub fn wal(&self) -> Option<&Wal> {
        self.wal.as_ref()
    }

    pub fn config(&self) -> &NodeConfig {
        &self.cfg
    }

    /// The node-level filter, as the capability trait it is used
    /// through.
    pub fn filter(&self) -> &(dyn BatchedFilter + Send + Sync) {
        &*self.filter
    }

    pub fn sstable_count(&self) -> usize {
        self.sstables.len()
    }

    pub fn memtable_len(&self) -> usize {
        self.memtable.len()
    }

    /// Total live keys on the node: exact via the filter's key store
    /// when it has one, counted from the node's own tables otherwise.
    pub fn live_keys(&self) -> usize {
        self.filter.exact_len().unwrap_or_else(|| {
            let mut n = 0usize;
            self.for_each_live_key(|_| n += 1);
            n
        })
    }

    /// Insert/overwrite a key with the default (`value_len` zero-byte)
    /// payload. Returns Err only in Static filter mode when the filter
    /// is wedged *and* flushing can't relieve it.
    pub fn put(&mut self, key: u64) -> Result<(), crate::filter::FilterError> {
        let value = self.default_value.clone();
        self.put_arc(key, value)
    }

    /// Insert/overwrite a key with real value bytes. The bytes ride
    /// the WAL record, the memtable entry, and the SSTable run —
    /// [`StorageNode::get_value`] returns them, across restarts.
    pub fn put_value(
        &mut self,
        key: u64,
        value: &[u8],
    ) -> Result<(), crate::filter::FilterError> {
        self.put_arc(key, Arc::from(value))
    }

    fn put_arc(&mut self, key: u64, value: Value) -> Result<(), crate::filter::FilterError> {
        if self.stats.degraded {
            return Err(degraded_refusal());
        }
        self.stats.puts += 1;
        // WAL first: by the time the memtable (and the caller) sees
        // the write, it is as durable as the fsync policy promises.
        self.wal_log(WalRecord::Put {
            key,
            value: value.clone(),
        });
        self.memtable.put(key, value);
        match self.filter.insert(key) {
            Ok(()) => {}
            Err(e) => {
                // Fixed-filter node: saturation → forced (premature)
                // flush, then retry once after the flush cleared the
                // memtable; the filter itself stays static so the
                // failure is visible to stats/experiments.
                self.flush(FlushReason::FilterPressure);
                if self.filter.insert(key).is_err() {
                    return Err(e);
                }
            }
        }
        self.maybe_flush();
        Ok(())
    }

    /// Delete a key, verified against the node's authoritative state —
    /// the paper's safe-delete path. Filters with a key store answer
    /// the verification exactly ([`MembershipFilter::contains_exact`]);
    /// for the rest the node consults its own ground truth (memtable +
    /// SSTables), so a bloom-backed node still never deletes an absent
    /// key.
    pub fn delete(&mut self, key: u64) -> bool {
        if self.stats.degraded {
            // read-only mode: a delete is a write too — refusing it
            // leaves the key verifiably live, so "nothing deleted" is
            // the honest answer
            return false;
        }
        self.stats.deletes += 1;
        let exact = self.filter.contains_exact(key);
        let live = match exact {
            Some(live) => live,
            None => self.read_tables(key),
        };
        if !live {
            return false;
        }
        self.wal_log(WalRecord::Delete { key });
        self.memtable.delete(key);
        // Only filters with an authoritative key store delete their own
        // entries — their removal is exact. For the rest the filter
        // stays over-approximate (bloom semantics): a probabilistic
        // delete (raw cuckoo's unverified fingerprint removal, counting
        // bloom's counter decrement) could strip a *colliding live*
        // key's evidence and turn the filter short-circuit in
        // [`StorageNode::get`] into a false negative. Staleness only
        // costs short-circuit efficiency, never correctness, and
        // pressure-flush rebuilds re-tighten the filter from the live
        // set.
        if exact.is_some() {
            self.filter.delete(key);
        }
        self.maybe_flush();
        true
    }

    /// Membership-test read. Takes `&self` (read-path stats are
    /// relaxed atomics), so any number of reader threads can probe the
    /// node concurrently with each other. A filter "present" that the
    /// tables then miss is a ground-truth false positive — it is
    /// reported back to the filter ([`FilterFeedback`]) so adaptive
    /// backends stop repeating it; other backends no-op the report.
    pub fn get(&self, key: u64) -> bool {
        self.stats.gets.fetch_add(1, Relaxed);
        if !self.filter.contains(key) {
            self.stats.filter_short_circuits.fetch_add(1, Relaxed);
            return false;
        }
        let found = self.read_tables(key);
        if !found {
            self.report_false_positive(key);
        }
        found
    }

    /// Value read: the payload bytes of a live key, `None` for
    /// absent/deleted keys. Same path as [`StorageNode::get`]
    /// (filter short-circuit, memtable, SSTables newest→oldest).
    pub fn get_value(&self, key: u64) -> Option<Value> {
        self.stats.gets.fetch_add(1, Relaxed);
        if !self.filter.contains(key) {
            self.stats.filter_short_circuits.fetch_add(1, Relaxed);
            return None;
        }
        match self.memtable.get(key) {
            Some(Entry::Put { value }) => return Some(value),
            Some(Entry::Tombstone) => return None,
            None => {}
        }
        for t in self.sstables.iter().rev() {
            if !t.might_contain(key) {
                self.stats.sstable_probes_skipped.fetch_add(1, Relaxed);
                continue;
            }
            self.stats.sstable_probes.fetch_add(1, Relaxed);
            match t.get(key) {
                Some(Entry::Put { value }) => return Some(value),
                Some(Entry::Tombstone) => return None,
                None => {}
            }
        }
        self.report_false_positive(key);
        None
    }

    /// Read-path FP feedback: count the ground-truth miss, tell the
    /// filter, count a successful remap. `&self` throughout — adaptive
    /// backends take the report through an atomic sidecar.
    fn report_false_positive(&self, key: u64) {
        self.stats.fp_observed.fetch_add(1, Relaxed);
        if self.filter.report_false_positive(key) {
            self.stats.fp_remapped.fetch_add(1, Relaxed);
        }
    }

    /// FP probes the filter's adaptation suppressed (reported FPs that
    /// no longer reach the tables). Lives in the filter's own stats —
    /// the node never sees a suppressed probe, by design.
    pub fn fp_suppressed(&self) -> u64 {
        self.filter.stats().fp_suppressed
    }

    /// Batched membership reads: one bulk hash + the prefetch-pipelined
    /// filter probe short-circuit definitely-absent keys (the node's
    /// negative-lookup fast path), then only survivors walk the
    /// memtable/SSTable read path. Bucket scans inside the probe ride
    /// the runtime-dispatched SIMD kernel vtable
    /// (`filter::kernel` — autodetected / `OCF_SIMD` / auto-tuned), so
    /// the node shares one dispatch story with every other engine
    /// consumer. Positionally aligned with `keys`; answer-identical to
    /// calling [`StorageNode::get`] per key — for every backend,
    /// including default-batch baselines (proptest P12).
    pub fn get_batch(&self, keys: &[u64]) -> Vec<bool> {
        self.stats.gets.fetch_add(keys.len() as u64, Relaxed);
        let pass = self.filter.contains_batch(keys);
        let mut short = 0u64;
        let out = keys
            .iter()
            .zip(&pass)
            .map(|(&k, &p)| {
                if p {
                    let found = self.read_tables(k);
                    if !found {
                        self.report_false_positive(k);
                    }
                    found
                } else {
                    short += 1;
                    false
                }
            })
            .collect();
        self.stats.filter_short_circuits.fetch_add(short, Relaxed);
        out
    }

    /// Batched puts: WAL + memtable per key in order (the same
    /// durability contract as [`StorageNode::put`], record for
    /// record), then one bulk-hashed, prefetch-pipelined filter
    /// insert for the whole batch. Per-key results are positionally
    /// aligned with `keys`; a saturated static filter triggers the
    /// same pressure-flush-and-retry as the scalar path. Flush
    /// policy is evaluated once after the batch instead of per key —
    /// batch sizes are bounded by the pipeline's `batch_size`, so the
    /// memtable overshoot is bounded too.
    pub fn put_batch(&mut self, keys: &[u64]) -> Vec<Result<(), crate::filter::FilterError>> {
        if self.stats.degraded {
            return keys.iter().map(|_| Err(degraded_refusal())).collect();
        }
        self.stats.puts += keys.len() as u64;
        for &key in keys {
            let value = self.default_value.clone();
            self.wal_log(WalRecord::Put {
                key,
                value: value.clone(),
            });
            self.memtable.put(key, value);
        }
        let mut session = ProbeSession::with_capacity(keys.len());
        let mut out = Vec::with_capacity(keys.len());
        self.filter.insert_batch_into(keys, &mut session, &mut out);
        for (i, &key) in keys.iter().enumerate() {
            if out[i].is_err() {
                // Same relief valve as the scalar path: a pressure
                // flush clears the memtable (rebuilding the static
                // filter from the live set), then one retry.
                self.flush(FlushReason::FilterPressure);
                if self.filter.insert(key).is_ok() {
                    out[i] = Ok(());
                }
            }
        }
        self.maybe_flush();
        out
    }

    /// Batched deletes: the scalar verified-delete per key, positionally
    /// aligned with `keys`. The win of the batched form lives a layer
    /// up — `Cluster::delete_batch` groups a batch by replica node and
    /// issues one call per node — while each key here still gets the
    /// full verification + WAL + tombstone treatment (deletes cannot
    /// skip per-key verification the way bulk-hashed inserts can).
    pub fn delete_batch(&mut self, keys: &[u64]) -> Vec<bool> {
        keys.iter().map(|&k| self.delete(k)).collect()
    }

    /// The post-filter read path: memtable, then SSTables newest→oldest
    /// gated by their frozen per-table filters.
    fn read_tables(&self, key: u64) -> bool {
        match self.memtable.get(key) {
            Some(Entry::Put { .. }) => return true,
            Some(Entry::Tombstone) => return false,
            None => {}
        }
        for t in self.sstables.iter().rev() {
            if !t.might_contain(key) {
                self.stats.sstable_probes_skipped.fetch_add(1, Relaxed);
                continue;
            }
            self.stats.sstable_probes.fetch_add(1, Relaxed);
            match t.get(key) {
                Some(Entry::Put { .. }) => return true,
                Some(Entry::Tombstone) => return false,
                None => {}
            }
        }
        false
    }

    /// Append one payload record to the WAL (no-op for fully
    /// in-memory nodes). Failure is loud but not fatal: the op is
    /// still applied, and `wal_append_failed` records the broken
    /// durability promise — for that op the node degrades to the
    /// pre-WAL freeze-time contract.
    fn wal_log(&mut self, rec: WalRecord) {
        let Some(w) = self.wal.as_mut() else {
            if self.cfg.wal.enabled && self.cfg.persist_dir.is_some() {
                // WAL configured on but unopenable: every
                // acknowledgement without a log record is counted.
                self.stats.wal_append_failed += 1;
            }
            return;
        };
        match w.append(&rec) {
            Ok(()) => self.stats.wal_appends += 1,
            Err(e) => {
                self.stats.wal_append_failed += 1;
                // Disk full is not transient churn: every further
                // acknowledged write would be losable. Flip into
                // read-only degraded mode — this op was already
                // applied (its caller was promised), the next write
                // is refused at the door.
                if is_enospc(&e) && !self.stats.degraded {
                    self.stats.degraded = true;
                    eprintln!(
                        "ocf: wal: append hit ENOSPC — node entering read-only \
                         degraded mode (writes refused until space is freed): {e}"
                    );
                } else {
                    eprintln!("ocf: wal: append failed (durability degraded): {e}");
                }
            }
        }
        self.stats.io_retries += w.take_retries();
    }

    fn maybe_flush(&mut self) {
        if let Some(reason) = self.cfg.flush.should_flush(
            self.memtable.approx_bytes(),
            self.memtable.len(),
            self.filter.occupancy(),
        ) {
            self.flush(reason);
        }
    }

    /// Freeze the memtable into an SSTable.
    pub fn flush(&mut self, reason: FlushReason) {
        if self.memtable.is_empty() {
            return;
        }
        self.stats.flushes += 1;
        if reason == FlushReason::FilterPressure {
            self.stats.flushes_premature += 1;
        }
        let run = self.memtable.drain_sorted();
        let gen = self.next_generation;
        self.next_generation += 1;
        let seed = self.cfg.filter.ocf.seed ^ gen;
        let table = SsTable::from_sorted_run(run, gen, self.cfg.filter.ocf.fp_bits, seed);
        // Durability hook: the freeze is the moment data leaves the
        // (volatile) memtable, so persist the SSTable before serving
        // from it. Persistence failure degrades to the in-memory tier
        // (loud, not fatal): the node keeps answering correctly from
        // RAM — and with a WAL the sealed segment is parked as an
        // orphan instead of retired, so the ops are still replayable.
        let mut persisted = false;
        if let Some(store) = &self.frozen_store {
            match store.persist(&table) {
                Ok(()) => persisted = true,
                Err(e) => {
                    eprintln!("ocf: persist: generation {gen:#x}: flush persist failed: {e}");
                }
            }
            self.stats.io_retries += store.take_retries();
        }
        if let Some(w) = self.wal.as_mut() {
            if persisted {
                // Marker after the data: its presence *proves* the
                // generation is durable. A failed marker/rotation
                // only costs an idempotent re-apply at recovery.
                if let Err(e) = w.commit_flush(gen) {
                    eprintln!("ocf: wal: generation {gen:#x}: flush commit failed: {e}");
                }
            } else {
                w.abandon_flush();
            }
            self.stats.io_retries += w.take_retries();
        }
        self.sstables.push(table);
        // Fixed-filter nodes rebuild their node filter from the live set
        // after a pressure flush ("complete rebuild of the in-memory
        // data structures" — the cost the paper wants to avoid).
        if reason == FlushReason::FilterPressure {
            self.rebuild_node_filter();
        }
        self.maybe_compact();
    }

    fn rebuild_node_filter(&mut self) {
        let live = self.live_keys();
        let capacity = (live * 2).max(self.cfg.filter.ocf.initial_capacity);
        let mut fresh = self
            .cfg
            .filter
            .clone()
            .with_initial_capacity(capacity)
            .build()
            .expect("filter config was validated at node construction");
        let mut keys: Vec<u64> = Vec::with_capacity(live);
        self.for_each_live_key(|k| keys.push(k));
        // Rebuild through the batched engine (bulk hash + pipelined
        // inserts); failures are tolerated like the old per-key loop.
        let mut session = ProbeSession::new();
        let mut results = Vec::with_capacity(keys.len());
        fresh.insert_batch_into(&keys, &mut session, &mut results);
        self.filter = fresh;
    }

    /// Page through the node's live keys whose ring token falls in the
    /// arc `(lo, hi]` (wrapping when `lo > hi`, the whole ring when
    /// `lo == hi`), in ascending key order, starting strictly after
    /// `after`, at most `limit` keys. This is the donor side of the
    /// membership range transfer (`cluster::transfer`): the cursor
    /// protocol makes each page idempotent, so a stream interrupted by
    /// a fault replays the same page deterministically.
    pub fn live_keys_in_arc(
        &self,
        lo: u64,
        hi: u64,
        after: Option<u64>,
        limit: usize,
    ) -> Vec<u64> {
        let in_arc = |k: u64| {
            let t = crate::filter::fingerprint::mix64(k);
            if lo < hi {
                lo < t && t <= hi
            } else if lo > hi {
                t > lo || t <= hi
            } else {
                true
            }
        };
        let mut keys: Vec<u64> = Vec::new();
        self.for_each_live_key(|k| {
            if in_arc(k) && after.is_none_or(|a| k > a) {
                keys.push(k);
            }
        });
        keys.sort_unstable();
        keys.truncate(limit);
        keys
    }

    /// Enumerate the node's live keys (memtable ∪ sstables, minus
    /// tombstones). Exactness is guaranteed by replaying newest-first.
    fn for_each_live_key(&self, mut f: impl FnMut(u64)) {
        let mut seen = std::collections::HashSet::new();
        for k in self.memtable.live_keys() {
            if seen.insert(k) {
                f(k);
            }
        }
        // memtable tombstones (and older-table tombstones, walked
        // newest-first) shadow sstable versions
        let mut dead: std::collections::HashSet<u64> = std::collections::HashSet::new();
        for t in self.sstables.iter().rev() {
            for (k, e) in t.iter() {
                let k = *k;
                if seen.contains(&k) || dead.contains(&k) {
                    continue;
                }
                match e {
                    Entry::Put { .. } => {
                        if self.memtable.get(k) != Some(Entry::Tombstone) {
                            seen.insert(k);
                            f(k);
                        } else {
                            dead.insert(k);
                        }
                    }
                    Entry::Tombstone => {
                        dead.insert(k);
                    }
                }
            }
        }
    }

    fn maybe_compact(&mut self) {
        if self.sstables.len() > self.cfg.compaction.max_tables {
            self.compact();
        }
    }

    /// Merge all SSTables into one.
    pub fn compact(&mut self) {
        if self.sstables.len() < 2 {
            return;
        }
        self.stats.compactions += 1;
        let merged = merge_tables(&self.sstables, self.cfg.compaction.drop_tombstones);
        let gen = self.next_generation;
        self.next_generation += 1;
        let seed = self.cfg.filter.ocf.seed ^ gen;
        let table = SsTable::from_sorted_run(merged, gen, self.cfg.filter.ocf.fp_bits, seed);
        // Atomic swap protocol: publish the merged generation first,
        // remove the inputs after. A crash anywhere in between leaves
        // old + new generations side by side, which recovers correctly
        // — the merged table is the newest generation, so it shadows
        // every record of its inputs (including dropped tombstones:
        // a tombstone is only dropped once no shadowed Put survives
        // below it, and after the swap nothing is below the merged
        // table). Removal is idempotent, so a re-run compaction can
        // finish the cleanup.
        let mut snapshot_durable = false;
        if let Some(store) = &self.frozen_store {
            match store.persist_full(&table) {
                Ok(()) => {
                    snapshot_durable = true;
                    for old in &self.sstables {
                        if let Err(e) = store.remove(old.generation) {
                            eprintln!(
                                "ocf: persist: generation {:#x}: cleanup failed: {e}",
                                old.generation
                            );
                        }
                    }
                }
                Err(e) => {
                    eprintln!("ocf: persist: generation {gen:#x}: compaction persist failed: {e}");
                }
            }
            self.stats.io_retries += store.take_retries();
        }
        if snapshot_durable {
            // A durable full snapshot covers every live key — any
            // orphaned WAL segments (failed-flush eras) can go.
            if let Some(w) = self.wal.as_mut() {
                w.commit_snapshot();
                self.stats.io_retries += w.take_retries();
            }
        }
        self.sstables = vec![table];
    }

    /// Filter memory (node-level) + per-SSTable frozen filters.
    pub fn filter_memory_bytes(&self) -> usize {
        self.filter.memory_bytes()
            + self
                .sstables
                .iter()
                .map(|t| t.memory_bytes())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node() -> StorageNode {
        StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        })
    }

    #[test]
    fn put_get_roundtrip() {
        let mut n = node();
        for k in 0..500u64 {
            n.put(k).unwrap();
        }
        for k in 0..500u64 {
            assert!(n.get(k), "{k}");
        }
        assert!(!n.get(10_000));
    }

    #[test]
    fn reads_survive_flushes() {
        let mut n = node();
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.flushes > 0, "small policy must have flushed");
        assert!(n.sstable_count() >= 1);
        for k in (0..5000u64).step_by(13) {
            assert!(n.get(k), "{k}");
        }
    }

    #[test]
    fn delete_shadows_flushed_data() {
        let mut n = node();
        for k in 0..3000u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        assert!(n.delete(7));
        assert!(!n.get(7), "tombstone must shadow the SSTable version");
        assert!(n.get(8));
    }

    #[test]
    fn delete_of_absent_key_rejected() {
        let mut n = node();
        n.put(1).unwrap();
        assert!(!n.delete(99));
        assert!(n.get(1));
        assert_eq!(n.stats.deletes, 1);
    }

    #[test]
    fn filter_short_circuits_absent_reads() {
        let mut n = node();
        for k in 0..1000u64 {
            n.put(k).unwrap();
        }
        let before = n.stats.filter_short_circuits();
        for k in 1_000_000..1_001_000u64 {
            n.get(k);
        }
        let hits = n.stats.filter_short_circuits() - before;
        assert!(hits > 950, "filter should kill most absent reads: {hits}");
    }

    #[test]
    fn get_batch_matches_scalar_gets() {
        for shards in [1usize, 4] {
            let mut n = StorageNode::new(NodeConfig {
                filter: FilterBuilder::default().with_shards(shards),
                flush: FlushPolicy::small(500),
                ..NodeConfig::default()
            });
            for k in 0..3000u64 {
                n.put(k).unwrap();
            }
            for k in 0..500u64 {
                n.delete(k);
            }
            let probes: Vec<u64> = (0..4000u64).chain(9_000_000..9_001_000).collect();
            let batched = n.get_batch(&probes);
            for (&k, &b) in probes.iter().zip(&batched) {
                assert_eq!(b, n.get(k), "shards={shards} key {k}");
            }
            // batch counted once per key, and absent keys short-circuit
            assert!(n.stats.gets() >= probes.len() as u64 * 2);
            assert!(n.stats.filter_short_circuits() > 1000);
        }
    }

    #[test]
    fn concurrent_readers_share_the_node() {
        // the ROADMAP "sharded store read path" item: get takes &self,
        // so reader threads drive the (sharded) node filter directly
        let mut n = StorageNode::new(NodeConfig {
            filter: FilterBuilder::default().with_shards(4),
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        let n = &n;
        std::thread::scope(|s| {
            for t in 0..4u64 {
                s.spawn(move || {
                    for k in 0..5000u64 {
                        assert!(n.get(k), "reader {t} key {k}");
                    }
                    let absent: Vec<u64> = (8_000_000..8_001_000).collect();
                    assert!(n.get_batch(&absent).iter().all(|&b| !b));
                });
            }
        });
        assert_eq!(n.stats.gets(), 4 * (5000 + 1000));
    }

    #[test]
    fn compaction_merges_and_preserves() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(100),
            compaction: CompactionPolicy {
                max_tables: 3,
                drop_tombstones: true,
            },
            ..NodeConfig::default()
        });
        for k in 0..2000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.compactions > 0);
        assert!(n.sstable_count() <= 4);
        for k in (0..2000u64).step_by(37) {
            assert!(n.get(k), "{k}");
        }
    }

    #[test]
    fn deleted_keys_stay_dead_through_compaction() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(100),
            ..NodeConfig::default()
        });
        for k in 0..500u64 {
            n.put(k).unwrap();
        }
        for k in 0..250u64 {
            assert!(n.delete(k), "{k}");
        }
        n.flush(FlushReason::MemtableKeys);
        n.compact();
        for k in 0..250u64 {
            assert!(!n.get(k), "{k} resurrected");
        }
        for k in 250..500u64 {
            assert!(n.get(k), "{k} lost");
        }
    }

    #[test]
    fn fixed_filter_node_flushes_prematurely() {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1_000_000).with_filter_pressure(0.8),
            filter: OcfConfig {
                mode: Mode::Static,
                initial_capacity: 2048,
                ..OcfConfig::default()
            }
            .into(),
            ..NodeConfig::default()
        });
        for k in 0..10_000u64 {
            let _ = n.put(k);
        }
        assert!(
            n.stats.flushes_premature > 0,
            "fixed filter under load must premature-flush"
        );
        // OCF node under the same load: zero premature flushes
        let mut o = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1_000_000),
            ..NodeConfig::default()
        });
        for k in 0..10_000u64 {
            o.put(k).unwrap();
        }
        assert_eq!(o.stats.flushes_premature, 0);
    }

    #[test]
    fn sharded_filter_node_roundtrip() {
        let mut n = StorageNode::new(NodeConfig {
            filter: FilterBuilder::default().with_shards(4),
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.flushes > 0, "small policy must have flushed");
        assert_eq!(n.filter().name(), "sharded-ocf");
        for k in (0..5000u64).step_by(13) {
            assert!(n.get(k), "{k}");
        }
        assert!(!n.get(10_000_000));
        assert!(n.delete(7));
        assert!(!n.get(7));
        assert!(!n.delete(9_999_999), "absent delete rejected");
        assert_eq!(n.live_keys(), 4999);
        // same put/get/delete semantics as the single-filter node
        let mut single = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        for k in 0..5000u64 {
            single.put(k).unwrap();
        }
        single.delete(7);
        assert_eq!(n.live_keys(), single.live_keys());
    }

    #[test]
    fn bloom_backed_node_works_end_to_end() {
        // the dyn payoff: a baseline filter with no batch code, no
        // keystore and no delete support still gives a correct node
        let mut n = StorageNode::new(NodeConfig {
            filter: FilterBuilder::named("bloom")
                .unwrap()
                .with_initial_capacity(10_000),
            flush: FlushPolicy::small(1000),
            ..NodeConfig::default()
        });
        assert_eq!(n.filter().name(), "bloom");
        for k in 0..3000u64 {
            n.put(k).unwrap();
        }
        assert_eq!(n.live_keys(), 3000, "live count without a keystore");
        // verified deletes ride the node's own ground truth
        assert!(n.delete(7));
        assert!(!n.delete(7), "second delete rejected");
        assert!(!n.delete(999_999), "absent delete rejected");
        assert_eq!(n.live_keys(), 2999);
        // batched reads through the default scalar batch impls
        let probes: Vec<u64> = (0..4000u64).collect();
        let batched = n.get_batch(&probes);
        for (&k, &b) in probes.iter().zip(&batched) {
            assert_eq!(b, n.get(k), "key {k}");
        }
        assert!(!n.get(7), "deleted key stays dead");
        assert!(n.get(8));
    }

    #[test]
    fn every_builder_backend_drives_a_node() {
        // dyn object-safety smoke: each backend by name, same workload
        for name in crate::filter::FilterBackend::NAMES {
            let mut n = StorageNode::new(NodeConfig {
                filter: FilterBuilder::named(name)
                    .unwrap()
                    .with_initial_capacity(8192),
                flush: FlushPolicy::small(2000),
                ..NodeConfig::default()
            });
            for k in 0..1000u64 {
                n.put(k).unwrap_or_else(|e| panic!("{name}: put {k}: {e}"));
            }
            for k in (0..1000u64).step_by(7) {
                assert!(n.get(k), "{name}: lost {k}");
            }
            assert!(n.delete(3), "{name}: verified delete of live key");
            assert!(!n.get(3), "{name}: deleted key visible");
            assert!(!n.delete(5_000_000), "{name}: absent delete accepted");
            assert_eq!(n.live_keys(), 999, "{name}");
        }
    }

    #[test]
    fn put_batch_matches_scalar_put_loop() {
        for shards in [1usize, 4] {
            let cfg = || NodeConfig {
                filter: FilterBuilder::default().with_shards(shards),
                flush: FlushPolicy::small(700),
                ..NodeConfig::default()
            };
            let keys: Vec<u64> = (0..3000u64).collect();
            let mut batched = StorageNode::new(cfg());
            for r in batched.put_batch(&keys) {
                r.unwrap();
            }
            let mut scalar = StorageNode::new(cfg());
            for &k in &keys {
                scalar.put(k).unwrap();
            }
            assert_eq!(batched.stats.puts, scalar.stats.puts, "shards={shards}");
            assert_eq!(batched.live_keys(), scalar.live_keys(), "shards={shards}");
            let probes: Vec<u64> = (0..4000u64).collect();
            assert_eq!(
                batched.get_batch(&probes),
                scalar.get_batch(&probes),
                "shards={shards}"
            );
        }
    }

    #[test]
    fn node_reports_false_positives_to_adaptive_filter() {
        // narrow fingerprints → plentiful FPs for the feedback loop
        let adaptive_cfg = || NodeConfig {
            filter: FilterBuilder::named("adaptive")
                .unwrap()
                .with_initial_capacity(16_384)
                .with_fp_bits(8),
            flush: FlushPolicy::small(1_000_000),
            ..NodeConfig::default()
        };
        let mut n = StorageNode::new(adaptive_cfg());
        for k in 0..4096u64 {
            n.put(k).unwrap();
        }
        // first pass over a fixed negative set: every FP gets reported
        let negatives: Vec<u64> = (1_000_000..1_008_000u64).collect();
        assert!(n.get_batch(&negatives).iter().all(|&b| !b));
        let observed = n.stats.fp_observed();
        assert!(observed > 0, "8-bit fingerprints must collide somewhere");
        assert!(n.stats.fp_remapped() > 0, "adaptive backend must remap");
        // second pass: the learned set stops reaching the tables
        assert!(n.get_batch(&negatives).iter().all(|&b| !b));
        let repeat = n.stats.fp_observed() - observed;
        assert!(
            repeat * 10 <= observed.max(10),
            "repeat FPs must collapse ≥10×: {observed} → {repeat}"
        );
        assert!(n.fp_suppressed() > 0, "suppressions surface via the filter");
        // the contract that makes feedback safe: no false negatives
        for k in 0..4096u64 {
            assert!(n.get(k), "false negative {k} after adaptation");
        }

        // a static backend observes the same FPs but never remaps
        let mut s = StorageNode::new(NodeConfig {
            filter: FilterBuilder::default()
                .with_initial_capacity(16_384)
                .with_fp_bits(8),
            flush: FlushPolicy::small(1_000_000),
            ..NodeConfig::default()
        });
        for k in 0..4096u64 {
            s.put(k).unwrap();
        }
        assert!(s.get_batch(&negatives).iter().all(|&b| !b));
        assert!(s.stats.fp_observed() > 0);
        assert_eq!(s.stats.fp_remapped(), 0, "static backend cannot adapt");
        assert_eq!(s.fp_suppressed(), 0);
    }

    /// Unique scratch dir per test (no tempfile crate offline).
    fn scratch(tag: &str) -> String {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let n = SEQ.fetch_add(1, Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "ocf-node-{tag}-{}-{n}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_str().unwrap().to_string()
    }

    fn persistent_cfg(dir: &str) -> NodeConfig {
        NodeConfig {
            flush: FlushPolicy::small(1000),
            persist_dir: Some(dir.to_string()),
            // Group commit keeps the multi-thousand-put tests cheap;
            // against in-process "crashes" (drop without flush) the
            // write-through appends are durable regardless of policy.
            wal: WalConfig {
                enabled: true,
                fsync: FsyncPolicy::EveryN(64),
            },
            ..NodeConfig::default()
        }
    }

    #[test]
    fn recover_round_trips_membership() {
        let dir = scratch("roundtrip");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..5000u64 {
            n.put(k).unwrap();
        }
        for k in 0..100u64 {
            n.delete(k);
        }
        n.flush(FlushReason::MemtableKeys); // everything durable
        let expect: Vec<(u64, bool)> = (0..6000u64).map(|k| (k, n.get(k))).collect();
        let tables = n.sstable_count();
        drop(n);

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r.sstable_count(), tables);
        assert_eq!(
            r.stats.filters_recovered(),
            tables as u64,
            "every persisted filter must recover without a rebuild"
        );
        assert_eq!(r.stats.filters_rebuilt(), 0);
        assert_eq!(r.stats.filter_recovery_rejected(), 0);
        if cfg!(all(unix, target_endian = "little")) {
            assert!(
                r.sstables.iter().all(|t| t.filter().is_mapped()),
                "recovered filters serve off the file mapping"
            );
        }
        for (k, want) in expect {
            assert_eq!(r.get(k), want, "key {k} changed across restart");
        }

        // the recovered node keeps writing: generations don't collide
        let mut r = r;
        for k in 100_000..101_000u64 {
            r.put(k).unwrap();
        }
        r.flush(FlushReason::MemtableKeys);
        assert!(r.get(100_500));
        let r2 = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert!(r2.get(100_500), "post-recovery flush must be durable too");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_makes_unflushed_memtable_durable() {
        // the PR-7 contract: acknowledged writes survive a crash even
        // when they never reached an SSTable — the WAL replays them
        let dir = scratch("memtable");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..200u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        for k in 200..300u64 {
            n.put(k).unwrap(); // memtable-only, but WAL-logged
        }
        assert!(n.delete(5), "delete of a flushed key, memtable-only");
        assert_eq!(n.stats.wal_append_failed(), 0);
        drop(n); // no flush: simulated crash

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert!(
            r.stats.wal_replayed() >= 101,
            "unflushed ops must replay: {}",
            r.stats.wal_replayed()
        );
        for k in 0..300u64 {
            if k == 5 {
                assert!(!r.get(k), "acknowledged delete must hold after replay");
            } else {
                assert!(r.get(k), "{k} was acknowledged, must survive");
            }
        }
        assert!(!r.get(400), "recovery must not invent keys");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_disabled_restores_freeze_time_contract() {
        // with the WAL off, only flushed data survives a restart —
        // the pre-WAL behaviour, still available as a config choice
        let dir = scratch("nowal");
        let cfg = || NodeConfig {
            wal: WalConfig {
                enabled: false,
                ..WalConfig::default()
            },
            ..persistent_cfg(&dir)
        };
        let mut n = StorageNode::new(cfg());
        for k in 0..200u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        for k in 200..300u64 {
            n.put(k).unwrap(); // stays in the memtable
        }
        assert_eq!(n.stats.wal_appends(), 0);
        assert_eq!(n.stats.wal_append_failed(), 0, "disabled is not a failure");
        drop(n);
        let r = StorageNode::recover(cfg()).unwrap();
        assert_eq!(r.stats.wal_replayed(), 0);
        for k in 0..200u64 {
            assert!(r.get(k), "{k}");
        }
        for k in 200..300u64 {
            assert!(!r.get(k), "{k} was never frozen, must not resurrect");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_values_round_trip_across_restart() {
        let dir = scratch("walvalues");
        // few ops → exercise the strict default policy here
        let cfg = NodeConfig {
            wal: WalConfig::default(), // fsync = Always
            ..persistent_cfg(&dir)
        };
        let mut n = StorageNode::new(cfg);
        n.put_value(1, b"alpha").unwrap();
        n.put_value(2, b"").unwrap();
        n.put_value(3, b"gamma-with-\x00-and-\xff").unwrap();
        n.flush(FlushReason::MemtableKeys); // 1-3 via the SSTable path
        n.put_value(4, b"unflushed-bytes").unwrap(); // 4 via WAL replay
        n.put_value(1, b"alpha-v2").unwrap(); // upsert shadows the run
        drop(n);

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r.get_value(1).as_deref(), Some(&b"alpha-v2"[..]));
        assert_eq!(r.get_value(2).as_deref(), Some(&b""[..]));
        assert_eq!(r.get_value(3).as_deref(), Some(&b"gamma-with-\x00-and-\xff"[..]));
        assert_eq!(r.get_value(4).as_deref(), Some(&b"unflushed-bytes"[..]));
        assert_eq!(r.get_value(9), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_segments_retire_once_flushed() {
        let dir = scratch("walretire");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..50u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        let wal = n.wal().expect("wal configured");
        assert!(wal.segments_retired() >= 1, "flushed segment must retire");
        let segs =
            wal::list_segments(&RealIo, Path::new(&dir)).unwrap();
        assert_eq!(segs.len(), 1, "only the active segment remains: {segs:?}");
        drop(n);

        // recovery of a clean shutdown retires the leftover segments
        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r.stats.wal_replayed(), 0, "clean shutdown: nothing staged");
        let segs = wal::list_segments(&RealIo, Path::new(&dir)).unwrap();
        assert_eq!(segs.len(), 1, "stale segments cleaned: {segs:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_replay_is_idempotent_across_double_recovery() {
        let dir = scratch("walidem");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..120u64 {
            n.put(k).unwrap();
        }
        n.delete(3);
        drop(n); // crash with everything in the WAL

        let r1 = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        let snap1: Vec<bool> = (0..130u64).map(|k| r1.get(k)).collect();
        drop(r1); // crash again before any flush: segments must survive

        let r2 = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        let snap2: Vec<bool> = (0..130u64).map(|k| r2.get(k)).collect();
        assert_eq!(snap1, snap2, "second replay must answer identically");
        assert!(r2.stats.wal_replayed() >= 120);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_filter_file_falls_back_to_rebuild_and_heals() {
        let dir = scratch("corrupt");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..3000u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        let gen = n.sstables[0].generation;
        let path = n.frozen_store().unwrap().filter_path(gen);
        drop(n);
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert!(r.stats.filters_rebuilt() >= 1);
        assert!(r.stats.filter_recovery_rejected() >= 1);
        for k in (0..3000u64).step_by(17) {
            assert!(r.get(k), "{k}");
        }
        drop(r);

        // the rebuild re-persisted a valid filter: next restart recovers
        let r2 = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r2.stats.filter_recovery_rejected(), 0, "healed on disk");
        assert!(r2.stats.filters_recovered() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_filter_file_rebuilds_without_rejection() {
        let dir = scratch("missingfltr");
        let mut n = StorageNode::new(persistent_cfg(&dir));
        for k in 0..500u64 {
            n.put(k).unwrap();
        }
        n.flush(FlushReason::MemtableKeys);
        let gen = n.sstables[0].generation;
        let path = n.frozen_store().unwrap().filter_path(gen);
        drop(n);
        std::fs::remove_file(&path).unwrap(); // the crash-between-run-and-filter window

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r.stats.filters_rebuilt(), 1);
        assert_eq!(
            r.stats.filter_recovery_rejected(),
            0,
            "absent is the normal crash window, not a rejection"
        );
        for k in (0..500u64).step_by(7) {
            assert!(r.get(k), "{k}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interrupted_compaction_swap_does_not_resurrect_dropped_keys() {
        use super::super::frozen::FrozenStore;
        let dir = scratch("swapcrash");
        let store = FrozenStore::open(&dir).unwrap();
        // Crash state: compaction persisted its merged output (gen 2,
        // full snapshot, tombstone for key 1 dropped) but died before
        // cleaning up its input (gen 1, which still holds Put 1).
        let old = SsTable::from_sorted_run(
            vec![(1, Entry::put_sized(8)), (2, Entry::put_sized(8))],
            1,
            16,
            7,
        );
        let merged = SsTable::from_sorted_run(vec![(2, Entry::put_sized(8))], 2, 16, 5);
        store.persist(&old).unwrap();
        store.persist_full(&merged).unwrap();

        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert!(!r.get(1), "dropped tombstone's key must stay dead");
        assert!(r.get(2));
        assert_eq!(r.sstable_count(), 1, "stale input discarded");
        assert_eq!(
            store.generations().unwrap(),
            vec![2],
            "recovery finished the interrupted cleanup"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_swaps_persisted_generations() {
        let dir = scratch("compact");
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(100),
            compaction: CompactionPolicy {
                max_tables: 3,
                drop_tombstones: true,
            },
            persist_dir: Some(dir.clone()),
            ..NodeConfig::default()
        });
        for k in 0..2000u64 {
            n.put(k).unwrap();
        }
        assert!(n.stats.compactions > 0);
        let on_disk = n.frozen_store().unwrap().generations().unwrap();
        let in_mem: Vec<u64> = n.sstables.iter().map(|t| t.generation).collect();
        assert_eq!(on_disk, in_mem, "disk mirrors the live table set");
        drop(n);
        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        for k in (0..2000u64).step_by(37) {
            assert!(r.get(k), "{k}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_on_empty_or_missing_dir_starts_clean() {
        let dir = scratch("fresh");
        let r = StorageNode::recover(persistent_cfg(&dir)).unwrap();
        assert_eq!(r.sstable_count(), 0);
        assert_eq!(r.stats.filters_recovered(), 0);
        assert!(!r.get(1));
        // and without persist_dir, recover is a config error
        assert!(StorageNode::recover(NodeConfig::default()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_keys_tracks_population() {
        let mut n = node();
        for k in 0..100u64 {
            n.put(k).unwrap();
        }
        assert_eq!(n.live_keys(), 100);
        for k in 0..50u64 {
            n.delete(k);
        }
        assert_eq!(n.live_keys(), 50);
    }

    #[test]
    fn live_keys_in_arc_pages_deterministically() {
        let mut n = node();
        for k in 0..400u64 {
            n.put(k).unwrap();
        }
        for k in 0..40u64 {
            n.delete(k);
        }
        // full ring (lo == hi): paging must cover exactly the live set
        let mut paged: Vec<u64> = Vec::new();
        let mut cursor = None;
        loop {
            let page = n.live_keys_in_arc(7, 7, cursor, 64);
            if page.is_empty() {
                break;
            }
            assert!(page.len() <= 64);
            cursor = page.last().copied();
            paged.extend(page);
        }
        let expect: Vec<u64> = (40..400u64).collect();
        assert_eq!(paged, expect, "pages must cover the live set in order");
        // a proper arc partitions the ring: (lo, hi] ∪ (hi, lo] = all
        let split = 1u64 << 63;
        let lower = n.live_keys_in_arc(0, split, None, usize::MAX);
        let upper = n.live_keys_in_arc(split, 0, None, usize::MAX);
        assert_eq!(lower.len() + upper.len(), 360);
        assert!(lower.iter().all(|k| !upper.contains(k)));
        // deterministic: same inputs, same page
        assert_eq!(
            n.live_keys_in_arc(0, split, Some(100), 16),
            n.live_keys_in_arc(0, split, Some(100), 16)
        );
    }

    #[test]
    fn delete_batch_matches_scalar_deletes() {
        let mut batched = node();
        let mut scalar = node();
        for k in 0..300u64 {
            batched.put(k).unwrap();
            scalar.put(k).unwrap();
        }
        // mix of live, already-deleted, and never-present keys
        let victims: Vec<u64> = (0..400u64).filter(|k| k % 3 == 0).collect();
        let b = batched.delete_batch(&victims);
        let s: Vec<bool> = victims.iter().map(|&k| scalar.delete(k)).collect();
        assert_eq!(b, s);
        assert_eq!(batched.live_keys(), scalar.live_keys());
        assert_eq!(batched.stats.deletes, scalar.stats.deletes);
    }

    #[test]
    fn enospc_flips_node_into_read_only_degraded_mode() {
        use super::super::io::{FaultConfig, FaultyIo};
        let dir = scratch("enospc");
        let mut cfg = persistent_cfg(&dir);
        cfg.io = Some(Arc::new(FaultyIo::new(FaultConfig {
            // enough budget for the WAL header + a handful of appends
            enospc_after_bytes: Some(512),
            ..FaultConfig::default()
        })));
        let mut n = StorageNode::new(cfg);
        // writes succeed until the disk "fills"
        let mut accepted = 0u64;
        for k in 0..200u64 {
            match n.put(k) {
                Ok(()) => accepted += 1,
                Err(crate::filter::FilterError::Unavailable(_)) => break,
                Err(e) => panic!("unexpected refusal: {e}"),
            }
        }
        assert!(accepted > 0, "some writes must land before the disk fills");
        assert!(accepted < 200, "the byte budget must eventually fire");
        assert!(n.stats.degraded(), "ENOSPC must flip the degraded flag");
        assert!(n.stats.wal_append_failed() > 0);
        // the flip is sticky: every write path refuses at the door
        assert!(matches!(
            n.put(9999),
            Err(crate::filter::FilterError::Unavailable(_))
        ));
        assert!(n
            .put_batch(&[1_000, 1_001])
            .iter()
            .all(|r| matches!(r, Err(crate::filter::FilterError::Unavailable(_)))));
        assert!(!n.delete(0), "read-only mode refuses deletes");
        let puts_after = n.stats.puts;
        let _ = n.put(10_000);
        assert_eq!(n.stats.puts, puts_after, "refused writes are not counted");
        // reads keep serving the pre-degradation state
        assert!(n.get(0), "accepted writes stay readable");
        assert!(!n.get(9999), "refused write never became visible");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! The store's I/O seam: every file operation the persistent tier
//! performs goes through the [`StoreIo`] trait, so tests can swap the
//! real filesystem for a deterministic fault injector.
//!
//! Two implementations:
//!
//! * [`RealIo`] — a zero-cost passthrough to `std::fs`. Production
//!   nodes use this (it is the default when `NodeConfig::io` is
//!   unset).
//! * [`FaultyIo`] — wraps the real filesystem but injects faults
//!   according to a seeded, fully deterministic [`FaultConfig`]:
//!   numbered **crash-points** (every mutating operation gets an
//!   ordinal; at the configured ordinal the "disk" dies, optionally
//!   leaving a torn prefix of the in-flight write), **short writes**
//!   on appends, one-shot **transient errors** (`EINTR`-style, to
//!   exercise the retry path), and **ENOSPC** after a byte budget.
//!
//! The crash-point model is what makes systematic crash testing
//! possible: a counting pass runs a workload against `FaultyIo` with
//! no crash configured and reads [`FaultyIo::mutations`]; the sweep
//! then re-runs the same deterministic workload once per ordinal
//! `0..n` with `crash_after = Some(i)`, covering *every* distinct
//! on-disk state the workload can be interrupted in. See
//! `testutil::crash`.
//!
//! Design notes:
//!
//! * Operations are **path-based** (open/act/close per call) rather
//!   than handle-based. That costs an `open` per WAL append, which is
//!   deliberate: it keeps the fault injector stateless per-call and
//!   the crash-point numbering stable. The WAL's group-commit fsync
//!   policy amortises the part that actually dominates (the fsync).
//! * Read-side operations never consume a crash-point ordinal (they
//!   don't change disk state) but all fail once the injected crash
//!   has fired — a dead disk is dead for reads too.
//! * `create_dir_all` is treated as a setup-phase operation: it also
//!   does not consume an ordinal, so a node can always be
//!   *constructed* and the sweep exercises failures in the
//!   interesting places (WAL segment creation onward).

use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::util::SplitMix64;

/// The file operations the persistent tier needs, abstracted for
/// fault injection. All implementations must be `Send + Sync`: the
/// store shares one instance across `FrozenStore`, the WAL, and
/// recovery.
pub trait StoreIo: fmt::Debug + Send + Sync {
    /// Read an entire file.
    fn read(&self, path: &Path) -> io::Result<Vec<u8>>;
    /// Open a file for reading (streamed reads / mmap). The returned
    /// handle performs *real* filesystem reads — mapping a fake file
    /// is not meaningful — but the open itself is gated.
    fn open_read(&self, path: &Path) -> io::Result<File>;
    /// List a directory's entry file names (not full paths).
    fn read_dir(&self, path: &Path) -> io::Result<Vec<String>>;
    /// Create/truncate `path` and write all of `bytes`.
    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()>;
    /// Append `bytes` to `path` (creating it if absent), returning
    /// how many bytes were actually appended — implementations may
    /// legally write a **short** count; callers must loop.
    fn append(&self, path: &Path, bytes: &[u8]) -> io::Result<usize>;
    /// fsync `path`'s contents to stable storage.
    fn sync(&self, path: &Path) -> io::Result<()>;
    /// Atomically rename `from` to `to`.
    fn rename(&self, from: &Path, to: &Path) -> io::Result<()>;
    /// Remove a file.
    fn remove_file(&self, path: &Path) -> io::Result<()>;
    /// Create a directory and its parents.
    fn create_dir_all(&self, path: &Path) -> io::Result<()>;
}

/// Passthrough to the real filesystem.
#[derive(Debug, Default, Clone, Copy)]
pub struct RealIo;

impl StoreIo for RealIo {
    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        std::fs::read(path)
    }

    fn open_read(&self, path: &Path) -> io::Result<File> {
        File::open(path)
    }

    fn read_dir(&self, path: &Path) -> io::Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(path)? {
            let entry = entry?;
            if let Some(name) = entry.file_name().to_str() {
                names.push(name.to_string());
            }
        }
        Ok(names)
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        let mut f = File::create(path)?;
        f.write_all(bytes)
    }

    fn append(&self, path: &Path, bytes: &[u8]) -> io::Result<usize> {
        let mut f = OpenOptions::new().create(true).append(true).open(path)?;
        f.write_all(bytes)?;
        Ok(bytes.len())
    }

    fn sync(&self, path: &Path) -> io::Result<()> {
        // Open read-only: fsync flushes the file's dirty pages
        // regardless of the descriptor's access mode.
        File::open(path)?.sync_all()
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        std::fs::rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        std::fs::remove_file(path)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        std::fs::create_dir_all(path)
    }
}

/// Deterministic fault plan for [`FaultyIo`]. Everything is derived
/// from `seed` and the operation ordinal — re-running the same
/// workload against the same config reproduces the same faults.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Seed for the torn-write length RNG.
    pub seed: u64,
    /// Crash (permanently fail all I/O) at mutating-operation ordinal
    /// `n` — i.e. the op with `mutations() == n` fails and every
    /// operation after it fails too.
    pub crash_after: Option<u64>,
    /// When crashing on a `write`/`append`, leave a *torn prefix* of
    /// the in-flight bytes on disk (seeded-random length), modelling
    /// a torn page at power loss. Checksums must catch it.
    pub torn_tail: bool,
    /// Every `k`-th mutating op (ordinals `k-1`, `2k-1`, ...) first
    /// fails once with `ErrorKind::Interrupted`, then succeeds when
    /// retried — exercises `util::retry` paths.
    pub transient_every: Option<u64>,
    /// Every `k`-th mutating op, an `append` writes only half its
    /// bytes (short write) — callers must loop.
    pub short_write_every: Option<u64>,
    /// Fail writes/appends with an ENOSPC-style error once this many
    /// payload bytes have been written through the injector.
    pub enospc_after_bytes: Option<u64>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 0x0c_f1_0c_f1,
            crash_after: None,
            torn_tail: true,
            transient_every: None,
            short_write_every: None,
            enospc_after_bytes: None,
        }
    }
}

#[derive(Debug)]
struct FaultState {
    /// Ordinal counter over *mutating* ops (write/append/sync/rename/
    /// remove_file). Reads don't count: they can't change disk state,
    /// so they can't create new crash-recovery cases.
    mutations: u64,
    bytes_written: u64,
    crashed: bool,
    /// One-shot latch: the op retried after a transient failure must
    /// succeed (otherwise `transient_every` would starve retries).
    transient_pending: bool,
    rng: SplitMix64,
}

/// A deterministic fault-injecting [`StoreIo`] over the real
/// filesystem. Not a simulation: real files are written, so recovery
/// code paths (mmap, read-back, checksum validation) run unmodified —
/// only the *failure schedule* is synthetic.
pub struct FaultyIo {
    cfg: FaultConfig,
    state: Mutex<FaultState>,
}

impl fmt::Debug for FaultyIo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let st = self.state.lock().unwrap();
        f.debug_struct("FaultyIo")
            .field("cfg", &self.cfg)
            .field("mutations", &st.mutations)
            .field("crashed", &st.crashed)
            .finish()
    }
}

fn crashed_err() -> io::Error {
    io::Error::new(io::ErrorKind::Other, "injected crash: device is gone")
}

fn enospc_err() -> io::Error {
    io::Error::new(
        io::ErrorKind::Other,
        "injected ENOSPC: no space left on device",
    )
}

impl FaultyIo {
    pub fn new(cfg: FaultConfig) -> Self {
        let rng = SplitMix64::new(cfg.seed);
        Self {
            cfg,
            state: Mutex::new(FaultState {
                mutations: 0,
                bytes_written: 0,
                crashed: false,
                transient_pending: false,
                rng,
            }),
        }
    }

    /// A crash-point at ordinal `point` with torn tails on, seeded
    /// for determinism — the sweep's standard configuration.
    pub fn crash_at(seed: u64, point: u64) -> Self {
        Self::new(FaultConfig {
            seed,
            crash_after: Some(point),
            ..FaultConfig::default()
        })
    }

    /// Mutating operations performed (or attempted) so far. A
    /// counting pass reads this to learn a workload's crash-point
    /// space.
    pub fn mutations(&self) -> u64 {
        self.state.lock().unwrap().mutations
    }

    /// Has the injected crash fired?
    pub fn crashed(&self) -> bool {
        self.state.lock().unwrap().crashed
    }

    /// Gate a mutating operation: assign it an ordinal and decide its
    /// fate. `in_flight` carries the bytes being written (for torn
    /// tails at the crash point). Returns the op's ordinal on
    /// success.
    fn gate_mutation(&self, in_flight: Option<(&Path, &[u8], bool)>) -> io::Result<u64> {
        let mut st = self.state.lock().unwrap();
        if st.crashed {
            return Err(crashed_err());
        }
        let op = st.mutations;
        st.mutations += 1;
        if let Some(n) = self.cfg.crash_after {
            if op >= n {
                st.crashed = true;
                if self.cfg.torn_tail {
                    if let Some((path, bytes, append)) = in_flight {
                        // Torn prefix: 0..len bytes actually land.
                        if !bytes.is_empty() {
                            let torn = (st.rng.next_u64() as usize) % bytes.len();
                            if torn > 0 {
                                let real = RealIo;
                                let _ = if append {
                                    real.append(path, &bytes[..torn]).map(|_| ())
                                } else {
                                    real.write(path, &bytes[..torn])
                                };
                            }
                        }
                    }
                }
                return Err(crashed_err());
            }
        }
        if st.transient_pending {
            // The retry of a transient failure goes through.
            st.transient_pending = false;
        } else if let Some(k) = self.cfg.transient_every {
            if k > 0 && (op + 1) % k == 0 {
                st.transient_pending = true;
                return Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    "injected transient EINTR",
                ));
            }
        }
        Ok(op)
    }

    fn charge_bytes(&self, len: usize) -> io::Result<()> {
        let mut st = self.state.lock().unwrap();
        if let Some(budget) = self.cfg.enospc_after_bytes {
            if st.bytes_written.saturating_add(len as u64) > budget {
                return Err(enospc_err());
            }
        }
        st.bytes_written += len as u64;
        Ok(())
    }

    fn check_alive(&self) -> io::Result<()> {
        if self.state.lock().unwrap().crashed {
            Err(crashed_err())
        } else {
            Ok(())
        }
    }
}

impl StoreIo for FaultyIo {
    fn read(&self, path: &Path) -> io::Result<Vec<u8>> {
        self.check_alive()?;
        RealIo.read(path)
    }

    fn open_read(&self, path: &Path) -> io::Result<File> {
        self.check_alive()?;
        RealIo.open_read(path)
    }

    fn read_dir(&self, path: &Path) -> io::Result<Vec<String>> {
        self.check_alive()?;
        RealIo.read_dir(path)
    }

    fn write(&self, path: &Path, bytes: &[u8]) -> io::Result<()> {
        self.gate_mutation(Some((path, bytes, false)))?;
        self.charge_bytes(bytes.len())?;
        RealIo.write(path, bytes)
    }

    fn append(&self, path: &Path, bytes: &[u8]) -> io::Result<usize> {
        let op = self.gate_mutation(Some((path, bytes, true)))?;
        let mut len = bytes.len();
        if let Some(k) = self.cfg.short_write_every {
            if k > 0 && (op + 1) % k == 0 && len > 1 {
                len /= 2;
            }
        }
        self.charge_bytes(len)?;
        RealIo.append(path, &bytes[..len])?;
        Ok(len)
    }

    fn sync(&self, path: &Path) -> io::Result<()> {
        self.gate_mutation(None)?;
        RealIo.sync(path)
    }

    fn rename(&self, from: &Path, to: &Path) -> io::Result<()> {
        self.gate_mutation(None)?;
        RealIo.rename(from, to)
    }

    fn remove_file(&self, path: &Path) -> io::Result<()> {
        self.gate_mutation(None)?;
        RealIo.remove_file(path)
    }

    fn create_dir_all(&self, path: &Path) -> io::Result<()> {
        // Setup-phase: gated on liveness but not ordinal-numbered,
        // so node construction is always reachable in a sweep.
        self.check_alive()?;
        RealIo.create_dir_all(path)
    }
}

/// Read `path` fully via a [`StoreIo`] handle — helper shared by the
/// frozen-format readers.
pub fn read_via_handle(io: &dyn StoreIo, path: &Path) -> io::Result<Vec<u8>> {
    let mut f = io.open_read(path)?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ocf-io-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn real_io_roundtrip_append_read() {
        let dir = scratch("real");
        let p = dir.join("a.bin");
        let io = RealIo;
        io.write(&p, b"hello ").unwrap();
        let n = io.append(&p, b"world").unwrap();
        assert_eq!(n, 5);
        assert_eq!(io.read(&p).unwrap(), b"hello world");
        io.sync(&p).unwrap();
        let names = io.read_dir(&dir).unwrap();
        assert!(names.contains(&"a.bin".to_string()));
        io.remove_file(&p).unwrap();
        assert!(io.read(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_point_kills_all_subsequent_io() {
        let dir = scratch("crash");
        let p = dir.join("x.bin");
        let io = FaultyIo::crash_at(1, 2);
        io.write(&p, b"one").unwrap(); // op 0
        io.sync(&p).unwrap(); // op 1
        assert!(io.write(&p, b"three").is_err()); // op 2: crash fires
        assert!(io.crashed());
        assert!(io.read(&p).is_err(), "dead disk is dead for reads");
        assert!(io.sync(&p).is_err());
        assert!(io.append(&p, b"z").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_counting_is_deterministic() {
        let dir = scratch("det");
        let run = |io: &FaultyIo| {
            let p = dir.join("d.bin");
            let _ = io.write(&p, b"abc");
            let _ = io.append(&p, b"def");
            let _ = io.sync(&p);
            let _ = io.remove_file(&p);
        };
        let a = FaultyIo::new(FaultConfig::default());
        run(&a);
        let b = FaultyIo::new(FaultConfig::default());
        run(&b);
        assert_eq!(a.mutations(), b.mutations());
        assert_eq!(a.mutations(), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_leaves_a_strict_prefix() {
        let dir = scratch("torn");
        let p = dir.join("t.bin");
        // crash at op 0 (the write itself), torn tails on
        let io = FaultyIo::crash_at(7, 0);
        let payload = vec![0xabu8; 4096];
        assert!(io.write(&p, &payload).is_err());
        match std::fs::read(&p) {
            Ok(bytes) => {
                assert!(bytes.len() < payload.len(), "torn prefix must be short");
                assert!(payload.starts_with(&bytes));
            }
            // torn length 0: nothing landed — also legal
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::NotFound),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn transient_fails_once_then_succeeds_on_retry() {
        let dir = scratch("transient");
        let p = dir.join("tr.bin");
        let io = Arc::new(FaultyIo::new(FaultConfig {
            transient_every: Some(1), // every op is transient-once
            ..FaultConfig::default()
        }));
        let io2 = io.clone();
        let r = crate::util::retry_transient(move || io2.write(&p, b"persisted"));
        assert!(r.result.is_ok());
        assert_eq!(r.retries, 1);
        assert_eq!(std::fs::read(dir.join("tr.bin")).unwrap(), b"persisted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn short_writes_force_callers_to_loop() {
        let dir = scratch("short");
        let p = dir.join("s.bin");
        let io = FaultyIo::new(FaultConfig {
            short_write_every: Some(1), // every append is short
            ..FaultConfig::default()
        });
        let payload = b"0123456789";
        let mut off = 0;
        while off < payload.len() {
            off += io.append(&p, &payload[off..]).unwrap();
        }
        assert_eq!(std::fs::read(&p).unwrap(), payload);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn enospc_fires_after_byte_budget() {
        let dir = scratch("enospc");
        let p = dir.join("e.bin");
        let io = FaultyIo::new(FaultConfig {
            enospc_after_bytes: Some(10),
            ..FaultConfig::default()
        });
        io.write(&p, b"12345").unwrap();
        io.write(&p, b"12345").unwrap();
        let err = io.write(&p, b"x").unwrap_err();
        assert!(err.to_string().contains("ENOSPC"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Live membership: the range-transfer plan that moves a joining (or
//! leaving) node's captured data without ever breaking the PR-9
//! contract.
//!
//! A membership change is a **ring transition** `old → new`. Splitting
//! the ring at the union of both rings' tokens yields arcs on which
//! *both* replica walks are constant, so the whole transition reduces
//! to a finite list of [`RangeTransfer`]s — the arcs whose new replica
//! set *gains* a node (the joiner, or the successor of a leaver). Each
//! range is an independent little state machine:
//!
//! ```text
//!   Pending ──(first pump)──▶ Streaming ──(commit gate)──▶ HandedOff
//! ```
//!
//! - **Streaming**: every old replica of the arc is paged in key order
//!   through the proxy seam (`stream_page` → `get_value` on the donor,
//!   `put_value` on each gainer), bounded `transfer_batch` keys per
//!   pump. Enumerating the *union* of all old replicas (not just the
//!   primary) is what makes donor death survivable: a write acked at
//!   quorum lives on ≥ 2 old replicas, so a single stale or crashed
//!   donor can never starve the gainer of an acked key. A donor that
//!   is unreachable simply stalls its range (counted in
//!   `transfers_retried`) until it recovers — reads keep routing to
//!   the old owners meanwhile, which is always safe.
//! - **Dual-apply**: a client write to a key in a non-committed range
//!   applies to the old replica set (which carries the consistency
//!   accounting) *and* to every gainer. A gainer that takes it is
//!   recorded in `overridden` — the stream must not later overwrite
//!   that newer state with a stale donor copy (the seq-tagged
//!   supersession rule of `handoff.rs`, applied to streaming). A
//!   gainer that misses it gets a hint, exactly like any down replica.
//! - **Commit gate**: a range hands off only when every donor has been
//!   fully paged *and* no hint destined to a gainer still names a key
//!   in the arc. At that point the gainer provably holds every acked
//!   write for the range (streamed, dual-applied, or hint-replayed),
//!   so flipping reads from the old owners to the new replica set
//!   preserves the R+W > RF overlap argument across the flip.
//!
//! The conservation law (proptest P19): every captured key is streamed
//! exactly once or superseded by a newer direct write — at completion
//! `keys_captured == keys_streamed + keys_superseded`, and nothing is
//! ever silently dropped.
//!
//! Everything here is a pure function of the rings and the op
//! sequence: `BTreeMap`/`BTreeSet` state, sorted pages, deterministic
//! donor order — membership chaos runs replay bit-identically from
//! their seed.

use std::collections::{BTreeMap, BTreeSet};

use super::ring::HashRing;

/// Which membership change a transition is carrying out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipChange {
    /// Node `id` is joining: it is the gainer of every range.
    Join(usize),
    /// Node `id` is leaving: each of its arcs falls to a successor.
    Leave(usize),
}

impl MembershipChange {
    pub fn node(&self) -> usize {
        match *self {
            MembershipChange::Join(id) | MembershipChange::Leave(id) => id,
        }
    }
}

/// Why a membership request was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MembershipError {
    /// Another transition is still streaming; one at a time.
    TransferInProgress,
    /// The id is not an active ring member (never added, or retired).
    UnknownNode(usize),
    /// Removing the last member would empty the ring.
    LastNode,
}

impl std::fmt::Display for MembershipError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MembershipError::TransferInProgress => {
                write!(f, "a membership transfer is already in progress")
            }
            MembershipError::UnknownNode(id) => write!(f, "node {id} is not an active member"),
            MembershipError::LastNode => write!(f, "cannot remove the last ring member"),
        }
    }
}

impl std::error::Error for MembershipError {}

/// Per-range transfer progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RangeState {
    /// Planned, no pump has touched it yet.
    Pending,
    /// Donors are being paged; reads still route to the old owners.
    Streaming,
    /// Committed: reads route to the new replica set.
    HandedOff,
}

/// One captured token arc `(lo, hi]` (wrapping when `lo > hi`) and the
/// state of moving its keys to the gainers.
#[derive(Debug, Clone, PartialEq)]
pub struct RangeTransfer {
    pub lo: u64,
    pub hi: u64,
    /// Replica walk of the arc in the old ring — the donors, and the
    /// read/write targets until the range commits.
    pub old_replicas: Vec<usize>,
    /// Replica walk of the arc in the new ring.
    pub new_replicas: Vec<usize>,
    /// `new_replicas − old_replicas`: the nodes that must be fed.
    pub gainers: Vec<usize>,
    pub state: RangeState,
    /// Index into `old_replicas` of the donor currently being paged.
    pub donor_idx: usize,
    /// Last key fully resolved from the current donor's pages.
    pub cursor: Option<u64>,
    /// key → bitmask over `gainers` of stream copies that landed.
    pub streamed: BTreeMap<u64, u32>,
    /// key → bitmask over `gainers` holding newer *direct* state (a
    /// dual-applied write) — the stream must skip these.
    pub overridden: BTreeMap<u64, u32>,
    /// Every key any donor has enumerated (conservation numerator).
    pub captured: BTreeSet<u64>,
    /// Keys fully resolved (every gainer streamed or overridden).
    pub done: BTreeSet<u64>,
}

impl RangeTransfer {
    /// Does ring position `token` fall in this arc?
    pub fn contains(&self, token: u64) -> bool {
        if self.lo < self.hi {
            self.lo < token && token <= self.hi
        } else if self.lo > self.hi {
            token > self.lo || token <= self.hi
        } else {
            true // single-token union: the arc is the whole ring
        }
    }

    pub fn committed(&self) -> bool {
        self.state == RangeState::HandedOff
    }

    /// Bitmask with one bit per gainer, all set.
    pub fn full_mask(&self) -> u32 {
        if self.gainers.len() >= 32 {
            u32::MAX
        } else {
            (1u32 << self.gainers.len()) - 1
        }
    }
}

/// A planned `old → new` ring transition: the full set of captured
/// ranges plus both rings, owned by the router while it streams.
#[derive(Debug, Clone, PartialEq)]
pub struct RingTransition {
    pub change: MembershipChange,
    pub old: HashRing,
    pub new: HashRing,
    /// Captured arcs, sorted by `hi` (the wrap arc, if captured, is
    /// first — it has the smallest `hi`).
    pub ranges: Vec<RangeTransfer>,
}

impl RingTransition {
    /// Split the ring at the union of both rings' tokens and keep the
    /// arcs whose new replica walk gains a node. On every kept arc the
    /// old and new replica sets are constant (no union token lies
    /// strictly inside an arc, and both rings' tokens are subsets of
    /// the union), so one [`HashRing::replicas_at`] call per ring
    /// covers the whole arc.
    pub fn plan(change: MembershipChange, old: HashRing, new: HashRing, rf: usize) -> Self {
        let mut cuts: Vec<u64> = old
            .tokens()
            .iter()
            .chain(new.tokens().iter())
            .map(|&(t, _)| t)
            .collect();
        cuts.sort_unstable();
        cuts.dedup();
        let mut ranges = Vec::new();
        for (i, &hi) in cuts.iter().enumerate() {
            let lo = if i == 0 { *cuts.last().unwrap() } else { cuts[i - 1] };
            let old_replicas = old.replicas_at(hi, rf);
            let new_replicas = new.replicas_at(hi, rf);
            let gainers: Vec<usize> = new_replicas
                .iter()
                .copied()
                .filter(|n| !old_replicas.contains(n))
                .collect();
            if gainers.is_empty() {
                continue;
            }
            assert!(gainers.len() <= 32, "gainer bitmask is u32");
            ranges.push(RangeTransfer {
                lo,
                hi,
                old_replicas,
                new_replicas,
                gainers,
                state: RangeState::Pending,
                donor_idx: 0,
                cursor: None,
                streamed: BTreeMap::new(),
                overridden: BTreeMap::new(),
                captured: BTreeSet::new(),
                done: BTreeSet::new(),
            });
        }
        Self {
            change,
            old,
            new,
            ranges,
        }
    }

    /// Index of the captured range containing ring position `token`,
    /// if any — `None` means the arc's replica sets are identical in
    /// both rings and either walk may serve it.
    pub fn range_index(&self, token: u64) -> Option<usize> {
        let idx = self.ranges.partition_point(|r| r.hi < token);
        if idx < self.ranges.len() && self.ranges[idx].contains(token) {
            return Some(idx);
        }
        // the wrap arc (lo > hi) sorts first by `hi`; tokens above
        // every `hi` belong to it when it was captured
        if self
            .ranges
            .first()
            .is_some_and(|r| r.lo > r.hi && r.contains(token))
        {
            return Some(0);
        }
        None
    }

    pub fn range_for(&self, token: u64) -> Option<&RangeTransfer> {
        self.range_index(token).map(|i| &self.ranges[i])
    }

    /// Ranges not yet handed off.
    pub fn pending(&self) -> usize {
        self.ranges.iter().filter(|r| !r.committed()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sorted(mut v: Vec<usize>) -> Vec<usize> {
        v.sort_unstable();
        v
    }

    #[test]
    fn join_plan_routes_old_until_commit_then_new() {
        let old = HashRing::new(4, 32);
        let mut grown = old.clone();
        grown.add_node(4);
        let mut tr = RingTransition::plan(MembershipChange::Join(4), old.clone(), grown.clone(), 3);
        assert!(!tr.ranges.is_empty(), "a join must capture ranges");
        for r in &tr.ranges {
            assert_eq!(r.gainers, vec![4], "the joiner is the only gainer");
            assert!(!r.old_replicas.contains(&4));
            assert!(r.new_replicas.contains(&4));
        }
        for k in 0..4000u64 {
            let token = crate::filter::fingerprint::mix64(k);
            let old_r = old.replicas(k, 3);
            let new_r = grown.replicas(k, 3);
            match tr.range_for(token) {
                Some(r) => {
                    assert_eq!(r.old_replicas, old_r, "key {k}");
                    assert_eq!(r.new_replicas, new_r, "key {k}");
                    assert!(new_r.contains(&4), "captured arc must involve the joiner");
                }
                None => {
                    // un-captured arcs must be identical in both rings —
                    // routing with either is correct
                    assert_eq!(old_r, new_r, "key {k}: uncaptured arc diverged");
                }
            }
        }
        // commit everything: every key now walks the new ring
        for r in &mut tr.ranges {
            r.state = RangeState::HandedOff;
        }
        for k in 0..1000u64 {
            let token = crate::filter::fingerprint::mix64(k);
            if let Some(r) = tr.range_for(token) {
                assert!(r.committed());
                assert_eq!(r.new_replicas, grown.replicas(k, 3));
            }
        }
    }

    #[test]
    fn leave_plan_gains_exactly_one_successor_per_range() {
        let old = HashRing::new(5, 32);
        let mut shrunk = old.clone();
        shrunk.remove_node(2);
        let tr = RingTransition::plan(MembershipChange::Leave(2), old.clone(), shrunk.clone(), 3);
        assert!(!tr.ranges.is_empty());
        for r in &tr.ranges {
            assert_eq!(r.gainers.len(), 1, "one successor per captured arc");
            assert!(r.old_replicas.contains(&2), "only node-2 arcs are captured");
            assert!(!r.new_replicas.contains(&2));
            assert!(!r.gainers.contains(&2));
        }
        // arcs that lose node 2 but gain nobody cannot exist at rf=3
        // with 4 survivors; every changed arc is captured
        for k in 0..4000u64 {
            let old_r = sorted(old.replicas(k, 3));
            let new_r = sorted(shrunk.replicas(k, 3));
            if old_r != new_r {
                let token = crate::filter::fingerprint::mix64(k);
                assert!(tr.range_for(token).is_some(), "changed key {k} not captured");
            }
        }
    }

    #[test]
    fn shrinking_below_rf_captures_nothing() {
        // 3 nodes at rf=3: removing one leaves rf capped at 2 —
        // survivors already hold everything, nothing to stream
        let old = HashRing::new(3, 32);
        let mut shrunk = old.clone();
        shrunk.remove_node(1);
        let tr = RingTransition::plan(MembershipChange::Leave(1), old, shrunk, 3);
        assert!(tr.ranges.is_empty(), "no gainers when survivors ⊆ old replicas");
        assert_eq!(tr.pending(), 0);
    }

    #[test]
    fn range_lookup_covers_the_whole_ring_consistently() {
        let old = HashRing::new(3, 16);
        let mut grown = old.clone();
        grown.add_node(3);
        let tr = RingTransition::plan(MembershipChange::Join(3), old, grown, 3);
        // every captured range must resolve to itself; bounds exact
        for (i, r) in tr.ranges.iter().enumerate() {
            assert_eq!(tr.range_index(r.hi), Some(i), "hi is inside its own arc");
            assert_ne!(
                tr.range_index(r.lo),
                Some(i),
                "lo is excluded from the arc"
            );
        }
    }

    #[test]
    fn membership_errors_render() {
        assert!(MembershipError::TransferInProgress.to_string().contains("in progress"));
        assert!(MembershipError::UnknownNode(7).to_string().contains('7'));
        assert!(MembershipError::LastNode.to_string().contains("last"));
    }
}

//! Per-node health tracking: a circuit breaker over the replica op
//! stream.
//!
//! Closed → open (after `threshold` consecutive *unreachable* failures;
//! node-level refusals like a saturated filter don't count — the node
//! answered) → half-open (cooldown expired; real ops trickle through as
//! probes) → closed again (`probes` consecutive probe successes) or
//! straight back to open (a probe fails).
//!
//! "Time" here is the cluster's deterministic op-tick clock, never wall
//! time: a chaos sweep replaying the same seed sees bit-identical
//! breaker transitions (proptest P18), and production cooldowns scale
//! with traffic rather than idle seconds.

/// Breaker thresholds (`[cluster] breaker_*` config keys).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Consecutive unreachable failures that open the breaker.
    pub threshold: u32,
    /// Op-ticks the breaker stays open before letting a probe through.
    pub cooldown: u64,
    /// Consecutive half-open probe successes that close it again.
    pub probes: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            threshold: 3,
            cooldown: 64,
            probes: 2,
        }
    }
}

/// Breaker state machine position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: every op passes.
    Closed,
    /// Tripped: ops fast-fail (and writes hint) until tick `until`.
    Open { until: u64 },
    /// Probing: ops pass; `successes` consecutive wins so far.
    HalfOpen { successes: u32 },
}

/// Transition emitted by the record calls — the router turns these
/// into `ClusterStats` counters and hint-replay triggers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerEvent {
    None,
    /// Closed/half-open → open.
    Tripped,
    /// Half-open → closed: the node is back; replay its hints.
    Closed,
}

/// One node's health as the router sees it.
#[derive(Debug, Clone)]
pub struct NodeHealth {
    cfg: BreakerConfig,
    state: BreakerState,
    consecutive_failures: u32,
}

impl NodeHealth {
    pub fn new(cfg: BreakerConfig) -> Self {
        Self {
            cfg,
            state: BreakerState::Closed,
            consecutive_failures: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn is_open(&self) -> bool {
        matches!(self.state, BreakerState::Open { .. })
    }

    /// May an op attempt the node at tick `now`? The open → half-open
    /// transition happens here, so the op that finds the cooldown
    /// expired *is* the first probe.
    pub fn allows(&mut self, now: u64) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen { .. } => true,
            BreakerState::Open { until } if now >= until => {
                self.state = BreakerState::HalfOpen { successes: 0 };
                true
            }
            BreakerState::Open { .. } => false,
        }
    }

    /// The node answered (including a node-level refusal — it's alive).
    pub fn record_success(&mut self) -> BreakerEvent {
        self.consecutive_failures = 0;
        match self.state {
            BreakerState::HalfOpen { successes } => {
                let successes = successes + 1;
                if successes >= self.cfg.probes {
                    self.state = BreakerState::Closed;
                    BreakerEvent::Closed
                } else {
                    self.state = BreakerState::HalfOpen { successes };
                    BreakerEvent::None
                }
            }
            _ => BreakerEvent::None,
        }
    }

    /// The node was unreachable (crashed, or transient retries
    /// exhausted).
    pub fn record_failure(&mut self, now: u64) -> BreakerEvent {
        match self.state {
            BreakerState::Closed => {
                self.consecutive_failures += 1;
                if self.consecutive_failures >= self.cfg.threshold {
                    self.state = BreakerState::Open {
                        until: now + self.cfg.cooldown,
                    };
                    BreakerEvent::Tripped
                } else {
                    BreakerEvent::None
                }
            }
            BreakerState::HalfOpen { .. } => {
                // a failed probe re-arms the full cooldown
                self.consecutive_failures = 0;
                self.state = BreakerState::Open {
                    until: now + self.cfg.cooldown,
                };
                BreakerEvent::Tripped
            }
            BreakerState::Open { .. } => BreakerEvent::None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn health() -> NodeHealth {
        NodeHealth::new(BreakerConfig {
            threshold: 3,
            cooldown: 10,
            probes: 2,
        })
    }

    #[test]
    fn trips_after_threshold_consecutive_failures() {
        let mut h = health();
        assert_eq!(h.record_failure(0), BreakerEvent::None);
        assert_eq!(h.record_failure(1), BreakerEvent::None);
        assert_eq!(h.record_failure(2), BreakerEvent::Tripped);
        assert!(h.is_open());
        assert!(!h.allows(3), "open: ops fast-fail");
        assert!(!h.allows(11), "cooldown counted from the tripping tick");
    }

    #[test]
    fn success_resets_the_failure_streak() {
        let mut h = health();
        h.record_failure(0);
        h.record_failure(1);
        assert_eq!(h.record_success(), BreakerEvent::None);
        h.record_failure(2);
        assert_eq!(h.record_failure(3), BreakerEvent::None, "streak restarted");
        assert_eq!(h.record_failure(4), BreakerEvent::Tripped);
    }

    #[test]
    fn half_open_probes_close_or_retrip() {
        let mut h = health();
        for t in 0..3 {
            h.record_failure(t);
        }
        assert!(h.allows(12), "cooldown expired → probe allowed");
        assert_eq!(h.state(), BreakerState::HalfOpen { successes: 0 });
        assert_eq!(h.record_success(), BreakerEvent::None, "1 of 2 probes");
        assert_eq!(h.record_success(), BreakerEvent::Closed, "2 of 2 → closed");
        assert_eq!(h.state(), BreakerState::Closed);

        // trip again; this time the probe fails → straight back to open
        for t in 20..23 {
            h.record_failure(t);
        }
        assert!(h.allows(40));
        assert_eq!(h.record_failure(40), BreakerEvent::Tripped);
        assert!(!h.allows(45));
        assert!(h.allows(50), "re-armed cooldown from the probe failure");
    }
}

//! Consistent-hash ring with virtual nodes.
//!
//! Standard Cassandra-style token ring: each physical node owns
//! `vnodes` tokens placed by hashing `(node_id, vnode_index)`; a key
//! routes to the first token clockwise from `mix64(key)`, and the next
//! RF-1 *distinct* nodes clockwise are its replicas.

use crate::filter::fingerprint::mix64;

/// Token ring over physical node ids.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// (token, node_id), sorted by token.
    tokens: Vec<(u64, usize)>,
    nodes: usize,
}

impl HashRing {
    pub fn new(nodes: usize, vnodes: usize) -> Self {
        assert!(nodes > 0 && vnodes > 0);
        let mut tokens = Vec::with_capacity(nodes * vnodes);
        for n in 0..nodes {
            for v in 0..vnodes {
                let token = mix64(((n as u64) << 32) | v as u64 ^ 0x51A7_ED00);
                tokens.push((token, n));
            }
        }
        tokens.sort_unstable();
        tokens.dedup_by_key(|t| t.0);
        Self { tokens, nodes }
    }

    pub fn node_count(&self) -> usize {
        self.nodes
    }

    /// The sorted `(token, node_id)` table — rebalance diagnostics
    /// (proptest P18 verifies minimal movement against it).
    pub fn tokens(&self) -> &[(u64, usize)] {
        &self.tokens
    }

    /// Primary owner of a key.
    pub fn primary(&self, key: u64) -> usize {
        self.walk(key).next().unwrap()
    }

    /// The first `rf` *distinct* nodes clockwise from the key's token.
    pub fn replicas(&self, key: u64, rf: usize) -> Vec<usize> {
        let rf = rf.min(self.nodes);
        let mut out = Vec::with_capacity(rf);
        for n in self.walk(key) {
            if !out.contains(&n) {
                out.push(n);
                if out.len() == rf {
                    break;
                }
            }
        }
        out
    }

    /// Clockwise node walk starting at the key's token.
    fn walk(&self, key: u64) -> impl Iterator<Item = usize> + '_ {
        let h = mix64(key);
        let start = self.tokens.partition_point(|&(t, _)| t < h);
        (0..self.tokens.len()).map(move |i| self.tokens[(start + i) % self.tokens.len()].1)
    }

    /// Fraction of a large key sample owned by each node (balance
    /// diagnostic).
    pub fn ownership(&self, sample: u64) -> Vec<f64> {
        let mut counts = vec![0u64; self.nodes];
        for k in 0..sample {
            counts[self.primary(k)] += 1;
        }
        counts
            .into_iter()
            .map(|c| c as f64 / sample as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_stable() {
        let ring = HashRing::new(5, 64);
        for k in 0..1000u64 {
            assert_eq!(ring.primary(k), ring.primary(k));
        }
    }

    #[test]
    fn ownership_roughly_balanced() {
        let ring = HashRing::new(4, 128);
        let shares = ring.ownership(40_000);
        for (n, s) in shares.iter().enumerate() {
            assert!(
                (0.15..0.35).contains(s),
                "node {n} owns {s} (expect ~0.25)"
            );
        }
    }

    #[test]
    fn replicas_distinct_and_sized() {
        let ring = HashRing::new(5, 32);
        for k in 0..500u64 {
            let r = ring.replicas(k, 3);
            assert_eq!(r.len(), 3);
            let set: std::collections::HashSet<_> = r.iter().collect();
            assert_eq!(set.len(), 3, "replicas must be distinct nodes");
            assert_eq!(r[0], ring.primary(k), "first replica is the primary");
        }
    }

    #[test]
    fn rf_capped_at_cluster_size() {
        let ring = HashRing::new(2, 16);
        assert_eq!(ring.replicas(1, 5).len(), 2);
    }

    #[test]
    fn single_node_ring() {
        let ring = HashRing::new(1, 8);
        for k in 0..100u64 {
            assert_eq!(ring.primary(k), 0);
        }
    }

    #[test]
    fn more_vnodes_improve_balance() {
        let coarse = HashRing::new(4, 2).ownership(20_000);
        let fine = HashRing::new(4, 256).ownership(20_000);
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max)
                - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(
            spread(&fine) < spread(&coarse),
            "fine {fine:?} vs coarse {coarse:?}"
        );
    }
}

//! Consistent-hash ring with virtual nodes.
//!
//! Standard Cassandra-style token ring: each physical node owns
//! `vnodes` tokens placed by hashing `(node_id, vnode_index)`; a key
//! routes to the first token clockwise from `mix64(key)`, and the next
//! RF-1 *distinct* nodes clockwise are its replicas.
//!
//! Node ids are **stable**: the ring tracks an explicit member list,
//! so [`HashRing::add_node`] / [`HashRing::remove_node`] change which
//! ids own tokens without renumbering anyone — the property the live
//! membership protocol (`transfer.rs`) depends on, and the one P18
//! pins: growing `new(n)` by `add_node(n)` is bit-identical to a fresh
//! `new(n + 1)` build, because every token is a pure function of
//! `(node_id, vnode_index)`.

use crate::filter::fingerprint::mix64;

/// Token placement for one `(node, vnode)` pair. The XOR constant
/// perturbs the *combined* id — it used to sit inside the `|` due to
/// operator precedence (`^` binds tighter), silently perturbing only
/// the vnode half; `ring_tokens_pin_exact_layout` pins the intended
/// layout so it cannot regress either way again.
fn token_for(node: usize, vnode: usize) -> u64 {
    mix64((((node as u64) << 32) | vnode as u64) ^ 0x51A7_ED00)
}

/// Token ring over physical node ids.
#[derive(Debug, Clone, PartialEq)]
pub struct HashRing {
    /// (token, node_id), sorted by token.
    tokens: Vec<(u64, usize)>,
    /// Active node ids, sorted. Ids are stable across joins/leaves;
    /// they index the cluster's proxy/hint/health tables directly.
    members: Vec<usize>,
    vnodes: usize,
}

impl HashRing {
    pub fn new(nodes: usize, vnodes: usize) -> Self {
        let members: Vec<usize> = (0..nodes).collect();
        Self::with_members(&members, vnodes)
    }

    /// Build a ring over an explicit member-id set (stable-id joins and
    /// leaves rebuild through here, so incremental and fresh builds
    /// can never drift apart).
    pub fn with_members(members: &[usize], vnodes: usize) -> Self {
        assert!(!members.is_empty() && vnodes > 0);
        let mut members = members.to_vec();
        members.sort_unstable();
        members.dedup();
        let mut tokens = Vec::with_capacity(members.len() * vnodes);
        for &n in &members {
            for v in 0..vnodes {
                tokens.push((token_for(n, v), n));
            }
        }
        tokens.sort_unstable();
        // token collisions across nodes resolve to the smallest node id
        // (sort order of the (token, id) pair), deterministically
        tokens.dedup_by_key(|t| t.0);
        Self {
            tokens,
            members,
            vnodes,
        }
    }

    /// Add a member id to the ring. Other nodes' tokens are untouched,
    /// so only keys the new node captures move (P18).
    pub fn add_node(&mut self, id: usize) {
        assert!(
            !self.members.contains(&id),
            "node {id} is already a ring member"
        );
        let mut members = self.members.clone();
        members.push(id);
        *self = Self::with_members(&members, self.vnodes);
    }

    /// Remove a member id from the ring; its arcs fall to the next
    /// node clockwise.
    pub fn remove_node(&mut self, id: usize) {
        assert!(
            self.members.contains(&id),
            "node {id} is not a ring member"
        );
        assert!(self.members.len() > 1, "cannot empty the ring");
        let members: Vec<usize> = self.members.iter().copied().filter(|&m| m != id).collect();
        *self = Self::with_members(&members, self.vnodes);
    }

    pub fn node_count(&self) -> usize {
        self.members.len()
    }

    /// Active member ids, sorted.
    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub fn contains(&self, id: usize) -> bool {
        self.members.contains(&id)
    }

    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// The sorted `(token, node_id)` table — rebalance diagnostics
    /// (proptest P18 verifies minimal movement against it).
    pub fn tokens(&self) -> &[(u64, usize)] {
        &self.tokens
    }

    /// Primary owner of a key.
    pub fn primary(&self, key: u64) -> usize {
        self.walk(mix64(key)).next().unwrap()
    }

    /// The first `rf` *distinct* nodes clockwise from the key's token.
    pub fn replicas(&self, key: u64, rf: usize) -> Vec<usize> {
        self.replicas_at(mix64(key), rf)
    }

    /// Replica walk from a raw ring position (already-mixed token).
    /// The membership planner uses this to compute the replica set of
    /// a whole token arc at once: every key hashing into the arc walks
    /// from the same ring slot, so one call covers the arc.
    pub fn replicas_at(&self, token: u64, rf: usize) -> Vec<usize> {
        let rf = rf.min(self.members.len());
        let mut out = Vec::with_capacity(rf);
        for n in self.walk(token) {
            if !out.contains(&n) {
                out.push(n);
                if out.len() == rf {
                    break;
                }
            }
        }
        out
    }

    /// Clockwise node walk starting at ring position `token`.
    fn walk(&self, token: u64) -> impl Iterator<Item = usize> + '_ {
        let start = self.tokens.partition_point(|&(t, _)| t < token);
        (0..self.tokens.len()).map(move |i| self.tokens[(start + i) % self.tokens.len()].1)
    }

    /// Fraction of a large key sample owned by each *member* (balance
    /// diagnostic), in member order.
    pub fn ownership(&self, sample: u64) -> Vec<f64> {
        let max_id = *self.members.last().unwrap();
        let mut counts = vec![0u64; max_id + 1];
        for k in 0..sample {
            counts[self.primary(k)] += 1;
        }
        self.members
            .iter()
            .map(|&n| counts[n] as f64 / sample as f64)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_stable() {
        let ring = HashRing::new(5, 64);
        for k in 0..1000u64 {
            assert_eq!(ring.primary(k), ring.primary(k));
        }
    }

    #[test]
    fn ownership_roughly_balanced() {
        let ring = HashRing::new(4, 128);
        let shares = ring.ownership(40_000);
        for (n, s) in shares.iter().enumerate() {
            assert!(
                (0.15..0.35).contains(s),
                "node {n} owns {s} (expect ~0.25)"
            );
        }
    }

    #[test]
    fn replicas_distinct_and_sized() {
        let ring = HashRing::new(5, 32);
        for k in 0..500u64 {
            let r = ring.replicas(k, 3);
            assert_eq!(r.len(), 3);
            let set: std::collections::HashSet<_> = r.iter().collect();
            assert_eq!(set.len(), 3, "replicas must be distinct nodes");
            assert_eq!(r[0], ring.primary(k), "first replica is the primary");
        }
    }

    #[test]
    fn rf_capped_at_cluster_size() {
        let ring = HashRing::new(2, 16);
        assert_eq!(ring.replicas(1, 5).len(), 2);
    }

    #[test]
    fn single_node_ring() {
        let ring = HashRing::new(1, 8);
        for k in 0..100u64 {
            assert_eq!(ring.primary(k), 0);
        }
    }

    #[test]
    fn more_vnodes_improve_balance() {
        let coarse = HashRing::new(4, 2).ownership(20_000);
        let fine = HashRing::new(4, 256).ownership(20_000);
        let spread = |v: &[f64]| {
            v.iter().cloned().fold(f64::MIN, f64::max)
                - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        assert!(
            spread(&fine) < spread(&coarse),
            "fine {fine:?} vs coarse {coarse:?}"
        );
    }

    /// Pins the token formula: the XOR constant perturbs the combined
    /// `(node << 32) | vnode` id, not just the vnode half, and the same
    /// inputs always produce the same sorted, collision-deduped layout.
    #[test]
    fn ring_tokens_pin_exact_layout() {
        let ring = HashRing::new(3, 16);
        let mut expect: Vec<(u64, usize)> = Vec::new();
        for n in 0..3usize {
            for v in 0..16usize {
                expect.push((
                    mix64((((n as u64) << 32) | v as u64) ^ 0x51A7_ED00),
                    n,
                ));
            }
        }
        expect.sort_unstable();
        expect.dedup_by_key(|t| t.0);
        assert_eq!(ring.tokens(), expect.as_slice());
        // determinism: two builds are bit-identical
        assert_eq!(HashRing::new(3, 16), HashRing::new(3, 16));
        // dedup leaves strictly increasing tokens
        for w in ring.tokens().windows(2) {
            assert!(w[0].0 < w[1].0, "tokens must be strictly increasing");
        }
    }

    #[test]
    fn incremental_add_matches_fresh_build() {
        for n in 1..6usize {
            let mut grown = HashRing::new(n, 32);
            grown.add_node(n);
            assert_eq!(grown, HashRing::new(n + 1, 32), "grow {n} -> {}", n + 1);
        }
    }

    #[test]
    fn remove_undoes_add_and_keeps_ids_stable() {
        let fresh = HashRing::new(4, 32);
        let mut ring = fresh.clone();
        ring.add_node(4);
        assert!(ring.contains(4));
        ring.remove_node(4);
        assert_eq!(ring, fresh);
        // removing a middle id keeps the survivors' ids (and tokens)
        let mut holey = HashRing::new(4, 32);
        holey.remove_node(1);
        assert_eq!(holey.members(), &[0, 2, 3]);
        for &(_, n) in holey.tokens() {
            assert_ne!(n, 1, "removed node must own no tokens");
        }
        // survivors' tokens are exactly their old tokens
        let survivor_tokens: Vec<(u64, usize)> = fresh
            .tokens()
            .iter()
            .copied()
            .filter(|&(_, n)| n != 1)
            .collect();
        assert_eq!(holey.tokens(), survivor_tokens.as_slice());
    }
}

//! Replication configuration and quorum math.

/// Replication settings for a cluster.
#[derive(Debug, Clone, Copy)]
pub struct ReplicationConfig {
    /// Replication factor (copies per key).
    pub rf: usize,
    /// Read consistency level: how many replicas must answer.
    pub read_consistency: Consistency,
    /// Write consistency level.
    pub write_consistency: Consistency,
}

/// Consistency levels (Cassandra-style subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Consistency {
    One,
    Quorum,
    All,
}

impl Consistency {
    /// Number of replicas that must participate for `rf` copies.
    pub fn required(&self, rf: usize) -> usize {
        match self {
            Consistency::One => 1,
            Consistency::Quorum => rf / 2 + 1,
            Consistency::All => rf,
        }
    }

    /// Parse a config-file value (`read_consistency = quorum`).
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "one" => Some(Consistency::One),
            "quorum" => Some(Consistency::Quorum),
            "all" => Some(Consistency::All),
            _ => None,
        }
    }

    /// Canonical config-file spelling (round-trips through [`parse`]).
    ///
    /// [`parse`]: Consistency::parse
    pub fn as_str(&self) -> &'static str {
        match self {
            Consistency::One => "one",
            Consistency::Quorum => "quorum",
            Consistency::All => "all",
        }
    }
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        Self {
            rf: 3,
            read_consistency: Consistency::One,
            write_consistency: Consistency::Quorum,
        }
    }
}

impl ReplicationConfig {
    pub fn none() -> Self {
        Self {
            rf: 1,
            read_consistency: Consistency::One,
            write_consistency: Consistency::One,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quorum_math() {
        assert_eq!(Consistency::Quorum.required(3), 2);
        assert_eq!(Consistency::Quorum.required(5), 3);
        assert_eq!(Consistency::Quorum.required(1), 1);
        assert_eq!(Consistency::One.required(3), 1);
        assert_eq!(Consistency::All.required(3), 3);
    }

    #[test]
    fn parse_round_trips() {
        for c in [Consistency::One, Consistency::Quorum, Consistency::All] {
            assert_eq!(Consistency::parse(c.as_str()), Some(c));
        }
        assert_eq!(Consistency::parse(" Quorum "), Some(Consistency::Quorum));
        assert_eq!(Consistency::parse("two"), None);
    }

    #[test]
    fn defaults_sane() {
        let c = ReplicationConfig::default();
        assert_eq!(c.rf, 3);
        assert_eq!(c.write_consistency.required(c.rf), 2);
        let n = ReplicationConfig::none();
        assert_eq!(n.rf, 1);
    }
}

//! Query coordinator: the paper's §I.B cartesian-product workload.
//!
//! > Consider sets T, U & V stored in different nodes in a data-center.
//! > We need to find T×U = {(t,u) | t ∈ T ∧ u ∈ U} s.t. V_α > u …
//! > This query will first create a set of size s = |T|·|U|, then
//! > trigger s queries in V to filter results in T×U.
//!
//! The coordinator fans the pair-predicate probes out to the node
//! holding V; membership filters on V's node absorb the (huge) fraction
//! of probes whose key is absent. [`QueryStats`] exposes per-node
//! lookup counts so experiments reproduce the asymmetry the paper
//! describes ("the number of look-ups on the node containing T is much
//! greater" — in our reconstruction the probe load lands on V's node,
//! which is the observable point either way).

use crate::store::StorageNode;

/// A three-set cartesian filter query.
#[derive(Debug, Clone)]
pub struct CartesianQuery {
    /// Keys of set T (resident on node_t).
    pub t: Vec<u64>,
    /// Keys of set U (resident on node_u).
    pub u: Vec<u64>,
    /// Pair combiner: the probe key derived from (t, u) — the paper's
    /// "V_α > u" predicate reduces to probing V for a derived key.
    pub probe_key: fn(u64, u64) -> u64,
}

impl CartesianQuery {
    /// The default combiner: a mixed pair-hash (order-sensitive).
    pub fn pair_key(t: u64, u: u64) -> u64 {
        crate::filter::mix64(t.rotate_left(32) ^ u)
    }
}

/// Outcome accounting for one coordinated query.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueryStats {
    /// |T| · |U| — probes the query plan generates.
    pub pairs_generated: u64,
    /// Probes that reached V's node storage (filter passed).
    pub v_probes: u64,
    /// Probes answered "absent" by V's node filter alone.
    pub v_filter_pruned: u64,
    /// Matching pairs returned.
    pub matches: u64,
}

/// Coordinator over three nodes (T, U, V).
#[derive(Debug)]
pub struct Coordinator;

impl Coordinator {
    /// Execute the cartesian query: for every (t, u), probe V for the
    /// derived key; count filter prunes vs real probes.
    pub fn execute(query: &CartesianQuery, v_node: &mut StorageNode) -> QueryStats {
        let mut stats = QueryStats::default();
        for &t in &query.t {
            for &u in &query.u {
                stats.pairs_generated += 1;
                let key = (query.probe_key)(t, u);
                let before_sc = v_node.stats.filter_short_circuits();
                let hit = v_node.get(key);
                if v_node.stats.filter_short_circuits() > before_sc {
                    stats.v_filter_pruned += 1;
                } else {
                    stats.v_probes += 1;
                }
                if hit {
                    stats.matches += 1;
                }
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::{FlushPolicy, NodeConfig};

    fn v_node_with(keys: &[u64]) -> StorageNode {
        let mut n = StorageNode::new(NodeConfig {
            flush: FlushPolicy::small(1 << 20),
            ..NodeConfig::default()
        });
        for &k in keys {
            n.put(k).unwrap();
        }
        n
    }

    #[test]
    fn finds_planted_pairs() {
        let t: Vec<u64> = (0..20).collect();
        let u: Vec<u64> = (100..120).collect();
        // plant 5 specific pair keys in V
        let planted: Vec<u64> = [(0, 100), (1, 101), (2, 102), (3, 103), (4, 104)]
            .iter()
            .map(|&(a, b)| CartesianQuery::pair_key(a, b))
            .collect();
        let mut v = v_node_with(&planted);
        let q = CartesianQuery {
            t,
            u,
            probe_key: CartesianQuery::pair_key,
        };
        let stats = Coordinator::execute(&q, &mut v);
        assert_eq!(stats.pairs_generated, 400);
        assert!(stats.matches >= 5, "all planted pairs found: {stats:?}");
        // fp collisions could add a couple, never remove
        assert!(stats.matches < 20, "{stats:?}");
    }

    #[test]
    fn filter_prunes_most_absent_pairs() {
        let t: Vec<u64> = (0..50).collect();
        let u: Vec<u64> = (0..50).collect();
        let mut v = v_node_with(&(0..100u64).collect::<Vec<_>>()); // unrelated keys
        let q = CartesianQuery {
            t,
            u,
            probe_key: CartesianQuery::pair_key,
        };
        let stats = Coordinator::execute(&q, &mut v);
        assert_eq!(stats.pairs_generated, 2500);
        assert!(
            stats.v_filter_pruned > 2400,
            "filter must absorb nearly all probes: {stats:?}"
        );
    }

    #[test]
    fn empty_sets_generate_nothing() {
        let mut v = v_node_with(&[1, 2, 3]);
        let q = CartesianQuery {
            t: vec![],
            u: vec![1, 2],
            probe_key: CartesianQuery::pair_key,
        };
        let stats = Coordinator::execute(&q, &mut v);
        assert_eq!(stats, QueryStats::default());
    }
}

//! The distributed layer: consistent-hash ring, request router,
//! replication, fault handling, and the query coordinator for the
//! paper's §I.B cartesian-product workload.
//!
//! The "data-center" is simulated in-process: N
//! [`StorageNode`](crate::store::StorageNode)s behind a [`Cluster`]
//! router, with per-node op accounting so experiments can report
//! the fan-out asymmetries the paper describes ("the number of look-ups
//! on the node containing T is much greater"). Replication is
//! RF-way with filter-first quorum reads.
//!
//! Every replica op crosses the [`ReplicaProxy`] fault seam
//! (`proxy.rs`, the replication-layer sibling of `store::StoreIo`),
//! and the router layers a circuit breaker per node (`health.rs`),
//! bounded retry with jitter, hinted handoff for missed writes
//! (`handoff.rs`), read repair, and typed quorum errors on top. See
//! `README.md` in this directory for the state machines and the
//! failure-mode × consistency-level contract table.

pub mod coordinator;
pub mod handoff;
pub mod health;
pub mod proxy;
pub mod replication;
pub mod ring;
pub mod router;
pub mod transfer;

pub use coordinator::{CartesianQuery, Coordinator, QueryStats};
pub use handoff::{Hint, HintOp, HintQueue};
pub use health::{BreakerConfig, BreakerEvent, BreakerState, NodeHealth};
pub use proxy::{FaultPlane, FaultSchedule, OpCtx, RealProxy, ReplicaError, ReplicaProxy, Verdict};
pub use replication::{Consistency, ReplicationConfig};
pub use ring::HashRing;
pub use router::{Cluster, ClusterError, ClusterStats, ResilienceConfig, RouterStats};
pub use transfer::{MembershipChange, MembershipError, RangeState, RangeTransfer, RingTransition};

//! The distributed layer: consistent-hash ring, request router,
//! replication, and the query coordinator for the paper's §I.B
//! cartesian-product workload.
//!
//! The "data-center" is simulated in-process: N
//! [`StorageNode`](crate::store::StorageNode)s behind a [`Cluster`]
//! router, with per-node op accounting so experiments can report
//! the fan-out asymmetries the paper describes ("the number of look-ups
//! on the node containing T is much greater"). Replication is
//! RF-way with filter-first quorum reads.

pub mod coordinator;
pub mod replication;
pub mod ring;
pub mod router;

pub use coordinator::{CartesianQuery, Coordinator, QueryStats};
pub use replication::{Consistency, ReplicationConfig};
pub use ring::HashRing;
pub use router::{Cluster, RouterStats};

//! The cluster: N storage nodes behind a consistent-hash router.
//!
//! In-process simulation of the data-center the paper targets: each op
//! routes to its replica set; per-node op counts expose the fan-out
//! asymmetries of §I.B. The router is also where the membership-filter
//! economics show up cluster-wide: a read whose replica filter says
//! "absent" never touches that node's SSTables.

use super::replication::ReplicationConfig;
use super::ring::HashRing;
use crate::store::{NodeConfig, StorageNode};
use crate::workload::Op;

/// Router-level counters.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub ops_routed: u64,
    /// Per-node op counts (fan-out visibility).
    pub per_node_ops: Vec<u64>,
}

/// An in-process cluster.
#[derive(Debug)]
pub struct Cluster {
    ring: HashRing,
    nodes: Vec<StorageNode>,
    repl: ReplicationConfig,
    pub stats: RouterStats,
}

impl Cluster {
    /// Build `n` nodes from a config template (node_id/seed are
    /// specialized per node so filters are independent).
    pub fn new(n: usize, vnodes: usize, template: NodeConfig, repl: ReplicationConfig) -> Self {
        let nodes = (0..n)
            .map(|i| {
                let mut cfg = template;
                cfg.node_id = i as u64;
                cfg.filter.seed = template.filter.seed ^ ((i as u64 + 1) << 17);
                StorageNode::new(cfg)
            })
            .collect();
        Self {
            ring: HashRing::new(n, vnodes),
            nodes,
            repl,
            stats: RouterStats {
                ops_routed: 0,
                per_node_ops: vec![0; n],
            },
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, i: usize) -> &StorageNode {
        &self.nodes[i]
    }

    pub fn node_mut(&mut self, i: usize) -> &mut StorageNode {
        &mut self.nodes[i]
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// Write to all RF replicas (the write consistency level governs
    /// how many must succeed; in-process nodes never fail, so this is
    /// an accounting distinction surfaced for experiments).
    pub fn put(&mut self, key: u64) -> Result<(), crate::filter::FilterError> {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        // consistency is computed over the *achievable* replica set —
        // a 1-node cluster with rf=3 has quorum 1, not 2
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0;
        let mut last_err = None;
        for &n in &replicas {
            self.stats.per_node_ops[n] += 1;
            match self.nodes[n].put(key) {
                Ok(()) => ok += 1,
                Err(e) => last_err = Some(e),
            }
        }
        if ok >= need {
            Ok(())
        } else {
            Err(last_err.expect("failed write must carry an error"))
        }
    }

    /// Verified delete across replicas.
    pub fn delete(&mut self, key: u64) -> bool {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        let mut any = false;
        for &n in &replicas {
            self.stats.per_node_ops[n] += 1;
            any |= self.nodes[n].delete(key);
        }
        any
    }

    /// Read at the configured consistency: consult up to `required`
    /// replicas, first positive wins (membership semantics).
    pub fn get(&mut self, key: u64) -> bool {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        let need = self.repl.read_consistency.required(replicas.len());
        for &n in replicas.iter().take(need.max(1)) {
            self.stats.per_node_ops[n] += 1;
            if self.nodes[n].get(key) {
                return true;
            }
        }
        false
    }

    /// Apply a workload op.
    pub fn apply(&mut self, op: Op) -> bool {
        match op {
            Op::Insert(k) => self.put(k).is_ok(),
            Op::Lookup(k) => self.get(k),
            Op::Delete(k) => self.delete(k),
        }
    }

    /// Sum of filter memory across nodes.
    pub fn filter_memory_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.filter_memory_bytes()).sum()
    }

    /// Aggregate flush counts (premature, total).
    pub fn flush_counts(&self) -> (u64, u64) {
        let premature = self.nodes.iter().map(|n| n.stats.flushes_premature).sum();
        let total = self.nodes.iter().map(|n| n.stats.flushes).sum();
        (premature, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::FlushPolicy;

    fn cluster(n: usize, rf: usize) -> Cluster {
        Cluster::new(
            n,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf,
                ..ReplicationConfig::default()
            },
        )
    }

    #[test]
    fn put_get_across_cluster() {
        let mut c = cluster(4, 2);
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k), "{k}");
        }
        assert!(!c.get(999_999));
    }

    #[test]
    fn replication_writes_rf_copies() {
        let mut c = cluster(4, 3);
        c.put(42).unwrap();
        let holders = (0..4).filter(|&i| c.node(i).live_keys() > 0).count();
        assert_eq!(holders, 3, "rf=3 must store 3 copies");
    }

    #[test]
    fn delete_removes_from_all_replicas() {
        let mut c = cluster(3, 3);
        c.put(7).unwrap();
        assert!(c.delete(7));
        assert!(!c.get(7));
        for i in 0..3 {
            assert_eq!(c.node(i).live_keys(), 0);
        }
        assert!(!c.delete(7), "second delete rejected everywhere");
    }

    #[test]
    fn per_node_ops_accumulate() {
        let mut c = cluster(3, 1);
        for k in 0..300u64 {
            c.put(k).unwrap();
        }
        let total: u64 = c.stats.per_node_ops.iter().sum();
        assert_eq!(total, 300, "rf=1 → one node op per put");
        assert!(c.stats.per_node_ops.iter().all(|&x| x > 50), "{:?}", c.stats.per_node_ops);
    }

    #[test]
    fn sharded_filter_cluster_roundtrip() {
        // nodes opt into the concurrent filter front-end via config;
        // routing/replication semantics must be unchanged
        let mut c = Cluster::new(
            3,
            32,
            NodeConfig {
                filter_shards: 4,
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 2,
                ..ReplicationConfig::default()
            },
        );
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k), "{k}");
        }
        assert!(!c.get(999_999));
        assert!(c.delete(42));
        assert!(!c.get(42));
    }

    #[test]
    fn single_node_cluster_degenerates_gracefully() {
        let mut c = cluster(1, 3);
        c.put(1).unwrap();
        assert!(c.get(1));
        assert!(c.delete(1));
    }
}

//! The cluster: N storage nodes behind a consistent-hash router, with
//! real fault handling between them.
//!
//! In-process simulation of the data-center the paper targets: each op
//! routes to its replica set; per-node op counts expose the fan-out
//! asymmetries of §I.B. The router is also where the membership-filter
//! economics show up cluster-wide: a read whose replica filter says
//! "absent" never touches that node's SSTables.
//!
//! Every replica op flows through a [`ReplicaProxy`] — the fault seam
//! (`proxy.rs`) — and the router layers the distributed-systems
//! machinery on top:
//!
//! - **Retry with backoff + jitter** on transient replica errors
//!   (`util::retry_transient_with`, budget = `[cluster] retry_budget`).
//! - **Circuit breaker** per node (`health.rs`): consecutive
//!   unreachable failures open it, ops then fast-fail until a cooldown
//!   of op-ticks expires and half-open probes re-close it.
//! - **Hinted handoff** (`handoff.rs`): a write that misses a down
//!   replica is still acknowledged if `write_consistency.required`
//!   other replicas took it, and the miss is queued as a hint that
//!   replays when the target's breaker closes again.
//! - **Read repair**: verified reads consult `read_consistency.required`
//!   replicas; on disagreement the newest pending hint for the key
//!   decides the truth (so a missed delete can never resurrect), the
//!   divergent replicas are rewritten, and the repair is counted.
//! - **Typed degraded-mode errors**: when consistency is unachievable
//!   the caller gets [`ClusterError::QuorumLost`] — never a silently
//!   wrong answer.
//!
//! False-positive feedback is **per replica**: when a replica's read
//! reaches its tables and misses, [`StorageNode::get`]/`get_batch`
//! report the FP to that replica's *own* filter
//! ([`crate::filter::FilterFeedback`]) inside the node read path —
//! node filters are independently seeded, so an FP on one replica says
//! nothing about the others and the router adds no extra mechanism.
//!
//! Time is the deterministic **op clock**: each client op advances it
//! by one tick, fault schedules and breaker cooldowns are expressed in
//! ticks, and nothing reads wall time — the chaos sweep
//! (`testutil::chaos`) replays bit-identically from a seed (P18).

use std::fmt;
use std::io;
use std::sync::Arc;

use super::handoff::{HintOp, HintQueue};
use super::health::{BreakerConfig, BreakerEvent, NodeHealth};
use super::proxy::{FaultPlane, OpCtx, RealProxy, ReplicaError, ReplicaProxy};
use super::replication::ReplicationConfig;
use super::ring::HashRing;
use crate::filter::FilterError;
use crate::store::{NodeConfig, StorageNode};
use crate::util::{retry_transient_with, rng::GOLDEN_GAMMA};
use crate::workload::Op;

/// Why a cluster op could not be served at its consistency level.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// Too few replicas were reachable: `got` of the `need` required
    /// acknowledgements arrived. The op may have partially applied;
    /// hints cover the missed replicas.
    QuorumLost { need: usize, got: usize },
    /// Enough replicas were reachable but they refused the op
    /// (filter saturated, node degraded read-only).
    Node(FilterError),
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::QuorumLost { need, got } => {
                write!(f, "quorum lost: needed {need} replicas, reached {got}")
            }
            ClusterError::Node(e) => write!(f, "replicas refused: {e}"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Fault-handling knobs (`[cluster]` config keys).
#[derive(Debug, Clone, Copy)]
pub struct ResilienceConfig {
    /// Transient-error retries per replica op (`retry_budget`).
    pub retry_budget: u32,
    /// Synthetic latency above this is a timeout (`timeout_us`).
    pub timeout_us: u64,
    /// Circuit-breaker thresholds (`breaker_*`).
    pub breaker: BreakerConfig,
    /// Max queued hints per target node (`handoff_capacity`).
    pub handoff_capacity: usize,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            retry_budget: 3,
            timeout_us: 2_000,
            breaker: BreakerConfig::default(),
            handoff_capacity: 4_096,
        }
    }
}

/// Router-level counters: routing fan-out plus the full fault-handling
/// story (retries absorbed, breaker trips, hint life cycle, repairs,
/// quorum losses). All deterministic under a seeded fault plane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClusterStats {
    pub ops_routed: u64,
    /// Per-node op counts (fan-out visibility).
    pub per_node_ops: Vec<u64>,
    /// Transient replica failures absorbed by retry.
    pub retries: u64,
    /// Breaker transitions into open.
    pub breaker_trips: u64,
    /// Hints queued for down replicas.
    pub hints_queued: u64,
    /// Hints successfully replayed onto recovered replicas.
    pub hints_replayed: u64,
    /// Hints lost (queue full, or target refused on replay) — the
    /// no-lost-writes contract only holds while this is zero.
    pub hints_dropped: u64,
    /// Hints made obsolete by a newer direct op landing on the target.
    pub hints_superseded: u64,
    /// Divergent replicas rewritten by read repair.
    pub read_repairs: u64,
    /// Ops that failed with [`ClusterError::QuorumLost`] or a replica
    /// refusal.
    pub quorum_losses: u64,
}

/// Former name of [`ClusterStats`], kept for call sites that predate
/// the fault-handling counters.
pub type RouterStats = ClusterStats;

/// An in-process cluster.
#[derive(Debug)]
pub struct Cluster {
    ring: HashRing,
    proxies: Vec<ReplicaProxy>,
    repl: ReplicationConfig,
    resilience: ResilienceConfig,
    health: Vec<NodeHealth>,
    hints: Vec<HintQueue>,
    clock: u64,
    /// Nodes whose breaker just closed; their hint queues replay at
    /// the end of the current client op (never recursively inside it).
    replay_due: Vec<usize>,
    pub stats: ClusterStats,
}

impl Cluster {
    /// Build `n` production nodes (always-healthy [`RealProxy`] planes,
    /// default resilience) from a config template — node_id/seed are
    /// specialized per node so filters are independent.
    pub fn new(n: usize, vnodes: usize, template: NodeConfig, repl: ReplicationConfig) -> Self {
        let planes: Vec<Arc<dyn FaultPlane>> = (0..n)
            .map(|_| Arc::new(RealProxy) as Arc<dyn FaultPlane>)
            .collect();
        Self::with_fault_planes(n, vnodes, template, repl, ResilienceConfig::default(), planes)
    }

    /// [`Cluster::new`] with an explicit fault plane per node and
    /// tuned resilience — the chaos-sweep entry point.
    pub fn with_fault_planes(
        n: usize,
        vnodes: usize,
        template: NodeConfig,
        repl: ReplicationConfig,
        resilience: ResilienceConfig,
        planes: Vec<Arc<dyn FaultPlane>>,
    ) -> Self {
        assert_eq!(planes.len(), n, "one fault plane per node");
        let proxies = planes
            .into_iter()
            .enumerate()
            .map(|(i, plane)| {
                let mut cfg = template.clone();
                cfg.node_id = i as u64;
                cfg.filter.ocf.seed = template.filter.ocf.seed ^ ((i as u64 + 1) << 17);
                ReplicaProxy::with_plane(StorageNode::new(cfg), plane)
            })
            .collect();
        Self {
            ring: HashRing::new(n, vnodes),
            proxies,
            repl,
            resilience,
            health: (0..n).map(|_| NodeHealth::new(resilience.breaker)).collect(),
            hints: (0..n)
                .map(|_| HintQueue::new(resilience.handoff_capacity))
                .collect(),
            clock: 0,
            replay_due: Vec::new(),
            stats: ClusterStats {
                per_node_ops: vec![0; n],
                ..ClusterStats::default()
            },
        }
    }

    pub fn node_count(&self) -> usize {
        self.proxies.len()
    }

    pub fn node(&self, i: usize) -> &StorageNode {
        self.proxies[i].node()
    }

    pub fn node_mut(&mut self, i: usize) -> &mut StorageNode {
        self.proxies[i].node_mut()
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    pub fn replication(&self) -> ReplicationConfig {
        self.repl
    }

    pub fn resilience(&self) -> ResilienceConfig {
        self.resilience
    }

    /// Current op-clock tick.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Advance the op clock without routing ops — lets harnesses age
    /// out fault windows and breaker cooldowns deterministically.
    pub fn advance_clock(&mut self, ticks: u64) {
        self.clock += ticks;
    }

    /// Is node `i`'s breaker currently open?
    pub fn breaker_open(&self, i: usize) -> bool {
        self.health[i].is_open()
    }

    /// Total hints still queued across all nodes.
    pub fn hints_pending(&self) -> usize {
        self.hints.iter().map(|q| q.len()).sum()
    }

    /// Synthetic latency absorbed from latent fault windows, summed
    /// across replicas (µs) — the E15 latency signal.
    pub fn synthetic_latency_us(&self) -> u64 {
        self.proxies.iter().map(|p| p.synthetic_latency_us()).sum()
    }

    /// Latent ops that exceeded the timeout, summed across replicas.
    pub fn timeouts(&self) -> u64 {
        self.proxies.iter().map(|p| p.timeouts()).sum()
    }

    fn tick(&mut self) -> u64 {
        let t = self.clock;
        self.clock += 1;
        t
    }

    fn queue_hint(&mut self, n: usize, seq: u64, op: HintOp) {
        if self.hints[n].push(seq, op) {
            self.stats.hints_queued += 1;
        } else {
            self.stats.hints_dropped += 1;
        }
    }

    /// One replica sub-op: breaker gate, bounded retry with seeded
    /// jitter, health bookkeeping. `weight` is how many client ops
    /// this call carries (batch group size; repairs pass 0) — charged
    /// to `per_node_ops` only when the node actually answered, so
    /// batched and scalar accounting stay identical in production.
    fn replica_call<T>(
        &mut self,
        n: usize,
        weight: u64,
        mut op: impl FnMut(&mut ReplicaProxy, &OpCtx) -> Result<T, ReplicaError>,
    ) -> Result<T, ReplicaError> {
        let clock = self.clock;
        if !self.health[n].allows(clock) {
            return Err(ReplicaError::Down); // fast-fail, no retry burn
        }
        let budget = self.resilience.retry_budget;
        let timeout_us = self.resilience.timeout_us;
        // per-(node, tick) jitter stream: replicas retrying the same
        // fault window don't sleep in lockstep, yet replays are exact
        let jitter_seed = (n as u64 + 1).wrapping_mul(GOLDEN_GAMMA).wrapping_add(clock);
        let proxy = &mut self.proxies[n];
        let retried = retry_transient_with(budget, jitter_seed, |attempt| {
            let ctx = OpCtx {
                clock,
                attempt,
                timeout_us,
            };
            match op(proxy, &ctx) {
                Ok(v) => Ok(Ok(v)),
                Err(ReplicaError::Transient) => Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    "transient replica fault",
                )),
                // hard failures stop the retry loop immediately
                Err(e) => Ok(Err(e)),
            }
        });
        self.stats.retries += u64::from(retried.retries);
        let outcome: Result<T, ReplicaError> = match retried.result {
            Ok(inner) => inner,
            Err(_) => Err(ReplicaError::Transient), // budget exhausted
        };
        match &outcome {
            // a node-level refusal is still an *answer* — the node is
            // alive, so it must not push the breaker toward open
            Ok(_) | Err(ReplicaError::Node(_)) => {
                self.stats.per_node_ops[n] += weight;
                if self.health[n].record_success() == BreakerEvent::Closed {
                    self.replay_due.push(n);
                }
            }
            Err(_) => {
                if self.health[n].record_failure(clock) == BreakerEvent::Tripped {
                    self.stats.breaker_trips += 1;
                }
            }
        }
        outcome
    }

    /// Replay queues for every node whose breaker just closed. Runs at
    /// the end of the client op (after read resolution — replaying
    /// mid-read could erase the pending hint a resolution depends on).
    fn drain_replay_due(&mut self) {
        while let Some(n) = self.replay_due.pop() {
            self.replay_node(n);
        }
    }

    /// Replay node `n`'s hint queue in FIFO order until it drains or
    /// the node becomes unreachable again.
    fn replay_node(&mut self, n: usize) {
        while let Some(hint) = self.hints[n].front() {
            let res = self.replica_call(n, 0, |p, ctx| match hint.op {
                HintOp::Put(k) => p.put(ctx, k).map(|()| true),
                HintOp::Delete(k) => p.delete(ctx, k),
            });
            match res {
                Ok(_) => {
                    self.hints[n].pop();
                    self.stats.hints_replayed += 1;
                }
                Err(ReplicaError::Node(_)) => {
                    // alive but refusing (saturated/degraded): the hint
                    // can never land — drop it loudly, contract void
                    self.hints[n].pop();
                    self.stats.hints_dropped += 1;
                }
                Err(_) => break, // unreachable again; retry next close
            }
        }
    }

    /// Replay every node's pending hints now (recovery tooling and the
    /// chaos sweep's drain loop). Returns the hints still pending —
    /// zero once all targets are reachable again.
    pub fn replay_hints(&mut self) -> usize {
        for n in 0..self.proxies.len() {
            self.replay_node(n);
        }
        self.drain_replay_due();
        self.hints_pending()
    }

    /// Write to all RF replicas. Acknowledged iff
    /// `write_consistency.required` replicas took it; misses on down
    /// replicas queue hints, misses on refusing replicas surface as
    /// [`ClusterError::Node`].
    pub fn put(&mut self, key: u64) -> Result<(), ClusterError> {
        self.stats.ops_routed += 1;
        let seq = self.tick();
        let replicas = self.ring.replicas(key, self.repl.rf);
        // consistency is computed over the *achievable* replica set —
        // a 1-node cluster with rf=3 has quorum 1, not 2
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0usize;
        let mut reachable = 0usize;
        let mut node_err: Option<FilterError> = None;
        for &n in &replicas {
            match self.replica_call(n, 1, |p, ctx| p.put(ctx, key)) {
                Ok(()) => {
                    ok += 1;
                    reachable += 1;
                    // the node now holds newer state than any pending
                    // hint for this key could replay
                    let s = self.hints[n].supersede(key);
                    self.stats.hints_superseded += s as u64;
                }
                Err(ReplicaError::Node(e)) => {
                    reachable += 1;
                    node_err = Some(e);
                }
                Err(_) => self.queue_hint(n, seq, HintOp::Put(key)),
            }
        }
        self.drain_replay_due();
        if ok >= need {
            Ok(())
        } else {
            self.stats.quorum_losses += 1;
            match node_err {
                // every replica answered yet too few accepted: the
                // cluster is reachable but refusing, not partitioned
                Some(e) if reachable == replicas.len() => Err(ClusterError::Node(e)),
                _ => Err(ClusterError::QuorumLost { need, got: ok }),
            }
        }
    }

    /// Batched write fan-out (the ROADMAP "batched replica writes"
    /// carry-over): every key still reaches all RF replicas, but keys
    /// are grouped by replica node in one pass over the batch and each
    /// node takes a single [`StorageNode::put_batch`] (WAL + memtable
    /// per key, one bulk-hashed filter insert) instead of a call per
    /// key per replica. Per-key results, consistency accounting
    /// (`write_consistency.required` over the achievable replica set),
    /// hinting, and `per_node_ops`/`ops_routed` are identical to a
    /// scalar [`Cluster::put`] loop.
    pub fn put_batch(&mut self, keys: &[u64]) -> Vec<Result<(), ClusterError>> {
        self.stats.ops_routed += keys.len() as u64;
        let base = self.clock;
        self.clock += keys.len() as u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut need: Vec<usize> = Vec::with_capacity(keys.len());
        let mut rf_count = vec![0usize; keys.len()];
        let mut ok = vec![0usize; keys.len()];
        let mut reachable = vec![0usize; keys.len()];
        let mut last_err: Vec<Option<FilterError>> = vec![None; keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let replicas = self.ring.replicas(k, self.repl.rf);
            need.push(self.repl.write_consistency.required(replicas.len()));
            rf_count[i] = replicas.len();
            for &n in &replicas {
                groups[n].push(i);
            }
        }
        let mut gkeys: Vec<u64> = Vec::new();
        for node_id in 0..groups.len() {
            let group = std::mem::take(&mut groups[node_id]);
            if group.is_empty() {
                continue;
            }
            gkeys.clear();
            gkeys.extend(group.iter().map(|&i| keys[i]));
            match self.replica_call(node_id, group.len() as u64, |p, ctx| {
                p.put_batch(ctx, &gkeys)
            }) {
                Ok(results) => {
                    for (&i, r) in group.iter().zip(results) {
                        match r {
                            Ok(()) => {
                                ok[i] += 1;
                                reachable[i] += 1;
                                let s = self.hints[node_id].supersede(keys[i]);
                                self.stats.hints_superseded += s as u64;
                            }
                            Err(e) => {
                                reachable[i] += 1;
                                last_err[i] = Some(e);
                            }
                        }
                    }
                }
                Err(ReplicaError::Node(e)) => {
                    for &i in &group {
                        reachable[i] += 1;
                        last_err[i] = Some(e.clone());
                    }
                }
                Err(_) => {
                    for &i in &group {
                        self.queue_hint(node_id, base + i as u64, HintOp::Put(keys[i]));
                    }
                }
            }
        }
        self.drain_replay_due();
        (0..keys.len())
            .map(|i| {
                if ok[i] >= need[i] {
                    Ok(())
                } else {
                    self.stats.quorum_losses += 1;
                    match &last_err[i] {
                        Some(e) if reachable[i] == rf_count[i] => {
                            Err(ClusterError::Node(e.clone()))
                        }
                        _ => Err(ClusterError::QuorumLost {
                            need: need[i],
                            got: ok[i],
                        }),
                    }
                }
            })
            .collect()
    }

    /// Verified delete across replicas at the write consistency level
    /// (the same accounting as [`Cluster::put`] — a delete is a write).
    /// `Ok(true)` iff some acknowledging replica actually held the key.
    pub fn delete(&mut self, key: u64) -> Result<bool, ClusterError> {
        self.stats.ops_routed += 1;
        let seq = self.tick();
        let replicas = self.ring.replicas(key, self.repl.rf);
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0usize;
        let mut any = false;
        for &n in &replicas {
            match self.replica_call(n, 1, |p, ctx| p.delete(ctx, key)) {
                Ok(was) => {
                    ok += 1;
                    any |= was;
                    let s = self.hints[n].supersede(key);
                    self.stats.hints_superseded += s as u64;
                }
                Err(ReplicaError::Node(_)) => {}
                Err(_) => self.queue_hint(n, seq, HintOp::Delete(key)),
            }
        }
        self.drain_replay_due();
        if ok >= need {
            Ok(any)
        } else {
            self.stats.quorum_losses += 1;
            Err(ClusterError::QuorumLost { need, got: ok })
        }
    }

    /// Batched delete fan-out, replica-grouped exactly like
    /// [`Cluster::put_batch`]: one [`StorageNode::delete_batch`] per
    /// node, per-key consistency accounting and hinting identical to a
    /// scalar [`Cluster::delete`] loop.
    pub fn delete_batch(&mut self, keys: &[u64]) -> Vec<Result<bool, ClusterError>> {
        self.stats.ops_routed += keys.len() as u64;
        let base = self.clock;
        self.clock += keys.len() as u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut need: Vec<usize> = Vec::with_capacity(keys.len());
        let mut ok = vec![0usize; keys.len()];
        let mut any = vec![false; keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let replicas = self.ring.replicas(k, self.repl.rf);
            need.push(self.repl.write_consistency.required(replicas.len()));
            for &n in &replicas {
                groups[n].push(i);
            }
        }
        let mut gkeys: Vec<u64> = Vec::new();
        for node_id in 0..groups.len() {
            let group = std::mem::take(&mut groups[node_id]);
            if group.is_empty() {
                continue;
            }
            gkeys.clear();
            gkeys.extend(group.iter().map(|&i| keys[i]));
            match self.replica_call(node_id, group.len() as u64, |p, ctx| {
                p.delete_batch(ctx, &gkeys)
            }) {
                Ok(results) => {
                    for (&i, was) in group.iter().zip(results) {
                        ok[i] += 1;
                        any[i] |= was;
                        let s = self.hints[node_id].supersede(keys[i]);
                        self.stats.hints_superseded += s as u64;
                    }
                }
                Err(ReplicaError::Node(_)) => {}
                Err(_) => {
                    for &i in &group {
                        self.queue_hint(node_id, base + i as u64, HintOp::Delete(keys[i]));
                    }
                }
            }
        }
        self.drain_replay_due();
        (0..keys.len())
            .map(|i| {
                if ok[i] >= need[i] {
                    Ok(any[i])
                } else {
                    self.stats.quorum_losses += 1;
                    Err(ClusterError::QuorumLost {
                        need: need[i],
                        got: ok[i],
                    })
                }
            })
            .collect()
    }

    /// Read at the configured consistency: walk the replica set in
    /// ring order until `read_consistency.required` replicas answered
    /// (skipping unreachable ones), then resolve — on disagreement the
    /// newest pending hint decides and divergent replicas are
    /// repaired. Fewer answers than required is a typed
    /// [`ClusterError::QuorumLost`], never a silent `false`.
    pub fn get(&mut self, key: u64) -> Result<bool, ClusterError> {
        self.stats.ops_routed += 1;
        self.tick();
        let replicas = self.ring.replicas(key, self.repl.rf);
        let need = self.repl.read_consistency.required(replicas.len()).max(1);
        let mut answers: Vec<(usize, bool)> = Vec::with_capacity(need);
        for &n in &replicas {
            if answers.len() >= need {
                break;
            }
            if let Ok(hit) = self.replica_call(n, 1, |p, ctx| p.get(ctx, key)) {
                answers.push((n, hit));
            }
        }
        let out = if answers.len() < need {
            self.stats.quorum_losses += 1;
            Err(ClusterError::QuorumLost {
                need,
                got: answers.len(),
            })
        } else {
            Ok(self.resolve_read(key, &answers))
        };
        self.drain_replay_due();
        out
    }

    /// Batched read fan-out: keys are grouped by replica and each
    /// node's group is resolved through [`StorageNode::get_batch`] (the
    /// filter-generic batched read path), in consultation "waves" —
    /// wave `w` probes replica `w` of every key still short of its
    /// required answer count, so the answers (and the per-node op
    /// accounting) are identical to a scalar [`Cluster::get`] loop
    /// while each node sees one batched probe per wave instead of a
    /// call per key.
    pub fn get_batch(&mut self, keys: &[u64]) -> Vec<Result<bool, ClusterError>> {
        self.stats.ops_routed += keys.len() as u64;
        self.clock += keys.len() as u64;
        let replica_sets: Vec<Vec<usize>> = keys
            .iter()
            .map(|&k| self.ring.replicas(k, self.repl.rf))
            .collect();
        let needs: Vec<usize> = replica_sets
            .iter()
            .map(|r| self.repl.read_consistency.required(r.len()).max(1))
            .collect();
        let mut answers: Vec<Vec<(usize, bool)>> = vec![Vec::new(); keys.len()];
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut gkeys: Vec<u64> = Vec::new();
        let mut wave = 0usize;
        loop {
            for g in groups.iter_mut() {
                g.clear();
            }
            let mut active = false;
            for i in 0..keys.len() {
                // a key keeps consulting deeper replicas only while it
                // is short of its required answers — under healthy
                // planes that is exactly the first `need` replicas
                if answers[i].len() < needs[i] && wave < replica_sets[i].len() {
                    groups[replica_sets[i][wave]].push(i);
                    active = true;
                }
            }
            if !active {
                break;
            }
            for node_id in 0..groups.len() {
                let group = std::mem::take(&mut groups[node_id]);
                if group.is_empty() {
                    continue;
                }
                gkeys.clear();
                gkeys.extend(group.iter().map(|&i| keys[i]));
                if let Ok(hits) = self.replica_call(node_id, group.len() as u64, |p, ctx| {
                    p.get_batch(ctx, &gkeys)
                }) {
                    for (&i, hit) in group.iter().zip(hits) {
                        answers[i].push((node_id, hit));
                    }
                }
            }
            wave += 1;
        }
        let out: Vec<Result<bool, ClusterError>> = (0..keys.len())
            .map(|i| {
                if answers[i].len() < needs[i] {
                    self.stats.quorum_losses += 1;
                    Err(ClusterError::QuorumLost {
                        need: needs[i],
                        got: answers[i].len(),
                    })
                } else {
                    Ok(self.resolve_read(keys[i], &answers[i]))
                }
            })
            .collect();
        self.drain_replay_due();
        out
    }

    /// Merge one key's replica answers; on disagreement, decide the
    /// truth and repair the replicas that answered wrong.
    ///
    /// The truth rule carries the no-resurrection proof: a divergent
    /// replica missed a write, and every missed write has a pending
    /// hint (or `hints_dropped` says the contract is void) — so the
    /// *newest pending hint* for the key is the write the divergent
    /// replica hasn't seen. A pending `Delete` newer than anything
    /// else means the key is gone, however many stale replicas still
    /// answer `true`. With no pending hint, a positive answer wins:
    /// reads are verified, so some replica provably holds the key.
    fn resolve_read(&mut self, key: u64, answers: &[(usize, bool)]) -> bool {
        let first = answers[0].1;
        if answers.iter().all(|&(_, h)| h == first) {
            return first;
        }
        let latest = self
            .hints
            .iter()
            .filter_map(|q| q.latest_for(key))
            .max_by_key(|h| h.seq);
        let truth = match latest {
            Some(h) => matches!(h.op, HintOp::Put(_)),
            None => true,
        };
        for &(n, hit) in answers {
            if hit == truth {
                continue;
            }
            let repaired = if truth {
                self.replica_call(n, 0, |p, ctx| p.put(ctx, key).map(|()| ()))
            } else {
                self.replica_call(n, 0, |p, ctx| p.delete(ctx, key).map(|_| ()))
            };
            if repaired.is_ok() {
                let s = self.hints[n].supersede(key);
                self.stats.hints_superseded += s as u64;
                self.stats.read_repairs += 1;
            }
        }
        truth
    }

    /// Apply a workload op (availability semantics: a quorum-lost read
    /// reports "absent" here; callers that need the distinction use
    /// the typed APIs).
    pub fn apply(&mut self, op: Op) -> bool {
        match op {
            Op::Insert(k) => self.put(k).is_ok(),
            Op::Lookup(k) => self.get(k).unwrap_or(false),
            Op::Delete(k) => self.delete(k).unwrap_or(false),
        }
    }

    /// Sum of filter memory across nodes.
    pub fn filter_memory_bytes(&self) -> usize {
        self.proxies.iter().map(|p| p.node().filter_memory_bytes()).sum()
    }

    /// Aggregate flush counts (premature, total).
    pub fn flush_counts(&self) -> (u64, u64) {
        let premature = self
            .proxies
            .iter()
            .map(|p| p.node().stats.flushes_premature)
            .sum();
        let total = self.proxies.iter().map(|p| p.node().stats.flushes).sum();
        (premature, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::proxy::Verdict;
    use crate::cluster::replication::Consistency;
    use crate::store::FlushPolicy;

    fn cluster(n: usize, rf: usize) -> Cluster {
        Cluster::new(
            n,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf,
                ..ReplicationConfig::default()
            },
        )
    }

    /// Crashed while `clock < until`, healthy afterwards.
    #[derive(Debug)]
    struct DownUntil(u64);

    impl FaultPlane for DownUntil {
        fn verdict(&self, clock: u64, _attempt: u32) -> Verdict {
            if clock < self.0 {
                Verdict::Crashed
            } else {
                Verdict::Healthy
            }
        }
        fn describe(&self) -> String {
            format!("down until tick {}", self.0)
        }
    }

    /// 3-node rf=3 cluster where node 2 is down until `until`.
    fn cluster_with_down_node(until: u64) -> Cluster {
        let planes: Vec<Arc<dyn FaultPlane>> = vec![
            Arc::new(RealProxy),
            Arc::new(RealProxy),
            Arc::new(DownUntil(until)),
        ];
        Cluster::with_fault_planes(
            3,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 3,
                read_consistency: Consistency::Quorum,
                write_consistency: Consistency::Quorum,
            },
            ResilienceConfig::default(),
            planes,
        )
    }

    #[test]
    fn put_get_across_cluster() {
        let mut c = cluster(4, 2);
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k).unwrap(), "{k}");
        }
        assert!(!c.get(999_999).unwrap());
    }

    #[test]
    fn replication_writes_rf_copies() {
        let mut c = cluster(4, 3);
        c.put(42).unwrap();
        let holders = (0..4).filter(|&i| c.node(i).live_keys() > 0).count();
        assert_eq!(holders, 3, "rf=3 must store 3 copies");
    }

    #[test]
    fn delete_removes_from_all_replicas() {
        let mut c = cluster(3, 3);
        c.put(7).unwrap();
        assert!(c.delete(7).unwrap());
        assert!(!c.get(7).unwrap());
        for i in 0..3 {
            assert_eq!(c.node(i).live_keys(), 0);
        }
        assert!(!c.delete(7).unwrap(), "second delete rejected everywhere");
    }

    #[test]
    fn per_node_ops_accumulate() {
        let mut c = cluster(3, 1);
        for k in 0..300u64 {
            c.put(k).unwrap();
        }
        let total: u64 = c.stats.per_node_ops.iter().sum();
        assert_eq!(total, 300, "rf=1 → one node op per put");
        assert!(c.stats.per_node_ops.iter().all(|&x| x > 50), "{:?}", c.stats.per_node_ops);
    }

    #[test]
    fn sharded_filter_cluster_roundtrip() {
        // nodes opt into the concurrent filter front-end via config;
        // routing/replication semantics must be unchanged
        let mut c = Cluster::new(
            3,
            32,
            NodeConfig {
                filter: crate::filter::FilterBuilder::default().with_shards(4),
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 2,
                ..ReplicationConfig::default()
            },
        );
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k).unwrap(), "{k}");
        }
        assert!(!c.get(999_999).unwrap());
        assert!(c.delete(42).unwrap());
        assert!(!c.get(42).unwrap());
    }

    #[test]
    fn single_node_cluster_degenerates_gracefully() {
        let mut c = cluster(1, 3);
        c.put(1).unwrap();
        assert!(c.get(1).unwrap());
        assert!(c.delete(1).unwrap());
    }

    #[test]
    fn put_batch_matches_scalar_puts() {
        for write_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 3,
                        write_consistency,
                        ..ReplicationConfig::default()
                    },
                )
            };
            let keys: Vec<u64> = (0..2000u64).collect();
            let mut batched_cluster = mk();
            for r in batched_cluster.put_batch(&keys) {
                r.unwrap_or_else(|e| panic!("{write_consistency:?}: {e}"));
            }
            let mut scalar_cluster = mk();
            for &k in &keys {
                scalar_cluster.put(k).unwrap();
            }
            // identical routing accounting, replica for replica
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{write_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            // identical answers and replica placement
            let probes: Vec<u64> = (0..3000u64).collect();
            let batched_answers: Vec<bool> = batched_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let scalar_answers: Vec<bool> = scalar_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(batched_answers, scalar_answers, "{write_consistency:?}");
            for i in 0..4 {
                assert_eq!(
                    batched_cluster.node(i).live_keys(),
                    scalar_cluster.node(i).live_keys(),
                    "{write_consistency:?}: node {i}"
                );
            }
        }
    }

    #[test]
    fn get_batch_matches_scalar_gets() {
        for read_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                let mut c = Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 2,
                        read_consistency,
                        ..ReplicationConfig::default()
                    },
                );
                for k in 0..2000u64 {
                    c.put(k).unwrap();
                }
                c
            };
            let probes: Vec<u64> = (0..3000u64).collect();
            let mut batched_cluster = mk();
            let batched: Vec<bool> = batched_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let mut scalar_cluster = mk();
            let scalar: Vec<bool> = probes
                .iter()
                .map(|&k| scalar_cluster.get(k).unwrap())
                .collect();
            assert_eq!(batched, scalar, "{read_consistency:?}");
            // identical routing accounting, probe for probe
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{read_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            for k in 0..2000u64 {
                assert!(batched[k as usize], "{read_consistency:?}: lost {k}");
            }
        }
    }

    #[test]
    fn delete_batch_matches_scalar_deletes() {
        for write_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                let mut c = Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 3,
                        write_consistency,
                        ..ReplicationConfig::default()
                    },
                );
                for k in 0..1000u64 {
                    c.put(k).unwrap();
                }
                c
            };
            // delete evens plus some never-inserted keys
            let victims: Vec<u64> = (0..1500u64).filter(|k| k % 2 == 0).collect();
            let mut batched_cluster = mk();
            let batched: Vec<bool> = batched_cluster
                .delete_batch(&victims)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let mut scalar_cluster = mk();
            let scalar: Vec<bool> = victims
                .iter()
                .map(|&k| scalar_cluster.delete(k).unwrap())
                .collect();
            assert_eq!(batched, scalar, "{write_consistency:?}");
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{write_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            for i in 0..4 {
                assert_eq!(
                    batched_cluster.node(i).live_keys(),
                    scalar_cluster.node(i).live_keys(),
                    "{write_consistency:?}: node {i}"
                );
            }
            // deleted keys are gone, odd keys survive
            for k in 0..1000u64 {
                assert_eq!(batched_cluster.get(k).unwrap(), k % 2 == 1, "{k}");
            }
        }
    }

    #[test]
    fn down_replica_trips_breaker_and_queues_hints() {
        let mut c = cluster_with_down_node(50);
        for k in 0..30u64 {
            c.put(k).unwrap_or_else(|e| panic!("quorum of 2 healthy replicas must ack: {e}"));
        }
        assert_eq!(c.stats.breaker_trips, 1, "node 2 tripped once");
        assert!(c.breaker_open(2));
        assert_eq!(c.stats.hints_queued, 30, "one hint per missed write");
        assert_eq!(c.hints_pending(), 30);
        assert_eq!(c.node(2).live_keys(), 0, "down node took nothing");
        // reads at quorum never see a false negative meanwhile
        for k in 0..30u64 {
            assert!(c.get(k).unwrap(), "acked write {k} must be readable");
        }
    }

    #[test]
    fn hints_replay_after_recovery_and_drain_to_zero() {
        let mut c = cluster_with_down_node(50);
        for k in 0..30u64 {
            c.put(k).unwrap();
        }
        assert_eq!(c.hints_pending(), 30);
        // recover: past the fault window *and* the breaker cooldown
        let cooldown = c.resilience().breaker.cooldown;
        c.advance_clock(50 + cooldown);
        let pending = c.replay_hints();
        assert_eq!(pending, 0, "hint queues must drain after recovery");
        assert_eq!(c.stats.hints_replayed, 30);
        assert_eq!(c.stats.hints_dropped, 0);
        assert!(!c.breaker_open(2));
        assert_eq!(c.node(2).live_keys(), 30, "replayed writes landed");
    }

    #[test]
    fn breaker_fast_fails_without_retry_burn() {
        let mut c = cluster_with_down_node(1_000_000);
        for k in 0..20u64 {
            c.put(k).unwrap();
        }
        // only the pre-trip calls burned retries; breaker-open ops
        // fast-fail (crashed verdicts are hard errors — no retry —
        // so the retry counter stays at zero here)
        assert_eq!(c.stats.retries, 0);
        assert_eq!(c.stats.breaker_trips, 1);
        assert_eq!(c.hints_pending(), 20, "fast-fail still queues hints");
    }

    #[test]
    fn read_repair_fixes_divergent_replica() {
        let mut c = cluster(3, 3);
        // read at All so every replica is consulted
        c.repl.read_consistency = Consistency::All;
        c.put(7).unwrap();
        // silently diverge node 0 behind the router's back
        let victim = c.ring().replicas(7, 3)[0];
        assert!(c.node_mut(victim).delete(7));
        assert!(c.get(7).unwrap(), "no pending hints → positive answer wins");
        assert_eq!(c.stats.read_repairs, 1);
        assert!(c.node(victim).get(7), "divergent replica rewritten");
        // now all replicas agree again — no further repairs
        assert!(c.get(7).unwrap());
        assert_eq!(c.stats.read_repairs, 1);
    }

    #[test]
    fn pending_delete_hint_wins_read_repair_no_resurrection() {
        let mut c = cluster_with_down_node(50);
        c.repl.read_consistency = Consistency::All;
        // while node 2 is still healthy... it isn't (down from tick 0),
        // so seed node 2 directly: it holds the key, the others will
        // process the delete
        c.node_mut(2).put(99).unwrap();
        c.node_mut(0).put(99).unwrap();
        c.node_mut(1).put(99).unwrap();
        let r = c.delete(99);
        assert!(r.unwrap(), "quorum delete acked");
        assert_eq!(c.hints_pending(), 1, "missed replica got a delete hint");
        // node 2 recovers; the hint has NOT replayed yet. A read-All
        // sees the stale positive — the pending delete hint must win.
        c.advance_clock(50 + c.resilience().breaker.cooldown);
        assert!(!c.get(99).unwrap(), "deleted key must not resurrect");
        assert!(!c.node(2).get(99), "stale replica repaired to absent");
        // drain: the repair superseded the hint (or replay deletes again)
        assert_eq!(c.replay_hints(), 0);
        assert!(!c.get(99).unwrap());
    }

    #[test]
    fn quorum_lost_is_a_typed_error() {
        // both of node 2's peers down forever: rf=3 quorum=2 writes
        // can only ever reach 1 replica
        let planes: Vec<Arc<dyn FaultPlane>> = vec![
            Arc::new(RealProxy),
            Arc::new(DownUntil(u64::MAX)),
            Arc::new(DownUntil(u64::MAX)),
        ];
        let mut c = Cluster::with_fault_planes(
            3,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 3,
                read_consistency: Consistency::Quorum,
                write_consistency: Consistency::Quorum,
            },
            ResilienceConfig::default(),
            planes,
        );
        let mut saw_quorum_lost = false;
        for k in 0..10u64 {
            match c.put(k) {
                Err(ClusterError::QuorumLost { need, got }) => {
                    assert_eq!(need, 2);
                    assert_eq!(got, 1);
                    saw_quorum_lost = true;
                }
                other => panic!("expected QuorumLost, got {other:?}"),
            }
        }
        assert!(saw_quorum_lost);
        assert!(c.stats.quorum_losses >= 10);
        match c.get(0) {
            Err(ClusterError::QuorumLost { need: 2, got: 1 }) => {}
            other => panic!("expected read QuorumLost, got {other:?}"),
        }
    }
}

//! The cluster: N storage nodes behind a consistent-hash router, with
//! real fault handling between them.
//!
//! In-process simulation of the data-center the paper targets: each op
//! routes to its replica set; per-node op counts expose the fan-out
//! asymmetries of §I.B. The router is also where the membership-filter
//! economics show up cluster-wide: a read whose replica filter says
//! "absent" never touches that node's SSTables.
//!
//! Every replica op flows through a [`ReplicaProxy`] — the fault seam
//! (`proxy.rs`) — and the router layers the distributed-systems
//! machinery on top:
//!
//! - **Retry with backoff + jitter** on transient replica errors
//!   (`util::retry_transient_with`, budget = `[cluster] retry_budget`).
//! - **Circuit breaker** per node (`health.rs`): consecutive
//!   unreachable failures open it, ops then fast-fail until a cooldown
//!   of op-ticks expires and half-open probes re-close it.
//! - **Hinted handoff** (`handoff.rs`): a write that misses a down
//!   replica is still acknowledged if `write_consistency.required`
//!   other replicas took it, and the miss is queued as a hint that
//!   replays when the target's breaker closes again.
//! - **Read repair**: verified reads consult `read_consistency.required`
//!   replicas; on disagreement the newest pending hint for the key
//!   decides the truth (so a missed delete can never resurrect), the
//!   divergent replicas are rewritten, and the repair is counted.
//! - **Typed degraded-mode errors**: when consistency is unachievable
//!   the caller gets [`ClusterError::QuorumLost`] — never a silently
//!   wrong answer.
//!
//! False-positive feedback is **per replica**: when a replica's read
//! reaches its tables and misses, [`StorageNode::get`]/`get_batch`
//! report the FP to that replica's *own* filter
//! ([`crate::filter::FilterFeedback`]) inside the node read path —
//! node filters are independently seeded, so an FP on one replica says
//! nothing about the others and the router adds no extra mechanism.
//!
//! - **Live membership** (`transfer.rs`): [`Cluster::add_node`] /
//!   [`Cluster::remove_node`] stream captured ranges to the new owners
//!   through the same proxy seam, dual-applying concurrent writes and
//!   flipping reads per range only once the commit gate proves the
//!   gainers hold every acked write. See [`Cluster::pump_transfers`].
//!
//! Time is the deterministic **op clock**: each client op advances it
//! by one tick, fault schedules and breaker cooldowns are expressed in
//! ticks, and nothing reads wall time — the chaos sweep
//! (`testutil::chaos`) replays bit-identically from a seed (P18).

use std::fmt;
use std::io;
use std::sync::Arc;

use super::handoff::{HintOp, HintQueue};
use super::health::{BreakerConfig, BreakerEvent, NodeHealth};
use super::proxy::{FaultPlane, OpCtx, RealProxy, ReplicaError, ReplicaProxy};
use super::replication::ReplicationConfig;
use super::ring::HashRing;
use super::transfer::{MembershipChange, MembershipError, RangeState, RingTransition};
use crate::filter::fingerprint::mix64;
use crate::filter::FilterError;
use crate::store::{NodeConfig, StorageNode};
use crate::util::{retry_transient_with, rng::GOLDEN_GAMMA};
use crate::workload::Op;

/// Why a cluster op could not be served at its consistency level.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterError {
    /// Too few replicas were reachable: `got` of the `need` required
    /// acknowledgements arrived. The op may have partially applied;
    /// hints cover the missed replicas.
    QuorumLost { need: usize, got: usize },
    /// Enough replicas were reachable but they refused the op
    /// (filter saturated, node degraded read-only).
    Node(FilterError),
}

impl fmt::Display for ClusterError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClusterError::QuorumLost { need, got } => {
                write!(f, "quorum lost: needed {need} replicas, reached {got}")
            }
            ClusterError::Node(e) => write!(f, "replicas refused: {e}"),
        }
    }
}

impl std::error::Error for ClusterError {}

/// Fault-handling knobs (`[cluster]` config keys).
#[derive(Debug, Clone, Copy)]
pub struct ResilienceConfig {
    /// Transient-error retries per replica op (`retry_budget`).
    pub retry_budget: u32,
    /// Synthetic latency above this is a timeout (`timeout_us`).
    pub timeout_us: u64,
    /// Circuit-breaker thresholds (`breaker_*`).
    pub breaker: BreakerConfig,
    /// Max queued hints per target node (`handoff_capacity`).
    pub handoff_capacity: usize,
    /// Keys streamed per membership-transfer pump (`transfer_batch`) —
    /// bounds how much range-handoff work piggybacks on one client op.
    pub transfer_batch: usize,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            retry_budget: 3,
            timeout_us: 2_000,
            breaker: BreakerConfig::default(),
            handoff_capacity: 4_096,
            transfer_batch: 64,
        }
    }
}

/// Router-level counters: routing fan-out plus the full fault-handling
/// story (retries absorbed, breaker trips, hint life cycle, repairs,
/// quorum losses). All deterministic under a seeded fault plane.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClusterStats {
    pub ops_routed: u64,
    /// Per-node op counts (fan-out visibility).
    pub per_node_ops: Vec<u64>,
    /// Transient replica failures absorbed by retry.
    pub retries: u64,
    /// Breaker transitions into open.
    pub breaker_trips: u64,
    /// Hints queued for down replicas.
    pub hints_queued: u64,
    /// Hints successfully replayed onto recovered replicas.
    pub hints_replayed: u64,
    /// Hints lost (queue full, or target refused on replay) — the
    /// no-lost-writes contract only holds while this is zero.
    pub hints_dropped: u64,
    /// Hints made obsolete by a newer direct op landing on the target.
    pub hints_superseded: u64,
    /// Divergent replicas rewritten by read repair.
    pub read_repairs: u64,
    /// Ops that failed with [`ClusterError::QuorumLost`] or a replica
    /// refusal.
    pub quorum_losses: u64,
    /// Membership transitions begun (`add_node` / `remove_node`).
    pub transfers_started: u64,
    /// Membership transitions fully handed off.
    pub transfers_completed: u64,
    /// Transfer pumps that hit an unreachable donor or gainer and will
    /// retry the same position later.
    pub transfers_retried: u64,
    /// Distinct keys enumerated from donors during transfers (the
    /// conservation-law numerator).
    pub keys_captured: u64,
    /// Captured keys that reached a gainer via the stream.
    pub keys_streamed: u64,
    /// Captured keys resolved by a newer direct write instead of a
    /// stream copy. At completion
    /// `keys_captured == keys_streamed + keys_superseded` — nothing is
    /// silently dropped (proptest P19).
    pub keys_superseded: u64,
    /// Gauge: captured ranges not yet handed off.
    pub ranges_pending: u64,
    /// Hints retired because their target node left the ring (the new
    /// owners hold the writes; the conservation law counts these).
    pub hints_retired: u64,
}

/// Former name of [`ClusterStats`], kept for call sites that predate
/// the fault-handling counters.
pub type RouterStats = ClusterStats;

/// An in-process cluster.
#[derive(Debug)]
pub struct Cluster {
    ring: HashRing,
    proxies: Vec<ReplicaProxy>,
    repl: ReplicationConfig,
    resilience: ResilienceConfig,
    health: Vec<NodeHealth>,
    hints: Vec<HintQueue>,
    clock: u64,
    /// Nodes whose breaker just closed; their hint queues replay at
    /// the end of the current client op (never recursively inside it).
    replay_due: Vec<usize>,
    /// Config template new members are specialized from (node_id and
    /// filter seed are derived per id, so ids stay stable forever).
    template: NodeConfig,
    /// Ids that left the ring. Slots are never reused: a retired id
    /// keeps its proxy/health/hint entries (inert) so every other id
    /// still indexes those tables directly.
    retired: Vec<bool>,
    /// The in-flight membership change, if any. One at a time.
    transition: Option<RingTransition>,
    pub stats: ClusterStats,
}

impl Cluster {
    /// Build `n` production nodes (always-healthy [`RealProxy`] planes,
    /// default resilience) from a config template — node_id/seed are
    /// specialized per node so filters are independent.
    pub fn new(n: usize, vnodes: usize, template: NodeConfig, repl: ReplicationConfig) -> Self {
        let planes: Vec<Arc<dyn FaultPlane>> = (0..n)
            .map(|_| Arc::new(RealProxy) as Arc<dyn FaultPlane>)
            .collect();
        Self::with_fault_planes(n, vnodes, template, repl, ResilienceConfig::default(), planes)
    }

    /// [`Cluster::new`] with an explicit fault plane per node and
    /// tuned resilience — the chaos-sweep entry point.
    pub fn with_fault_planes(
        n: usize,
        vnodes: usize,
        template: NodeConfig,
        repl: ReplicationConfig,
        resilience: ResilienceConfig,
        planes: Vec<Arc<dyn FaultPlane>>,
    ) -> Self {
        assert_eq!(planes.len(), n, "one fault plane per node");
        let proxies = planes
            .into_iter()
            .enumerate()
            .map(|(i, plane)| {
                let mut cfg = template.clone();
                cfg.node_id = i as u64;
                cfg.filter.ocf.seed = template.filter.ocf.seed ^ ((i as u64 + 1) << 17);
                ReplicaProxy::with_plane(StorageNode::new(cfg), plane)
            })
            .collect();
        Self {
            ring: HashRing::new(n, vnodes),
            proxies,
            repl,
            resilience,
            health: (0..n).map(|_| NodeHealth::new(resilience.breaker)).collect(),
            hints: (0..n)
                .map(|_| HintQueue::new(resilience.handoff_capacity))
                .collect(),
            clock: 0,
            replay_due: Vec::new(),
            template,
            retired: vec![false; n],
            transition: None,
            stats: ClusterStats {
                per_node_ops: vec![0; n],
                ..ClusterStats::default()
            },
        }
    }

    pub fn node_count(&self) -> usize {
        self.proxies.len()
    }

    pub fn node(&self, i: usize) -> &StorageNode {
        self.proxies[i].node()
    }

    pub fn node_mut(&mut self, i: usize) -> &mut StorageNode {
        self.proxies[i].node_mut()
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    pub fn replication(&self) -> ReplicationConfig {
        self.repl
    }

    pub fn resilience(&self) -> ResilienceConfig {
        self.resilience
    }

    /// Current op-clock tick.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Advance the op clock without routing ops — lets harnesses age
    /// out fault windows and breaker cooldowns deterministically.
    pub fn advance_clock(&mut self, ticks: u64) {
        self.clock += ticks;
    }

    /// Is node `i`'s breaker currently open?
    pub fn breaker_open(&self, i: usize) -> bool {
        self.health[i].is_open()
    }

    /// Total hints still queued across all nodes.
    pub fn hints_pending(&self) -> usize {
        self.hints.iter().map(|q| q.len()).sum()
    }

    /// Is a membership transition still streaming?
    pub fn transfer_active(&self) -> bool {
        self.transition.is_some()
    }

    /// The in-flight membership transition, if any.
    pub fn transition(&self) -> Option<&RingTransition> {
        self.transition.as_ref()
    }

    /// Captured ranges not yet handed off.
    pub fn ranges_pending(&self) -> usize {
        self.transition.as_ref().map_or(0, |t| t.pending())
    }

    /// Has node `i` left the ring? (Its id is never reused.)
    pub fn is_retired(&self, i: usize) -> bool {
        self.retired[i]
    }

    /// Synthetic latency absorbed from latent fault windows, summed
    /// across replicas (µs) — the E15 latency signal.
    pub fn synthetic_latency_us(&self) -> u64 {
        self.proxies.iter().map(|p| p.synthetic_latency_us()).sum()
    }

    /// Latent ops that exceeded the timeout, summed across replicas.
    pub fn timeouts(&self) -> u64 {
        self.proxies.iter().map(|p| p.timeouts()).sum()
    }

    fn tick(&mut self) -> u64 {
        let t = self.clock;
        self.clock += 1;
        t
    }

    fn queue_hint(&mut self, n: usize, seq: u64, op: HintOp) {
        if self.hints[n].push(seq, op) {
            self.stats.hints_queued += 1;
        } else {
            self.stats.hints_dropped += 1;
        }
    }

    /// One replica sub-op: breaker gate, bounded retry with seeded
    /// jitter, health bookkeeping. `weight` is how many client ops
    /// this call carries (batch group size; repairs pass 0) — charged
    /// to `per_node_ops` only when the node actually answered, so
    /// batched and scalar accounting stay identical in production.
    fn replica_call<T>(
        &mut self,
        n: usize,
        weight: u64,
        mut op: impl FnMut(&mut ReplicaProxy, &OpCtx) -> Result<T, ReplicaError>,
    ) -> Result<T, ReplicaError> {
        let clock = self.clock;
        if !self.health[n].allows(clock) {
            return Err(ReplicaError::Down); // fast-fail, no retry burn
        }
        let budget = self.resilience.retry_budget;
        let timeout_us = self.resilience.timeout_us;
        // per-(node, tick) jitter stream: replicas retrying the same
        // fault window don't sleep in lockstep, yet replays are exact
        let jitter_seed = (n as u64 + 1).wrapping_mul(GOLDEN_GAMMA).wrapping_add(clock);
        let proxy = &mut self.proxies[n];
        let retried = retry_transient_with(budget, jitter_seed, |attempt| {
            let ctx = OpCtx {
                clock,
                attempt,
                timeout_us,
            };
            match op(proxy, &ctx) {
                Ok(v) => Ok(Ok(v)),
                Err(ReplicaError::Transient) => Err(io::Error::new(
                    io::ErrorKind::Interrupted,
                    "transient replica fault",
                )),
                // hard failures stop the retry loop immediately
                Err(e) => Ok(Err(e)),
            }
        });
        self.stats.retries += u64::from(retried.retries);
        let outcome: Result<T, ReplicaError> = match retried.result {
            Ok(inner) => inner,
            Err(_) => Err(ReplicaError::Transient), // budget exhausted
        };
        match &outcome {
            // a node-level refusal is still an *answer* — the node is
            // alive, so it must not push the breaker toward open
            Ok(_) | Err(ReplicaError::Node(_)) => {
                self.stats.per_node_ops[n] += weight;
                if self.health[n].record_success() == BreakerEvent::Closed {
                    self.replay_due.push(n);
                }
            }
            Err(_) => {
                if self.health[n].record_failure(clock) == BreakerEvent::Tripped {
                    self.stats.breaker_trips += 1;
                }
            }
        }
        outcome
    }

    /// Replay queues for every node whose breaker just closed. Runs at
    /// the end of the client op (after read resolution — replaying
    /// mid-read could erase the pending hint a resolution depends on).
    fn drain_replay_due(&mut self) {
        while let Some(n) = self.replay_due.pop() {
            self.replay_node(n);
        }
    }

    /// Replay node `n`'s hint queue in FIFO order until it drains or
    /// the node becomes unreachable again.
    fn replay_node(&mut self, n: usize) {
        while let Some(hint) = self.hints[n].front() {
            let res = self.replica_call(n, 0, |p, ctx| match hint.op {
                HintOp::Put(k) => p.put(ctx, k).map(|()| true),
                HintOp::Delete(k) => p.delete(ctx, k),
            });
            match res {
                Ok(_) => {
                    self.hints[n].pop();
                    self.stats.hints_replayed += 1;
                }
                Err(ReplicaError::Node(_)) => {
                    // alive but refusing (saturated/degraded): the hint
                    // can never land — drop it loudly, contract void
                    self.hints[n].pop();
                    self.stats.hints_dropped += 1;
                }
                Err(_) => break, // unreachable again; retry next close
            }
        }
    }

    /// Replay every node's pending hints now (recovery tooling and the
    /// chaos sweep's drain loop). Returns the hints still pending —
    /// zero once all targets are reachable again.
    pub fn replay_hints(&mut self) -> usize {
        for n in 0..self.proxies.len() {
            self.replay_node(n);
        }
        self.drain_replay_due();
        self.hints_pending()
    }

    /// Join a new node (production plane): allocate the next stable id,
    /// plan the ring transition, and start streaming its captured
    /// ranges. Reads keep routing to the old owners until each range's
    /// commit gate proves the joiner holds every acked write.
    pub fn add_node(&mut self) -> Result<usize, MembershipError> {
        self.add_node_with_plane(Arc::new(RealProxy))
    }

    /// [`Cluster::add_node`] with an explicit fault plane — the chaos
    /// harness uses this to kill the joiner mid-transfer.
    pub fn add_node_with_plane(
        &mut self,
        plane: Arc<dyn FaultPlane>,
    ) -> Result<usize, MembershipError> {
        if self.transition.is_some() {
            return Err(MembershipError::TransferInProgress);
        }
        let id = self.proxies.len();
        let mut cfg = self.template.clone();
        cfg.node_id = id as u64;
        cfg.filter.ocf.seed = self.template.filter.ocf.seed ^ ((id as u64 + 1) << 17);
        self.proxies
            .push(ReplicaProxy::with_plane(StorageNode::new(cfg), plane));
        self.health.push(NodeHealth::new(self.resilience.breaker));
        self.hints
            .push(HintQueue::new(self.resilience.handoff_capacity));
        self.stats.per_node_ops.push(0);
        self.retired.push(false);
        let old = self.ring.clone();
        let mut new = old.clone();
        new.add_node(id);
        self.begin_transition(MembershipChange::Join(id), old, new);
        Ok(id)
    }

    /// Decommission node `id`: stream every range it serves to the
    /// successors first, then drop it from the ring. The node keeps
    /// serving reads (and taking writes) for its arcs until each one
    /// commits — removal is the join protocol run in reverse, not a
    /// crash.
    pub fn remove_node(&mut self, id: usize) -> Result<(), MembershipError> {
        if self.transition.is_some() {
            return Err(MembershipError::TransferInProgress);
        }
        if id >= self.proxies.len() || self.retired[id] || !self.ring.contains(id) {
            return Err(MembershipError::UnknownNode(id));
        }
        if self.ring.node_count() <= 1 {
            return Err(MembershipError::LastNode);
        }
        let old = self.ring.clone();
        let mut new = old.clone();
        new.remove_node(id);
        self.begin_transition(MembershipChange::Leave(id), old, new);
        Ok(())
    }

    fn begin_transition(&mut self, change: MembershipChange, old: HashRing, new: HashRing) {
        let tr = RingTransition::plan(change, old, new, self.repl.rf);
        self.stats.transfers_started += 1;
        self.stats.ranges_pending = tr.ranges.len() as u64;
        let empty = tr.ranges.is_empty();
        self.transition = Some(tr);
        if empty {
            // no arc gains a node (e.g. shrinking below RF): the
            // remaining owners already hold every key — flip now
            self.finish_transition();
        }
    }

    /// Every range handed off: install the new ring. A leaver is
    /// marked retired and its pending hints are retired with it (the
    /// commit gates proved the new owners hold those writes).
    fn finish_transition(&mut self) {
        let Some(tr) = self.transition.take() else {
            return;
        };
        self.ring = tr.new;
        if let MembershipChange::Leave(id) = tr.change {
            self.retired[id] = true;
            let retired = self.hints[id].retire_all();
            self.stats.hints_retired += retired as u64;
        }
        self.stats.transfers_completed += 1;
        self.stats.ranges_pending = 0;
    }

    /// Replica set for a key, transfer-aware: a key in a captured
    /// range routes to the old owners until its range commits, then to
    /// the new set; un-captured arcs have identical replica walks in
    /// both rings, so the current ring serves them.
    fn replicas_for(&self, key: u64) -> Vec<usize> {
        if let Some(tr) = &self.transition {
            if let Some(r) = tr.range_for(mix64(key)) {
                return if r.committed() {
                    r.new_replicas.clone()
                } else {
                    r.old_replicas.clone()
                };
            }
        }
        self.ring.replicas(key, self.repl.rf)
    }

    /// While a key's range is still streaming, a client write must
    /// reach the future owners too: apply it to every gainer (weight 0
    /// — the old set carries the consistency accounting), record
    /// success in the range's `overridden` mask so the stream never
    /// clobbers the newer state with a stale donor copy, and hint the
    /// gainer on a miss exactly like any down replica — the commit
    /// gate refuses to flip the range until that hint drains.
    fn dual_apply(&mut self, key: u64, seq: u64, put: bool) {
        let Some(tr) = &self.transition else {
            return;
        };
        let Some(ridx) = tr.range_index(mix64(key)) else {
            return;
        };
        if tr.ranges[ridx].committed() {
            return;
        }
        let gainers = tr.ranges[ridx].gainers.clone();
        for (gi, &g) in gainers.iter().enumerate() {
            let res = if put {
                self.replica_call(g, 0, |p, ctx| p.put(ctx, key))
            } else {
                self.replica_call(g, 0, |p, ctx| p.delete(ctx, key).map(|_| ()))
            };
            match res {
                Ok(()) => {
                    let s = self.hints[g].supersede(key);
                    self.stats.hints_superseded += s as u64;
                    let r = &mut self.transition.as_mut().unwrap().ranges[ridx];
                    *r.overridden.entry(key).or_insert(0) |= 1 << gi;
                }
                Err(_) => {
                    let op = if put { HintOp::Put(key) } else { HintOp::Delete(key) };
                    self.queue_hint(g, seq, op);
                }
            }
        }
    }

    /// Drive the in-flight transfer one bounded step: page the current
    /// donor of the first non-committed range (`transfer_batch` keys),
    /// land each key on the gainers, and try the range's commit gate
    /// once every donor is exhausted. Called automatically after every
    /// client op; harness drain loops call it directly. Returns the
    /// ranges still pending (0 = no transfer, or it just completed).
    pub fn pump_transfers(&mut self) -> usize {
        let Some(tr) = self.transition.as_ref() else {
            return 0;
        };
        let Some(ridx) = tr.ranges.iter().position(|r| !r.committed()) else {
            self.finish_transition();
            return 0;
        };
        let (lo, hi, old_replicas, gainers) = {
            let r = &tr.ranges[ridx];
            (r.lo, r.hi, r.old_replicas.clone(), r.gainers.clone())
        };
        let batch = self.resilience.transfer_batch.max(1);
        let range = &mut self.transition.as_mut().unwrap().ranges[ridx];
        if range.state == RangeState::Pending {
            range.state = RangeState::Streaming;
        }
        let mut donor_idx = range.donor_idx;
        let mut cursor = range.cursor;
        if donor_idx < old_replicas.len() {
            let donor = old_replicas[donor_idx];
            match self.replica_call(donor, 0, |p, ctx| p.stream_page(ctx, lo, hi, cursor, batch)) {
                Ok(page) => {
                    let short_page = page.len() < batch;
                    let mut stalled = false;
                    for key in page {
                        if !self.stream_key(ridx, donor, key, &gainers) {
                            // unreachable donor or gainer mid-key: hold
                            // the cursor here and retry later
                            self.stats.transfers_retried += 1;
                            stalled = true;
                            break;
                        }
                        cursor = Some(key);
                    }
                    if !stalled && short_page {
                        // donor fully enumerated; next donor from the top
                        donor_idx += 1;
                        cursor = None;
                    }
                    let r = &mut self.transition.as_mut().unwrap().ranges[ridx];
                    r.donor_idx = donor_idx;
                    r.cursor = cursor;
                }
                Err(_) => self.stats.transfers_retried += 1,
            }
        }
        if donor_idx >= old_replicas.len() {
            self.try_commit(ridx, &gainers, lo, hi);
        }
        self.drain_replay_due();
        match &self.transition {
            Some(tr) => {
                let pending = tr.pending();
                self.stats.ranges_pending = pending as u64;
                if pending == 0 {
                    self.finish_transition();
                    0
                } else {
                    pending
                }
            }
            None => 0,
        }
    }

    /// Land one enumerated key on every gainer that has neither a
    /// stream copy nor newer dual-applied state. Returns `false` if a
    /// replica call failed — the pump must not advance the cursor past
    /// this key.
    fn stream_key(&mut self, ridx: usize, donor: usize, key: u64, gainers: &[usize]) -> bool {
        {
            let r = &mut self.transition.as_mut().unwrap().ranges[ridx];
            if r.captured.insert(key) {
                self.stats.keys_captured += 1;
            }
            if r.done.contains(&key) {
                return true;
            }
        }
        // the newest pending hint is newer than any donor copy: if it
        // is a delete, every donor still listing the key is stale and
        // streaming it would resurrect — skip, the commit-time sweep
        // accounts for it (same truth rule as read repair)
        let deleted_pending = self
            .hints
            .iter()
            .filter_map(|q| q.latest_for(key))
            .max_by_key(|h| h.seq)
            .is_some_and(|h| matches!(h.op, HintOp::Delete(_)));
        if deleted_pending {
            return true;
        }
        let (mut streamed, overridden, full) = {
            let r = &self.transition.as_ref().unwrap().ranges[ridx];
            (
                r.streamed.get(&key).copied().unwrap_or(0),
                r.overridden.get(&key).copied().unwrap_or(0),
                r.full_mask(),
            )
        };
        // fetched lazily, once, from the donor that enumerated the key
        let mut value: Option<Option<crate::store::Value>> = None;
        let mut failed = false;
        for (gi, &g) in gainers.iter().enumerate() {
            let bit = 1u32 << gi;
            if (streamed | overridden) & bit != 0 {
                continue;
            }
            if value.is_none() {
                match self.replica_call(donor, 0, |p, ctx| p.get_value(ctx, key)) {
                    Ok(v) => value = Some(v),
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
            let Some(Some(v)) = value.clone() else {
                // vanished from this donor across pump retries: a later
                // donor or the commit-time sweep owns it now
                break;
            };
            match self.replica_call(g, 0, |p, ctx| p.put_value(ctx, key, &v)) {
                Ok(()) => streamed |= bit,
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        let r = &mut self.transition.as_mut().unwrap().ranges[ridx];
        if streamed != 0 {
            r.streamed.insert(key, streamed);
        }
        if (streamed | overridden) == full && r.done.insert(key) {
            if streamed != 0 {
                self.stats.keys_streamed += 1;
            } else {
                self.stats.keys_superseded += 1;
            }
        }
        !failed
    }

    /// The commit gate: a range hands off only when every donor has
    /// been fully paged *and* no pending hint against a gainer names a
    /// key in the arc. At that point the gainers provably hold every
    /// acked write for the range — streamed, dual-applied, or
    /// hint-replayed — so flipping reads to the new replica set
    /// preserves the quorum-overlap argument across the flip.
    fn try_commit(&mut self, ridx: usize, gainers: &[usize], lo: u64, hi: u64) {
        // give the gainers' queues one replay chance right now
        for &g in gainers {
            self.replay_node(g);
        }
        let in_arc = |token: u64| {
            if lo < hi {
                lo < token && token <= hi
            } else if lo > hi {
                token > lo || token <= hi
            } else {
                true
            }
        };
        let blocked = gainers
            .iter()
            .any(|&g| self.hints[g].iter().any(|h| in_arc(mix64(h.op.key()))));
        if blocked {
            return;
        }
        let r = &mut self.transition.as_mut().unwrap().ranges[ridx];
        // keys enumerated once but resolved by newer direct writes
        // (deleted mid-transfer, or landed on the gainers via
        // dual-apply/hint replay) — never silently dropped
        let leftovers: Vec<u64> = r.captured.difference(&r.done).copied().collect();
        for k in leftovers {
            r.done.insert(k);
            self.stats.keys_superseded += 1;
        }
        r.state = RangeState::HandedOff;
    }

    /// Write to all RF replicas. Acknowledged iff
    /// `write_consistency.required` replicas took it; misses on down
    /// replicas queue hints, misses on refusing replicas surface as
    /// [`ClusterError::Node`].
    pub fn put(&mut self, key: u64) -> Result<(), ClusterError> {
        self.stats.ops_routed += 1;
        let seq = self.tick();
        let replicas = self.replicas_for(key);
        // consistency is computed over the *achievable* replica set —
        // a 1-node cluster with rf=3 has quorum 1, not 2
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0usize;
        let mut reachable = 0usize;
        let mut node_err: Option<FilterError> = None;
        for &n in &replicas {
            match self.replica_call(n, 1, |p, ctx| p.put(ctx, key)) {
                Ok(()) => {
                    ok += 1;
                    reachable += 1;
                    // the node now holds newer state than any pending
                    // hint for this key could replay
                    let s = self.hints[n].supersede(key);
                    self.stats.hints_superseded += s as u64;
                }
                Err(ReplicaError::Node(e)) => {
                    reachable += 1;
                    node_err = Some(e);
                }
                Err(_) => self.queue_hint(n, seq, HintOp::Put(key)),
            }
        }
        self.dual_apply(key, seq, true);
        self.drain_replay_due();
        self.pump_transfers();
        if ok >= need {
            Ok(())
        } else {
            self.stats.quorum_losses += 1;
            match node_err {
                // every replica answered yet too few accepted: the
                // cluster is reachable but refusing, not partitioned
                Some(e) if reachable == replicas.len() => Err(ClusterError::Node(e)),
                _ => Err(ClusterError::QuorumLost { need, got: ok }),
            }
        }
    }

    /// Batched write fan-out (the ROADMAP "batched replica writes"
    /// carry-over): every key still reaches all RF replicas, but keys
    /// are grouped by replica node in one pass over the batch and each
    /// node takes a single [`StorageNode::put_batch`] (WAL + memtable
    /// per key, one bulk-hashed filter insert) instead of a call per
    /// key per replica. Per-key results, consistency accounting
    /// (`write_consistency.required` over the achievable replica set),
    /// hinting, and `per_node_ops`/`ops_routed` are identical to a
    /// scalar [`Cluster::put`] loop.
    pub fn put_batch(&mut self, keys: &[u64]) -> Vec<Result<(), ClusterError>> {
        if self.transition.is_some() {
            // routing is per-arc while a transfer streams: take the
            // scalar path so dual-apply and pump accounting stay exact
            return keys.iter().map(|&k| self.put(k)).collect();
        }
        self.stats.ops_routed += keys.len() as u64;
        let base = self.clock;
        self.clock += keys.len() as u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut need: Vec<usize> = Vec::with_capacity(keys.len());
        let mut rf_count = vec![0usize; keys.len()];
        let mut ok = vec![0usize; keys.len()];
        let mut reachable = vec![0usize; keys.len()];
        let mut last_err: Vec<Option<FilterError>> = vec![None; keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let replicas = self.ring.replicas(k, self.repl.rf);
            need.push(self.repl.write_consistency.required(replicas.len()));
            rf_count[i] = replicas.len();
            for &n in &replicas {
                groups[n].push(i);
            }
        }
        let mut gkeys: Vec<u64> = Vec::new();
        for node_id in 0..groups.len() {
            let group = std::mem::take(&mut groups[node_id]);
            if group.is_empty() {
                continue;
            }
            gkeys.clear();
            gkeys.extend(group.iter().map(|&i| keys[i]));
            match self.replica_call(node_id, group.len() as u64, |p, ctx| {
                p.put_batch(ctx, &gkeys)
            }) {
                Ok(results) => {
                    for (&i, r) in group.iter().zip(results) {
                        match r {
                            Ok(()) => {
                                ok[i] += 1;
                                reachable[i] += 1;
                                let s = self.hints[node_id].supersede(keys[i]);
                                self.stats.hints_superseded += s as u64;
                            }
                            Err(e) => {
                                reachable[i] += 1;
                                last_err[i] = Some(e);
                            }
                        }
                    }
                }
                Err(ReplicaError::Node(e)) => {
                    for &i in &group {
                        reachable[i] += 1;
                        last_err[i] = Some(e.clone());
                    }
                }
                Err(_) => {
                    for &i in &group {
                        self.queue_hint(node_id, base + i as u64, HintOp::Put(keys[i]));
                    }
                }
            }
        }
        self.drain_replay_due();
        (0..keys.len())
            .map(|i| {
                if ok[i] >= need[i] {
                    Ok(())
                } else {
                    self.stats.quorum_losses += 1;
                    match &last_err[i] {
                        Some(e) if reachable[i] == rf_count[i] => {
                            Err(ClusterError::Node(e.clone()))
                        }
                        _ => Err(ClusterError::QuorumLost {
                            need: need[i],
                            got: ok[i],
                        }),
                    }
                }
            })
            .collect()
    }

    /// Verified delete across replicas at the write consistency level
    /// (the same accounting as [`Cluster::put`] — a delete is a write).
    /// `Ok(true)` iff some acknowledging replica actually held the key.
    pub fn delete(&mut self, key: u64) -> Result<bool, ClusterError> {
        self.stats.ops_routed += 1;
        let seq = self.tick();
        let replicas = self.replicas_for(key);
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0usize;
        let mut any = false;
        for &n in &replicas {
            match self.replica_call(n, 1, |p, ctx| p.delete(ctx, key)) {
                Ok(was) => {
                    ok += 1;
                    any |= was;
                    let s = self.hints[n].supersede(key);
                    self.stats.hints_superseded += s as u64;
                }
                Err(ReplicaError::Node(_)) => {}
                Err(_) => self.queue_hint(n, seq, HintOp::Delete(key)),
            }
        }
        self.dual_apply(key, seq, false);
        self.drain_replay_due();
        self.pump_transfers();
        if ok >= need {
            Ok(any)
        } else {
            self.stats.quorum_losses += 1;
            Err(ClusterError::QuorumLost { need, got: ok })
        }
    }

    /// Batched delete fan-out, replica-grouped exactly like
    /// [`Cluster::put_batch`]: one [`StorageNode::delete_batch`] per
    /// node, per-key consistency accounting and hinting identical to a
    /// scalar [`Cluster::delete`] loop.
    pub fn delete_batch(&mut self, keys: &[u64]) -> Vec<Result<bool, ClusterError>> {
        if self.transition.is_some() {
            return keys.iter().map(|&k| self.delete(k)).collect();
        }
        self.stats.ops_routed += keys.len() as u64;
        let base = self.clock;
        self.clock += keys.len() as u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut need: Vec<usize> = Vec::with_capacity(keys.len());
        let mut ok = vec![0usize; keys.len()];
        let mut any = vec![false; keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let replicas = self.ring.replicas(k, self.repl.rf);
            need.push(self.repl.write_consistency.required(replicas.len()));
            for &n in &replicas {
                groups[n].push(i);
            }
        }
        let mut gkeys: Vec<u64> = Vec::new();
        for node_id in 0..groups.len() {
            let group = std::mem::take(&mut groups[node_id]);
            if group.is_empty() {
                continue;
            }
            gkeys.clear();
            gkeys.extend(group.iter().map(|&i| keys[i]));
            match self.replica_call(node_id, group.len() as u64, |p, ctx| {
                p.delete_batch(ctx, &gkeys)
            }) {
                Ok(results) => {
                    for (&i, was) in group.iter().zip(results) {
                        ok[i] += 1;
                        any[i] |= was;
                        let s = self.hints[node_id].supersede(keys[i]);
                        self.stats.hints_superseded += s as u64;
                    }
                }
                Err(ReplicaError::Node(_)) => {}
                Err(_) => {
                    for &i in &group {
                        self.queue_hint(node_id, base + i as u64, HintOp::Delete(keys[i]));
                    }
                }
            }
        }
        self.drain_replay_due();
        (0..keys.len())
            .map(|i| {
                if ok[i] >= need[i] {
                    Ok(any[i])
                } else {
                    self.stats.quorum_losses += 1;
                    Err(ClusterError::QuorumLost {
                        need: need[i],
                        got: ok[i],
                    })
                }
            })
            .collect()
    }

    /// Read at the configured consistency: walk the replica set in
    /// ring order until `read_consistency.required` replicas answered
    /// (skipping unreachable ones), then resolve — on disagreement the
    /// newest pending hint decides and divergent replicas are
    /// repaired. Fewer answers than required is a typed
    /// [`ClusterError::QuorumLost`], never a silent `false`.
    pub fn get(&mut self, key: u64) -> Result<bool, ClusterError> {
        self.stats.ops_routed += 1;
        self.tick();
        let replicas = self.replicas_for(key);
        let need = self.repl.read_consistency.required(replicas.len()).max(1);
        let mut answers: Vec<(usize, bool)> = Vec::with_capacity(need);
        for &n in &replicas {
            if answers.len() >= need {
                break;
            }
            if let Ok(hit) = self.replica_call(n, 1, |p, ctx| p.get(ctx, key)) {
                answers.push((n, hit));
            }
        }
        let out = if answers.len() < need {
            self.stats.quorum_losses += 1;
            Err(ClusterError::QuorumLost {
                need,
                got: answers.len(),
            })
        } else {
            Ok(self.resolve_read(key, &answers))
        };
        self.drain_replay_due();
        self.pump_transfers();
        out
    }

    /// Batched read fan-out: keys are grouped by replica and each
    /// node's group is resolved through [`StorageNode::get_batch`] (the
    /// filter-generic batched read path), in consultation "waves" —
    /// wave `w` probes replica `w` of every key still short of its
    /// required answer count, so the answers (and the per-node op
    /// accounting) are identical to a scalar [`Cluster::get`] loop
    /// while each node sees one batched probe per wave instead of a
    /// call per key.
    pub fn get_batch(&mut self, keys: &[u64]) -> Vec<Result<bool, ClusterError>> {
        if self.transition.is_some() {
            return keys.iter().map(|&k| self.get(k)).collect();
        }
        self.stats.ops_routed += keys.len() as u64;
        self.clock += keys.len() as u64;
        let replica_sets: Vec<Vec<usize>> = keys
            .iter()
            .map(|&k| self.ring.replicas(k, self.repl.rf))
            .collect();
        let needs: Vec<usize> = replica_sets
            .iter()
            .map(|r| self.repl.read_consistency.required(r.len()).max(1))
            .collect();
        let mut answers: Vec<Vec<(usize, bool)>> = vec![Vec::new(); keys.len()];
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.proxies.len()];
        let mut gkeys: Vec<u64> = Vec::new();
        let mut wave = 0usize;
        loop {
            for g in groups.iter_mut() {
                g.clear();
            }
            let mut active = false;
            for i in 0..keys.len() {
                // a key keeps consulting deeper replicas only while it
                // is short of its required answers — under healthy
                // planes that is exactly the first `need` replicas
                if answers[i].len() < needs[i] && wave < replica_sets[i].len() {
                    groups[replica_sets[i][wave]].push(i);
                    active = true;
                }
            }
            if !active {
                break;
            }
            for node_id in 0..groups.len() {
                let group = std::mem::take(&mut groups[node_id]);
                if group.is_empty() {
                    continue;
                }
                gkeys.clear();
                gkeys.extend(group.iter().map(|&i| keys[i]));
                if let Ok(hits) = self.replica_call(node_id, group.len() as u64, |p, ctx| {
                    p.get_batch(ctx, &gkeys)
                }) {
                    for (&i, hit) in group.iter().zip(hits) {
                        answers[i].push((node_id, hit));
                    }
                }
            }
            wave += 1;
        }
        let out: Vec<Result<bool, ClusterError>> = (0..keys.len())
            .map(|i| {
                if answers[i].len() < needs[i] {
                    self.stats.quorum_losses += 1;
                    Err(ClusterError::QuorumLost {
                        need: needs[i],
                        got: answers[i].len(),
                    })
                } else {
                    Ok(self.resolve_read(keys[i], &answers[i]))
                }
            })
            .collect();
        self.drain_replay_due();
        out
    }

    /// Merge one key's replica answers; on disagreement, decide the
    /// truth and repair the replicas that answered wrong.
    ///
    /// The truth rule carries the no-resurrection proof: a divergent
    /// replica missed a write, and every missed write has a pending
    /// hint (or `hints_dropped` says the contract is void) — so the
    /// *newest pending hint* for the key is the write the divergent
    /// replica hasn't seen. A pending `Delete` newer than anything
    /// else means the key is gone, however many stale replicas still
    /// answer `true`. With no pending hint, a positive answer wins:
    /// reads are verified, so some replica provably holds the key.
    fn resolve_read(&mut self, key: u64, answers: &[(usize, bool)]) -> bool {
        let first = answers[0].1;
        if answers.iter().all(|&(_, h)| h == first) {
            return first;
        }
        let latest = self
            .hints
            .iter()
            .filter_map(|q| q.latest_for(key))
            .max_by_key(|h| h.seq);
        let truth = match latest {
            Some(h) => matches!(h.op, HintOp::Put(_)),
            None => true,
        };
        for &(n, hit) in answers {
            if hit == truth {
                continue;
            }
            let repaired = if truth {
                self.replica_call(n, 0, |p, ctx| p.put(ctx, key).map(|()| ()))
            } else {
                self.replica_call(n, 0, |p, ctx| p.delete(ctx, key).map(|_| ()))
            };
            if repaired.is_ok() {
                let s = self.hints[n].supersede(key);
                self.stats.hints_superseded += s as u64;
                self.stats.read_repairs += 1;
            }
        }
        truth
    }

    /// Apply a workload op (availability semantics: a quorum-lost read
    /// reports "absent" here; callers that need the distinction use
    /// the typed APIs).
    pub fn apply(&mut self, op: Op) -> bool {
        match op {
            Op::Insert(k) => self.put(k).is_ok(),
            Op::Lookup(k) => self.get(k).unwrap_or(false),
            Op::Delete(k) => self.delete(k).unwrap_or(false),
        }
    }

    /// Sum of filter memory across nodes.
    pub fn filter_memory_bytes(&self) -> usize {
        self.proxies.iter().map(|p| p.node().filter_memory_bytes()).sum()
    }

    /// Aggregate flush counts (premature, total).
    pub fn flush_counts(&self) -> (u64, u64) {
        let premature = self
            .proxies
            .iter()
            .map(|p| p.node().stats.flushes_premature)
            .sum();
        let total = self.proxies.iter().map(|p| p.node().stats.flushes).sum();
        (premature, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::proxy::Verdict;
    use crate::cluster::replication::Consistency;
    use crate::store::FlushPolicy;

    fn cluster(n: usize, rf: usize) -> Cluster {
        Cluster::new(
            n,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf,
                ..ReplicationConfig::default()
            },
        )
    }

    /// Crashed while `clock < until`, healthy afterwards.
    #[derive(Debug)]
    struct DownUntil(u64);

    impl FaultPlane for DownUntil {
        fn verdict(&self, clock: u64, _attempt: u32) -> Verdict {
            if clock < self.0 {
                Verdict::Crashed
            } else {
                Verdict::Healthy
            }
        }
        fn describe(&self) -> String {
            format!("down until tick {}", self.0)
        }
    }

    /// 3-node rf=3 cluster where node 2 is down until `until`.
    fn cluster_with_down_node(until: u64) -> Cluster {
        let planes: Vec<Arc<dyn FaultPlane>> = vec![
            Arc::new(RealProxy),
            Arc::new(RealProxy),
            Arc::new(DownUntil(until)),
        ];
        Cluster::with_fault_planes(
            3,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 3,
                read_consistency: Consistency::Quorum,
                write_consistency: Consistency::Quorum,
            },
            ResilienceConfig::default(),
            planes,
        )
    }

    #[test]
    fn put_get_across_cluster() {
        let mut c = cluster(4, 2);
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k).unwrap(), "{k}");
        }
        assert!(!c.get(999_999).unwrap());
    }

    #[test]
    fn replication_writes_rf_copies() {
        let mut c = cluster(4, 3);
        c.put(42).unwrap();
        let holders = (0..4).filter(|&i| c.node(i).live_keys() > 0).count();
        assert_eq!(holders, 3, "rf=3 must store 3 copies");
    }

    #[test]
    fn delete_removes_from_all_replicas() {
        let mut c = cluster(3, 3);
        c.put(7).unwrap();
        assert!(c.delete(7).unwrap());
        assert!(!c.get(7).unwrap());
        for i in 0..3 {
            assert_eq!(c.node(i).live_keys(), 0);
        }
        assert!(!c.delete(7).unwrap(), "second delete rejected everywhere");
    }

    #[test]
    fn per_node_ops_accumulate() {
        let mut c = cluster(3, 1);
        for k in 0..300u64 {
            c.put(k).unwrap();
        }
        let total: u64 = c.stats.per_node_ops.iter().sum();
        assert_eq!(total, 300, "rf=1 → one node op per put");
        assert!(c.stats.per_node_ops.iter().all(|&x| x > 50), "{:?}", c.stats.per_node_ops);
    }

    #[test]
    fn sharded_filter_cluster_roundtrip() {
        // nodes opt into the concurrent filter front-end via config;
        // routing/replication semantics must be unchanged
        let mut c = Cluster::new(
            3,
            32,
            NodeConfig {
                filter: crate::filter::FilterBuilder::default().with_shards(4),
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 2,
                ..ReplicationConfig::default()
            },
        );
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k).unwrap(), "{k}");
        }
        assert!(!c.get(999_999).unwrap());
        assert!(c.delete(42).unwrap());
        assert!(!c.get(42).unwrap());
    }

    #[test]
    fn single_node_cluster_degenerates_gracefully() {
        let mut c = cluster(1, 3);
        c.put(1).unwrap();
        assert!(c.get(1).unwrap());
        assert!(c.delete(1).unwrap());
    }

    #[test]
    fn put_batch_matches_scalar_puts() {
        for write_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 3,
                        write_consistency,
                        ..ReplicationConfig::default()
                    },
                )
            };
            let keys: Vec<u64> = (0..2000u64).collect();
            let mut batched_cluster = mk();
            for r in batched_cluster.put_batch(&keys) {
                r.unwrap_or_else(|e| panic!("{write_consistency:?}: {e}"));
            }
            let mut scalar_cluster = mk();
            for &k in &keys {
                scalar_cluster.put(k).unwrap();
            }
            // identical routing accounting, replica for replica
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{write_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            // identical answers and replica placement
            let probes: Vec<u64> = (0..3000u64).collect();
            let batched_answers: Vec<bool> = batched_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let scalar_answers: Vec<bool> = scalar_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(batched_answers, scalar_answers, "{write_consistency:?}");
            for i in 0..4 {
                assert_eq!(
                    batched_cluster.node(i).live_keys(),
                    scalar_cluster.node(i).live_keys(),
                    "{write_consistency:?}: node {i}"
                );
            }
        }
    }

    #[test]
    fn get_batch_matches_scalar_gets() {
        for read_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                let mut c = Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 2,
                        read_consistency,
                        ..ReplicationConfig::default()
                    },
                );
                for k in 0..2000u64 {
                    c.put(k).unwrap();
                }
                c
            };
            let probes: Vec<u64> = (0..3000u64).collect();
            let mut batched_cluster = mk();
            let batched: Vec<bool> = batched_cluster
                .get_batch(&probes)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let mut scalar_cluster = mk();
            let scalar: Vec<bool> = probes
                .iter()
                .map(|&k| scalar_cluster.get(k).unwrap())
                .collect();
            assert_eq!(batched, scalar, "{read_consistency:?}");
            // identical routing accounting, probe for probe
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{read_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            for k in 0..2000u64 {
                assert!(batched[k as usize], "{read_consistency:?}: lost {k}");
            }
        }
    }

    #[test]
    fn delete_batch_matches_scalar_deletes() {
        for write_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                let mut c = Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 3,
                        write_consistency,
                        ..ReplicationConfig::default()
                    },
                );
                for k in 0..1000u64 {
                    c.put(k).unwrap();
                }
                c
            };
            // delete evens plus some never-inserted keys
            let victims: Vec<u64> = (0..1500u64).filter(|k| k % 2 == 0).collect();
            let mut batched_cluster = mk();
            let batched: Vec<bool> = batched_cluster
                .delete_batch(&victims)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            let mut scalar_cluster = mk();
            let scalar: Vec<bool> = victims
                .iter()
                .map(|&k| scalar_cluster.delete(k).unwrap())
                .collect();
            assert_eq!(batched, scalar, "{write_consistency:?}");
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{write_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            for i in 0..4 {
                assert_eq!(
                    batched_cluster.node(i).live_keys(),
                    scalar_cluster.node(i).live_keys(),
                    "{write_consistency:?}: node {i}"
                );
            }
            // deleted keys are gone, odd keys survive
            for k in 0..1000u64 {
                assert_eq!(batched_cluster.get(k).unwrap(), k % 2 == 1, "{k}");
            }
        }
    }

    #[test]
    fn down_replica_trips_breaker_and_queues_hints() {
        let mut c = cluster_with_down_node(50);
        for k in 0..30u64 {
            c.put(k).unwrap_or_else(|e| panic!("quorum of 2 healthy replicas must ack: {e}"));
        }
        assert_eq!(c.stats.breaker_trips, 1, "node 2 tripped once");
        assert!(c.breaker_open(2));
        assert_eq!(c.stats.hints_queued, 30, "one hint per missed write");
        assert_eq!(c.hints_pending(), 30);
        assert_eq!(c.node(2).live_keys(), 0, "down node took nothing");
        // reads at quorum never see a false negative meanwhile
        for k in 0..30u64 {
            assert!(c.get(k).unwrap(), "acked write {k} must be readable");
        }
    }

    #[test]
    fn hints_replay_after_recovery_and_drain_to_zero() {
        let mut c = cluster_with_down_node(50);
        for k in 0..30u64 {
            c.put(k).unwrap();
        }
        assert_eq!(c.hints_pending(), 30);
        // recover: past the fault window *and* the breaker cooldown
        let cooldown = c.resilience().breaker.cooldown;
        c.advance_clock(50 + cooldown);
        let pending = c.replay_hints();
        assert_eq!(pending, 0, "hint queues must drain after recovery");
        assert_eq!(c.stats.hints_replayed, 30);
        assert_eq!(c.stats.hints_dropped, 0);
        assert!(!c.breaker_open(2));
        assert_eq!(c.node(2).live_keys(), 30, "replayed writes landed");
    }

    #[test]
    fn breaker_fast_fails_without_retry_burn() {
        let mut c = cluster_with_down_node(1_000_000);
        for k in 0..20u64 {
            c.put(k).unwrap();
        }
        // only the pre-trip calls burned retries; breaker-open ops
        // fast-fail (crashed verdicts are hard errors — no retry —
        // so the retry counter stays at zero here)
        assert_eq!(c.stats.retries, 0);
        assert_eq!(c.stats.breaker_trips, 1);
        assert_eq!(c.hints_pending(), 20, "fast-fail still queues hints");
    }

    #[test]
    fn read_repair_fixes_divergent_replica() {
        let mut c = cluster(3, 3);
        // read at All so every replica is consulted
        c.repl.read_consistency = Consistency::All;
        c.put(7).unwrap();
        // silently diverge node 0 behind the router's back
        let victim = c.ring().replicas(7, 3)[0];
        assert!(c.node_mut(victim).delete(7));
        assert!(c.get(7).unwrap(), "no pending hints → positive answer wins");
        assert_eq!(c.stats.read_repairs, 1);
        assert!(c.node(victim).get(7), "divergent replica rewritten");
        // now all replicas agree again — no further repairs
        assert!(c.get(7).unwrap());
        assert_eq!(c.stats.read_repairs, 1);
    }

    #[test]
    fn pending_delete_hint_wins_read_repair_no_resurrection() {
        let mut c = cluster_with_down_node(50);
        c.repl.read_consistency = Consistency::All;
        // while node 2 is still healthy... it isn't (down from tick 0),
        // so seed node 2 directly: it holds the key, the others will
        // process the delete
        c.node_mut(2).put(99).unwrap();
        c.node_mut(0).put(99).unwrap();
        c.node_mut(1).put(99).unwrap();
        let r = c.delete(99);
        assert!(r.unwrap(), "quorum delete acked");
        assert_eq!(c.hints_pending(), 1, "missed replica got a delete hint");
        // node 2 recovers; the hint has NOT replayed yet. A read-All
        // sees the stale positive — the pending delete hint must win.
        c.advance_clock(50 + c.resilience().breaker.cooldown);
        assert!(!c.get(99).unwrap(), "deleted key must not resurrect");
        assert!(!c.node(2).get(99), "stale replica repaired to absent");
        // drain: the repair superseded the hint (or replay deletes again)
        assert_eq!(c.replay_hints(), 0);
        assert!(!c.get(99).unwrap());
    }

    #[test]
    fn quorum_lost_is_a_typed_error() {
        // both of node 2's peers down forever: rf=3 quorum=2 writes
        // can only ever reach 1 replica
        let planes: Vec<Arc<dyn FaultPlane>> = vec![
            Arc::new(RealProxy),
            Arc::new(DownUntil(u64::MAX)),
            Arc::new(DownUntil(u64::MAX)),
        ];
        let mut c = Cluster::with_fault_planes(
            3,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 3,
                read_consistency: Consistency::Quorum,
                write_consistency: Consistency::Quorum,
            },
            ResilienceConfig::default(),
            planes,
        );
        let mut saw_quorum_lost = false;
        for k in 0..10u64 {
            match c.put(k) {
                Err(ClusterError::QuorumLost { need, got }) => {
                    assert_eq!(need, 2);
                    assert_eq!(got, 1);
                    saw_quorum_lost = true;
                }
                other => panic!("expected QuorumLost, got {other:?}"),
            }
        }
        assert!(saw_quorum_lost);
        assert!(c.stats.quorum_losses >= 10);
        match c.get(0) {
            Err(ClusterError::QuorumLost { need: 2, got: 1 }) => {}
            other => panic!("expected read QuorumLost, got {other:?}"),
        }
    }

    #[test]
    fn join_streams_all_data_and_flips_the_ring() {
        let mut c = cluster(3, 3);
        for k in 0..500u64 {
            c.put(k).unwrap();
        }
        let id = c.add_node().unwrap();
        assert_eq!(id, 3, "stable ids: next free slot");
        assert!(c.transfer_active());
        // reads during the transfer never miss (old owners serve)
        for k in 0..500u64 {
            assert!(c.get(k).unwrap(), "{k} during transfer");
        }
        while c.pump_transfers() > 0 {}
        assert!(!c.transfer_active());
        assert!(c.ring().contains(3));
        assert_eq!(c.ring().node_count(), 4);
        assert!(c.node(3).live_keys() > 0, "joiner received streamed keys");
        assert_eq!(c.stats.transfers_started, 1);
        assert_eq!(c.stats.transfers_completed, 1);
        assert_eq!(
            c.stats.keys_captured,
            c.stats.keys_streamed + c.stats.keys_superseded,
            "conservation law"
        );
        // post-flip: every key on every new-ring replica, reads hit
        for k in 0..500u64 {
            assert!(c.get(k).unwrap(), "{k} after flip");
            for &n in &c.ring().replicas(k, 3) {
                assert!(c.node(n).get(k), "key {k} missing on replica {n}");
            }
        }
        assert!(!c.get(999_999).unwrap());
    }

    #[test]
    fn leave_streams_to_successors_and_retires_the_node() {
        let mut c = cluster(4, 2);
        for k in 0..400u64 {
            c.put(k).unwrap();
        }
        c.remove_node(1).unwrap();
        while c.pump_transfers() > 0 {}
        assert!(!c.transfer_active());
        assert!(!c.ring().contains(1));
        assert!(c.is_retired(1));
        for k in 0..400u64 {
            assert!(c.get(k).unwrap(), "{k} after leave");
            for &n in &c.ring().replicas(k, 2) {
                assert_ne!(n, 1, "retired node must own nothing");
                assert!(c.node(n).get(k), "key {k} missing on replica {n}");
            }
        }
        assert_eq!(
            c.stats.keys_captured,
            c.stats.keys_streamed + c.stats.keys_superseded
        );
        assert_eq!(
            c.remove_node(1),
            Err(MembershipError::UnknownNode(1)),
            "a retired id cannot be removed twice"
        );
    }

    #[test]
    fn membership_guards_reject_invalid_requests() {
        let mut c = cluster(2, 2);
        c.add_node().unwrap();
        assert_eq!(c.add_node(), Err(MembershipError::TransferInProgress));
        assert_eq!(c.remove_node(0), Err(MembershipError::TransferInProgress));
        while c.pump_transfers() > 0 {}
        assert_eq!(c.remove_node(9), Err(MembershipError::UnknownNode(9)));
        let mut solo = cluster(1, 2);
        assert_eq!(solo.remove_node(0), Err(MembershipError::LastNode));
    }

    #[test]
    fn shrinking_below_rf_flips_immediately() {
        // 3 nodes at rf=3: survivors already hold everything, so the
        // leave plan has no gainers and completes without streaming
        let mut c = cluster(3, 3);
        for k in 0..100u64 {
            c.put(k).unwrap();
        }
        c.remove_node(2).unwrap();
        assert!(!c.transfer_active(), "nothing to stream");
        assert!(c.is_retired(2));
        assert_eq!(c.stats.keys_captured, 0);
        for k in 0..100u64 {
            assert!(c.get(k).unwrap(), "{k}");
        }
    }

    #[test]
    fn writes_during_transfer_dual_apply_and_survive_the_flip() {
        let mut c = cluster(3, 3);
        for k in 0..200u64 {
            c.put(k).unwrap();
        }
        c.add_node().unwrap();
        // interleave fresh writes and deletes with the stream (each op
        // pumps one bounded batch)
        for k in 200..400u64 {
            c.put(k).unwrap();
        }
        for k in 0..100u64 {
            c.delete(k).unwrap();
        }
        while c.pump_transfers() > 0 {}
        assert!(!c.transfer_active());
        for k in 0..100u64 {
            assert!(!c.get(k).unwrap(), "deleted {k} resurrected");
            for &n in &c.ring().replicas(k, 3) {
                assert!(!c.node(n).get(k), "deleted {k} still live on {n}");
            }
        }
        for k in 100..400u64 {
            assert!(c.get(k).unwrap(), "{k} lost across the flip");
            for &n in &c.ring().replicas(k, 3) {
                assert!(c.node(n).get(k), "key {k} missing on {n}");
            }
        }
        assert_eq!(
            c.stats.keys_captured,
            c.stats.keys_streamed + c.stats.keys_superseded
        );
    }

    #[test]
    fn joiner_death_mid_transfer_stalls_then_completes() {
        let mut c = cluster(3, 3);
        for k in 0..300u64 {
            c.put(k).unwrap();
        }
        // clock is now 300; the joiner is unreachable until tick 400
        let id = c.add_node_with_plane(Arc::new(DownUntil(400))).unwrap();
        for _ in 0..40 {
            c.pump_transfers();
        }
        assert!(
            c.transfer_active(),
            "stream cannot finish against a dead joiner"
        );
        assert!(c.stats.transfers_retried > 0);
        // reads keep serving from the old owners meanwhile
        for k in 0..300u64 {
            assert!(c.get(k).unwrap(), "{k} while joiner is down");
        }
        c.advance_clock(400 + c.resilience().breaker.cooldown);
        let mut rounds = 0;
        while c.pump_transfers() > 0 {
            rounds += 1;
            assert!(rounds < 100_000, "transfer must complete after recovery");
        }
        assert!(!c.transfer_active());
        assert!(c.node(id).live_keys() > 0);
        for k in 0..300u64 {
            assert!(c.get(k).unwrap(), "{k} after recovery and flip");
        }
        assert_eq!(
            c.stats.keys_captured,
            c.stats.keys_streamed + c.stats.keys_superseded
        );
        assert_eq!(c.hints_pending(), 0);
    }
}

//! The cluster: N storage nodes behind a consistent-hash router.
//!
//! In-process simulation of the data-center the paper targets: each op
//! routes to its replica set; per-node op counts expose the fan-out
//! asymmetries of §I.B. The router is also where the membership-filter
//! economics show up cluster-wide: a read whose replica filter says
//! "absent" never touches that node's SSTables.
//!
//! False-positive feedback is **per replica**: when a replica's read
//! reaches its tables and misses, [`StorageNode::get`]/`get_batch`
//! report the FP to that replica's *own* filter
//! ([`crate::filter::FilterFeedback`]) inside the node read path —
//! node filters are independently seeded, so an FP on one replica says
//! nothing about the others and the router adds no extra mechanism.

use super::replication::ReplicationConfig;
use super::ring::HashRing;
use crate::store::{NodeConfig, StorageNode};
use crate::workload::Op;

/// Router-level counters.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub ops_routed: u64,
    /// Per-node op counts (fan-out visibility).
    pub per_node_ops: Vec<u64>,
}

/// An in-process cluster.
#[derive(Debug)]
pub struct Cluster {
    ring: HashRing,
    nodes: Vec<StorageNode>,
    repl: ReplicationConfig,
    pub stats: RouterStats,
}

impl Cluster {
    /// Build `n` nodes from a config template (node_id/seed are
    /// specialized per node so filters are independent).
    pub fn new(n: usize, vnodes: usize, template: NodeConfig, repl: ReplicationConfig) -> Self {
        let nodes = (0..n)
            .map(|i| {
                let mut cfg = template.clone();
                cfg.node_id = i as u64;
                cfg.filter.ocf.seed = template.filter.ocf.seed ^ ((i as u64 + 1) << 17);
                StorageNode::new(cfg)
            })
            .collect();
        Self {
            ring: HashRing::new(n, vnodes),
            nodes,
            repl,
            stats: RouterStats {
                ops_routed: 0,
                per_node_ops: vec![0; n],
            },
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, i: usize) -> &StorageNode {
        &self.nodes[i]
    }

    pub fn node_mut(&mut self, i: usize) -> &mut StorageNode {
        &mut self.nodes[i]
    }

    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// Write to all RF replicas (the write consistency level governs
    /// how many must succeed; in-process nodes never fail, so this is
    /// an accounting distinction surfaced for experiments).
    pub fn put(&mut self, key: u64) -> Result<(), crate::filter::FilterError> {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        // consistency is computed over the *achievable* replica set —
        // a 1-node cluster with rf=3 has quorum 1, not 2
        let need = self.repl.write_consistency.required(replicas.len());
        let mut ok = 0;
        let mut last_err = None;
        for &n in &replicas {
            self.stats.per_node_ops[n] += 1;
            match self.nodes[n].put(key) {
                Ok(()) => ok += 1,
                Err(e) => last_err = Some(e),
            }
        }
        if ok >= need {
            Ok(())
        } else {
            Err(last_err.expect("failed write must carry an error"))
        }
    }

    /// Batched write fan-out (the ROADMAP "batched replica writes"
    /// carry-over): every key still reaches all RF replicas, but keys
    /// are grouped by replica node in one pass over the batch and each
    /// node takes a single [`StorageNode::put_batch`] (WAL + memtable
    /// per key, one bulk-hashed filter insert) instead of a call per
    /// key per replica. Per-key results, consistency accounting
    /// (`write_consistency.required` over the achievable replica set)
    /// and `per_node_ops`/`ops_routed` are identical to a scalar
    /// [`Cluster::put`] loop.
    pub fn put_batch(&mut self, keys: &[u64]) -> Vec<Result<(), crate::filter::FilterError>> {
        self.stats.ops_routed += keys.len() as u64;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        let mut need: Vec<usize> = Vec::with_capacity(keys.len());
        let mut ok = vec![0usize; keys.len()];
        let mut last_err: Vec<Option<crate::filter::FilterError>> = vec![None; keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let replicas = self.ring.replicas(k, self.repl.rf);
            need.push(self.repl.write_consistency.required(replicas.len()));
            for &n in &replicas {
                groups[n].push(i);
            }
        }
        let mut gkeys: Vec<u64> = Vec::new();
        for (node_id, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            self.stats.per_node_ops[node_id] += group.len() as u64;
            gkeys.clear();
            gkeys.extend(group.iter().map(|&i| keys[i]));
            let results = self.nodes[node_id].put_batch(&gkeys);
            for (&i, r) in group.iter().zip(results) {
                match r {
                    Ok(()) => ok[i] += 1,
                    Err(e) => last_err[i] = Some(e),
                }
            }
        }
        (0..keys.len())
            .map(|i| {
                if ok[i] >= need[i] {
                    Ok(())
                } else {
                    Err(last_err[i]
                        .clone()
                        .expect("failed write must carry an error"))
                }
            })
            .collect()
    }

    /// Verified delete across replicas.
    pub fn delete(&mut self, key: u64) -> bool {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        let mut any = false;
        for &n in &replicas {
            self.stats.per_node_ops[n] += 1;
            any |= self.nodes[n].delete(key);
        }
        any
    }

    /// Read at the configured consistency: consult up to `required`
    /// replicas, first positive wins (membership semantics).
    pub fn get(&mut self, key: u64) -> bool {
        self.stats.ops_routed += 1;
        let replicas = self.ring.replicas(key, self.repl.rf);
        let need = self.repl.read_consistency.required(replicas.len());
        for &n in replicas.iter().take(need.max(1)) {
            self.stats.per_node_ops[n] += 1;
            if self.nodes[n].get(key) {
                return true;
            }
        }
        false
    }

    /// Batched read fan-out: keys are grouped by replica and each
    /// node's group is resolved through [`StorageNode::get_batch`] (the
    /// filter-generic batched read path), in consultation "waves" —
    /// wave `w` probes replica `w` of every still-unresolved key, so
    /// the answers (and the per-node op accounting) are identical to a
    /// scalar [`Cluster::get`] loop while each node sees one batched
    /// probe per wave instead of a call per key.
    pub fn get_batch(&mut self, keys: &[u64]) -> Vec<bool> {
        self.stats.ops_routed += keys.len() as u64;
        let mut out = vec![false; keys.len()];
        // (key index, replica list) for every unresolved key
        let mut pending: Vec<(usize, Vec<usize>)> = keys
            .iter()
            .enumerate()
            .map(|(i, &k)| (i, self.ring.replicas(k, self.repl.rf)))
            .collect();
        let mut wave = 0usize;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        while !pending.is_empty() {
            for g in groups.iter_mut() {
                g.clear();
            }
            // a key participates in wave `w` only while w < need
            let mut next_pending: Vec<(usize, Vec<usize>)> = Vec::new();
            for (i, replicas) in pending.drain(..) {
                let need = self.repl.read_consistency.required(replicas.len()).max(1);
                if wave < need.min(replicas.len()) {
                    groups[replicas[wave]].push(i);
                    next_pending.push((i, replicas));
                }
            }
            if next_pending.is_empty() {
                break;
            }
            let mut gkeys: Vec<u64> = Vec::new();
            for (node_id, group) in groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                self.stats.per_node_ops[node_id] += group.len() as u64;
                gkeys.clear();
                gkeys.extend(group.iter().map(|&i| keys[i]));
                let answers = self.nodes[node_id].get_batch(&gkeys);
                for (&i, hit) in group.iter().zip(answers) {
                    if hit {
                        out[i] = true;
                    }
                }
            }
            // keys answered positive leave the wave set
            pending = next_pending.into_iter().filter(|(i, _)| !out[*i]).collect();
            wave += 1;
        }
        out
    }

    /// Apply a workload op.
    pub fn apply(&mut self, op: Op) -> bool {
        match op {
            Op::Insert(k) => self.put(k).is_ok(),
            Op::Lookup(k) => self.get(k),
            Op::Delete(k) => self.delete(k),
        }
    }

    /// Sum of filter memory across nodes.
    pub fn filter_memory_bytes(&self) -> usize {
        self.nodes.iter().map(|n| n.filter_memory_bytes()).sum()
    }

    /// Aggregate flush counts (premature, total).
    pub fn flush_counts(&self) -> (u64, u64) {
        let premature = self.nodes.iter().map(|n| n.stats.flushes_premature).sum();
        let total = self.nodes.iter().map(|n| n.stats.flushes).sum();
        (premature, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::FlushPolicy;

    fn cluster(n: usize, rf: usize) -> Cluster {
        Cluster::new(
            n,
            32,
            NodeConfig {
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf,
                ..ReplicationConfig::default()
            },
        )
    }

    #[test]
    fn put_get_across_cluster() {
        let mut c = cluster(4, 2);
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k), "{k}");
        }
        assert!(!c.get(999_999));
    }

    #[test]
    fn replication_writes_rf_copies() {
        let mut c = cluster(4, 3);
        c.put(42).unwrap();
        let holders = (0..4).filter(|&i| c.node(i).live_keys() > 0).count();
        assert_eq!(holders, 3, "rf=3 must store 3 copies");
    }

    #[test]
    fn delete_removes_from_all_replicas() {
        let mut c = cluster(3, 3);
        c.put(7).unwrap();
        assert!(c.delete(7));
        assert!(!c.get(7));
        for i in 0..3 {
            assert_eq!(c.node(i).live_keys(), 0);
        }
        assert!(!c.delete(7), "second delete rejected everywhere");
    }

    #[test]
    fn per_node_ops_accumulate() {
        let mut c = cluster(3, 1);
        for k in 0..300u64 {
            c.put(k).unwrap();
        }
        let total: u64 = c.stats.per_node_ops.iter().sum();
        assert_eq!(total, 300, "rf=1 → one node op per put");
        assert!(c.stats.per_node_ops.iter().all(|&x| x > 50), "{:?}", c.stats.per_node_ops);
    }

    #[test]
    fn sharded_filter_cluster_roundtrip() {
        // nodes opt into the concurrent filter front-end via config;
        // routing/replication semantics must be unchanged
        let mut c = Cluster::new(
            3,
            32,
            NodeConfig {
                filter: crate::filter::FilterBuilder::default().with_shards(4),
                flush: FlushPolicy::small(10_000),
                ..NodeConfig::default()
            },
            ReplicationConfig {
                rf: 2,
                ..ReplicationConfig::default()
            },
        );
        for k in 0..2000u64 {
            c.put(k).unwrap();
        }
        for k in 0..2000u64 {
            assert!(c.get(k), "{k}");
        }
        assert!(!c.get(999_999));
        assert!(c.delete(42));
        assert!(!c.get(42));
    }

    #[test]
    fn single_node_cluster_degenerates_gracefully() {
        let mut c = cluster(1, 3);
        c.put(1).unwrap();
        assert!(c.get(1));
        assert!(c.delete(1));
    }

    #[test]
    fn put_batch_matches_scalar_puts() {
        use crate::cluster::replication::Consistency;
        for write_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 3,
                        write_consistency,
                        ..ReplicationConfig::default()
                    },
                )
            };
            let keys: Vec<u64> = (0..2000u64).collect();
            let mut batched_cluster = mk();
            for r in batched_cluster.put_batch(&keys) {
                r.unwrap_or_else(|e| panic!("{write_consistency:?}: {e}"));
            }
            let mut scalar_cluster = mk();
            for &k in &keys {
                scalar_cluster.put(k).unwrap();
            }
            // identical routing accounting, replica for replica
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{write_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            // identical answers and replica placement
            let probes: Vec<u64> = (0..3000u64).collect();
            assert_eq!(
                batched_cluster.get_batch(&probes),
                scalar_cluster.get_batch(&probes),
                "{write_consistency:?}"
            );
            for i in 0..4 {
                assert_eq!(
                    batched_cluster.node(i).live_keys(),
                    scalar_cluster.node(i).live_keys(),
                    "{write_consistency:?}: node {i}"
                );
            }
        }
    }

    #[test]
    fn get_batch_matches_scalar_gets() {
        use crate::cluster::replication::Consistency;
        for read_consistency in [Consistency::One, Consistency::Quorum, Consistency::All] {
            let mk = || {
                let mut c = Cluster::new(
                    4,
                    32,
                    NodeConfig {
                        flush: FlushPolicy::small(10_000),
                        ..NodeConfig::default()
                    },
                    ReplicationConfig {
                        rf: 2,
                        read_consistency,
                        ..ReplicationConfig::default()
                    },
                );
                for k in 0..2000u64 {
                    c.put(k).unwrap();
                }
                c
            };
            let probes: Vec<u64> = (0..3000u64).collect();
            let mut batched_cluster = mk();
            let batched = batched_cluster.get_batch(&probes);
            let mut scalar_cluster = mk();
            let scalar: Vec<bool> = probes.iter().map(|&k| scalar_cluster.get(k)).collect();
            assert_eq!(batched, scalar, "{read_consistency:?}");
            // identical routing accounting, probe for probe
            assert_eq!(
                batched_cluster.stats.per_node_ops, scalar_cluster.stats.per_node_ops,
                "{read_consistency:?}"
            );
            assert_eq!(
                batched_cluster.stats.ops_routed,
                scalar_cluster.stats.ops_routed
            );
            for k in 0..2000u64 {
                assert!(batched[k as usize], "{read_consistency:?}: lost {k}");
            }
        }
    }
}

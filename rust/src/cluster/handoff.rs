//! Hinted handoff: per-target-node queues of writes that missed a down
//! replica.
//!
//! When a replica write fails because the node is unreachable (breaker
//! open, or transient retries exhausted), the router acknowledges the
//! op anyway if enough *other* replicas took it — but it must not
//! forget the miss, or the recovered node would serve stale answers
//! forever. Instead the miss is queued here as a [`Hint`] and replayed
//! in FIFO order when the node's breaker half-opens.
//!
//! Two details carry the correctness argument of the chaos sweep:
//!
//! - **Sequencing.** Every hint records the cluster op-clock tick of
//!   the op that produced it. On a verified-read disagreement, the
//!   *latest pending hint* for the key is the truth (a pending
//!   `Delete` newer than a pending `Put` means the key is gone — read
//!   repair must not resurrect it).
//! - **Supersession.** When a *direct* op on key `k` later succeeds at
//!   node `n`, all pending `k`-hints at `n` are dropped: the node now
//!   holds newer state than anything the queue could replay, and
//!   replaying a stale `Put` over a fresh `Delete` would resurrect the
//!   key.
//!
//! Capacity is bounded (`[cluster] handoff_capacity`); when a queue is
//! full the *incoming* hint is dropped and counted — losing the newest
//! hint is visible in `hints_dropped`, and the chaos-sweep contract
//! only holds while that counter stays zero.

use std::collections::VecDeque;

/// The replayable payload of a missed replica write.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HintOp {
    Put(u64),
    Delete(u64),
}

impl HintOp {
    pub fn key(&self) -> u64 {
        match *self {
            HintOp::Put(k) | HintOp::Delete(k) => k,
        }
    }
}

/// One missed write: the op plus the cluster-clock tick it happened at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hint {
    pub seq: u64,
    pub op: HintOp,
}

/// Bounded FIFO of hints destined for one node.
#[derive(Debug, Clone, Default)]
pub struct HintQueue {
    hints: VecDeque<Hint>,
    capacity: usize,
}

impl HintQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            hints: VecDeque::new(),
            capacity,
        }
    }

    /// Queue a hint; `false` means the queue is full and the hint was
    /// dropped (caller counts it — the durability contract is void).
    pub fn push(&mut self, seq: u64, op: HintOp) -> bool {
        if self.hints.len() >= self.capacity {
            return false;
        }
        self.hints.push_back(Hint { seq, op });
        true
    }

    pub fn front(&self) -> Option<Hint> {
        self.hints.front().copied()
    }

    pub fn pop(&mut self) -> Option<Hint> {
        self.hints.pop_front()
    }

    pub fn len(&self) -> usize {
        self.hints.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hints.is_empty()
    }

    /// Drop every pending hint for `key` (a newer direct op landed on
    /// the target node). Returns how many were superseded.
    pub fn supersede(&mut self, key: u64) -> usize {
        let before = self.hints.len();
        self.hints.retain(|h| h.op.key() != key);
        before - self.hints.len()
    }

    /// Pending hints in FIFO order. The membership transfer's commit
    /// gate scans this: a range may only hand off once its gainers
    /// hold every dual-applied write, i.e. no hint for a key in the
    /// range is still pending against them.
    pub fn iter(&self) -> impl Iterator<Item = &Hint> {
        self.hints.iter()
    }

    /// Drop every pending hint (the target node left the ring; its
    /// acked state is owned by the new replica set). Returns how many
    /// were retired — the caller counts them so the hint conservation
    /// law stays exact.
    pub fn retire_all(&mut self) -> usize {
        let n = self.hints.len();
        self.hints.clear();
        n
    }

    /// The newest pending hint for `key`, if any — the read-repair
    /// truth source on replica disagreement.
    pub fn latest_for(&self, key: u64) -> Option<Hint> {
        self.hints
            .iter()
            .filter(|h| h.op.key() == key)
            .max_by_key(|h| h.seq)
            .copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity_bound() {
        let mut q = HintQueue::new(2);
        assert!(q.push(1, HintOp::Put(10)));
        assert!(q.push(2, HintOp::Delete(20)));
        assert!(!q.push(3, HintOp::Put(30)), "full: incoming hint dropped");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().op, HintOp::Put(10));
        assert_eq!(q.pop().unwrap().op, HintOp::Delete(20));
        assert!(q.is_empty());
    }

    #[test]
    fn supersede_removes_only_that_key() {
        let mut q = HintQueue::new(8);
        q.push(1, HintOp::Put(10));
        q.push(2, HintOp::Put(20));
        q.push(3, HintOp::Delete(10));
        assert_eq!(q.supersede(10), 2);
        assert_eq!(q.len(), 1);
        assert_eq!(q.front().unwrap().op, HintOp::Put(20));
        assert_eq!(q.supersede(99), 0);
    }

    #[test]
    fn latest_for_picks_highest_seq() {
        let mut q = HintQueue::new(8);
        q.push(1, HintOp::Put(10));
        q.push(5, HintOp::Delete(10));
        q.push(3, HintOp::Put(10));
        let latest = q.latest_for(10).unwrap();
        assert_eq!(latest.seq, 5);
        assert_eq!(latest.op, HintOp::Delete(10), "delete is the truth");
        assert!(q.latest_for(11).is_none());
    }
}

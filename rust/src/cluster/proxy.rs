//! The replica fault seam: every op the router sends to a node flows
//! through a [`ReplicaProxy`], and the proxy consults a [`FaultPlane`]
//! before forwarding.
//!
//! This mirrors the `StoreIo` design one layer down (`store::io`): in
//! production the plane is [`RealProxy`] — zero-cost passthrough, the
//! node is always reachable — while tests and the chaos sweep install a
//! seeded [`FaultSchedule`] that makes the node transiently flaky,
//! latent, or crashed over deterministic windows of the cluster op
//! clock, then permanently healthy ("recovered") past a horizon.
//!
//! The plane decides *reachability*; it never corrupts answers. A node
//! that is reachable gives its true answer, a node that isn't yields a
//! [`ReplicaError`] the router must handle (retry, breaker, hint). That
//! split keeps the chaos-sweep contract crisp: wrong answers can only
//! come from the *router's* merging logic, which is exactly what the
//! sweep is auditing.
//!
//! Determinism: [`FaultPlane::verdict`] is a pure function of
//! `(clock, attempt)`. The same seed and the same op sequence replay
//! bit-identically (proptest P18), exactly like `FaultyIo`'s
//! crash-point enumeration.

use std::fmt;
use std::sync::Arc;

use crate::filter::FilterError;
use crate::store::StorageNode;
use crate::util::SplitMix64;

/// What the fault plane says about one `(clock, attempt)` probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Forward the op; the node answers truthfully.
    Healthy,
    /// The op fails with a retryable error (dropped packet, brief GC
    /// pause). Deeper windows need more attempts than shallow ones.
    Transient,
    /// The op succeeds but takes `us` extra microseconds; if that
    /// exceeds the router's timeout it counts as a transient failure.
    Latent { us: u64 },
    /// The node is down: every attempt fails until the window ends.
    Crashed,
}

/// Why a replica op did not produce an answer.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplicaError {
    /// Retryable: the next attempt may succeed.
    Transient,
    /// The node is unreachable (crashed window or breaker open).
    Down,
    /// The node answered with a refusal of its own (filter full,
    /// degraded read-only mode). The node is *alive* — this must not
    /// trip the breaker.
    Node(FilterError),
}

impl fmt::Display for ReplicaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReplicaError::Transient => write!(f, "transient replica error"),
            ReplicaError::Down => write!(f, "replica down"),
            ReplicaError::Node(e) => write!(f, "replica refused: {e}"),
        }
    }
}

/// Deterministic reachability oracle for one replica.
pub trait FaultPlane: fmt::Debug + Send + Sync {
    /// Verdict for attempt `attempt` of the op at cluster tick `clock`.
    /// Must be pure: same inputs, same verdict, forever.
    fn verdict(&self, clock: u64, attempt: u32) -> Verdict;

    /// One-line description for banners and sweep reports.
    fn describe(&self) -> String;
}

/// Production plane: the node is always reachable.
#[derive(Debug, Clone, Copy, Default)]
pub struct RealProxy;

impl FaultPlane for RealProxy {
    fn verdict(&self, _clock: u64, _attempt: u32) -> Verdict {
        Verdict::Healthy
    }

    fn describe(&self) -> String {
        "real".to_string()
    }
}

/// One fault window over the op clock.
#[derive(Debug, Clone, Copy)]
enum Window {
    /// Fails while `attempt < depth`: a retry budget ≥ depth clears it.
    Transient { depth: u32 },
    /// Adds `us` of synthetic latency per op.
    Latent { us: u64 },
    /// Unreachable for the whole window regardless of retries.
    Crashed,
}

/// A seeded schedule of fault windows: `(start, end, kind)` half-open
/// intervals over the cluster op clock, healthy in the gaps, and
/// permanently healthy (recovered) at `horizon` and beyond — so every
/// schedule eventually lets hint queues drain.
#[derive(Debug, Clone)]
pub struct FaultSchedule {
    windows: Vec<(u64, u64, Window)>,
    horizon: u64,
}

impl FaultSchedule {
    /// Build a schedule from `seed` with expected fault density
    /// `fault_rate` (0.0 = always healthy, 1.0 = nearly always
    /// faulty) over clock ticks `[0, horizon)`.
    pub fn seeded(seed: u64, fault_rate: f64, horizon: u64) -> Self {
        let mut windows = Vec::new();
        if fault_rate > 0.0 {
            let rate = fault_rate.min(0.95);
            let mut rng = SplitMix64::new(seed);
            // expected healthy gap so that window/(window+gap) ≈ rate
            let mean_gap = (12.0 * (1.0 - rate) / rate).max(1.0) as u64;
            let mut cursor = 1 + rng.next_below(mean_gap.max(1)) * 2;
            while cursor < horizon {
                let len = 1 + rng.next_below(24);
                let end = (cursor + len).min(horizon);
                let kind = match rng.next_below(3) {
                    0 => Window::Transient {
                        depth: 1 + rng.next_below(4) as u32,
                    },
                    1 => Window::Latent {
                        us: 50 << rng.next_below(8),
                    },
                    _ => Window::Crashed,
                };
                windows.push((cursor, end, kind));
                cursor = end + 1 + rng.next_below(mean_gap.max(1)) * 2;
            }
        }
        Self { windows, horizon }
    }

    pub fn horizon(&self) -> u64 {
        self.horizon
    }
}

impl FaultPlane for FaultSchedule {
    fn verdict(&self, clock: u64, attempt: u32) -> Verdict {
        if clock >= self.horizon {
            return Verdict::Healthy; // recovered, forever
        }
        for &(start, end, kind) in &self.windows {
            if clock >= start && clock < end {
                return match kind {
                    Window::Transient { depth } if attempt < depth => Verdict::Transient,
                    Window::Transient { .. } => Verdict::Healthy,
                    Window::Latent { us } => Verdict::Latent { us },
                    Window::Crashed => Verdict::Crashed,
                };
            }
        }
        Verdict::Healthy
    }

    fn describe(&self) -> String {
        format!(
            "seeded schedule: {} windows over {} ticks",
            self.windows.len(),
            self.horizon
        )
    }
}

/// Per-op context the router threads through every proxy call.
#[derive(Debug, Clone, Copy)]
pub struct OpCtx {
    /// Cluster op-clock tick of the op (not the attempt).
    pub clock: u64,
    /// Attempt index, 0 = first try (fed by `retry_transient_with`).
    pub attempt: u32,
    /// Latency above this counts as a timeout → transient failure.
    pub timeout_us: u64,
}

/// The seam between the router and one `StorageNode`: consults the
/// fault plane, then forwards. Management-plane access (`node()`,
/// `node_mut()`) bypasses the plane — stats, flushes, and recovery
/// tooling must work even on a "crashed" replica.
#[derive(Debug)]
pub struct ReplicaProxy {
    node: StorageNode,
    plane: Arc<dyn FaultPlane>,
    synthetic_latency_us: u64,
    timeouts: u64,
}

impl ReplicaProxy {
    /// Production proxy: passthrough, always healthy.
    pub fn real(node: StorageNode) -> Self {
        Self::with_plane(node, Arc::new(RealProxy))
    }

    pub fn with_plane(node: StorageNode, plane: Arc<dyn FaultPlane>) -> Self {
        Self {
            node,
            plane,
            synthetic_latency_us: 0,
            timeouts: 0,
        }
    }

    /// Management-plane access (bypasses the fault plane).
    pub fn node(&self) -> &StorageNode {
        &self.node
    }

    /// Management-plane access (bypasses the fault plane).
    pub fn node_mut(&mut self) -> &mut StorageNode {
        &mut self.node
    }

    /// Synthetic latency accumulated from `Latent` verdicts that fit
    /// inside the timeout (the E15 latency signal).
    pub fn synthetic_latency_us(&self) -> u64 {
        self.synthetic_latency_us
    }

    /// `Latent` verdicts that exceeded the timeout and were converted
    /// into transient failures.
    pub fn timeouts(&self) -> u64 {
        self.timeouts
    }

    pub fn plane_describe(&self) -> String {
        self.plane.describe()
    }

    /// Consult the plane; `Err` means the op never reaches the node.
    fn gate(&mut self, ctx: &OpCtx) -> Result<(), ReplicaError> {
        match self.plane.verdict(ctx.clock, ctx.attempt) {
            Verdict::Healthy => Ok(()),
            Verdict::Transient => Err(ReplicaError::Transient),
            Verdict::Latent { us } => {
                if us > ctx.timeout_us {
                    self.timeouts += 1;
                    Err(ReplicaError::Transient) // a timeout is retryable
                } else {
                    self.synthetic_latency_us += us;
                    Ok(())
                }
            }
            Verdict::Crashed => Err(ReplicaError::Down),
        }
    }

    pub fn put(&mut self, ctx: &OpCtx, key: u64) -> Result<(), ReplicaError> {
        self.gate(ctx)?;
        self.node.put(key).map_err(ReplicaError::Node)
    }

    pub fn put_batch(
        &mut self,
        ctx: &OpCtx,
        keys: &[u64],
    ) -> Result<Vec<Result<(), FilterError>>, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.put_batch(keys))
    }

    pub fn get(&mut self, ctx: &OpCtx, key: u64) -> Result<bool, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.get(key))
    }

    pub fn get_batch(&mut self, ctx: &OpCtx, keys: &[u64]) -> Result<Vec<bool>, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.get_batch(keys))
    }

    pub fn delete(&mut self, ctx: &OpCtx, key: u64) -> Result<bool, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.delete(key))
    }

    /// Value write — the receiving side of a membership range stream.
    /// Crosses the fault plane like every replica op, so chaos
    /// schedules can kill the *joiner* mid-transfer.
    pub fn put_value(&mut self, ctx: &OpCtx, key: u64, value: &[u8]) -> Result<(), ReplicaError> {
        self.gate(ctx)?;
        self.node.put_value(key, value).map_err(ReplicaError::Node)
    }

    /// Value read — the donor side of a membership range stream.
    /// `Ok(None)` means the key is no longer live on this replica.
    pub fn get_value(
        &mut self,
        ctx: &OpCtx,
        key: u64,
    ) -> Result<Option<crate::store::Value>, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.get_value(key))
    }

    /// One bounded page of live keys in the token arc `(lo, hi]`,
    /// ascending, strictly after `after` — the donor enumeration step
    /// of the membership transfer. Going through the plane (rather
    /// than the management path) is the point: a crashed donor stalls
    /// the stream exactly like a crashed RPC peer would, and the
    /// transfer must recover when the donor does.
    pub fn stream_page(
        &mut self,
        ctx: &OpCtx,
        lo: u64,
        hi: u64,
        after: Option<u64>,
        limit: usize,
    ) -> Result<Vec<u64>, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.live_keys_in_arc(lo, hi, after, limit))
    }

    pub fn delete_batch(&mut self, ctx: &OpCtx, keys: &[u64]) -> Result<Vec<bool>, ReplicaError> {
        self.gate(ctx)?;
        Ok(self.node.delete_batch(keys))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_proxy_is_always_healthy() {
        let p = RealProxy;
        for clock in 0..100 {
            assert_eq!(p.verdict(clock, 0), Verdict::Healthy);
        }
    }

    #[test]
    fn schedule_is_deterministic_and_recovers_at_horizon() {
        let a = FaultSchedule::seeded(42, 0.3, 500);
        let b = FaultSchedule::seeded(42, 0.3, 500);
        for clock in 0..600 {
            for attempt in 0..4 {
                assert_eq!(a.verdict(clock, attempt), b.verdict(clock, attempt));
            }
        }
        for clock in 500..600 {
            assert_eq!(a.verdict(clock, 0), Verdict::Healthy, "past horizon");
        }
        // a non-trivial rate must actually produce faults
        let faults = (0..500)
            .filter(|&c| a.verdict(c, 0) != Verdict::Healthy)
            .count();
        assert!(faults > 0, "rate 0.3 over 500 ticks produced no faults");
    }

    #[test]
    fn zero_rate_schedule_never_faults() {
        let s = FaultSchedule::seeded(7, 0.0, 1000);
        for clock in 0..1000 {
            assert_eq!(s.verdict(clock, 0), Verdict::Healthy);
        }
    }

    #[test]
    fn transient_windows_clear_with_enough_attempts() {
        // depth ≤ 4 by construction, so attempt 4 is always past it
        let s = FaultSchedule::seeded(11, 0.5, 300);
        for clock in 0..300 {
            match s.verdict(clock, 0) {
                Verdict::Transient => {
                    assert_eq!(s.verdict(clock, 4), Verdict::Healthy);
                }
                Verdict::Crashed => {
                    assert_eq!(s.verdict(clock, 4), Verdict::Crashed, "retries can't fix a crash");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn latent_verdict_times_out_or_accumulates() {
        let node = StorageNode::new(crate::store::NodeConfig::default());
        #[derive(Debug)]
        struct AlwaysLatent(u64);
        impl FaultPlane for AlwaysLatent {
            fn verdict(&self, _c: u64, _a: u32) -> Verdict {
                Verdict::Latent { us: self.0 }
            }
            fn describe(&self) -> String {
                "latent".into()
            }
        }
        let mut p = ReplicaProxy::with_plane(node, Arc::new(AlwaysLatent(100)));
        let fits = OpCtx { clock: 0, attempt: 0, timeout_us: 200 };
        assert_eq!(p.get(&fits, 1).unwrap(), false);
        assert_eq!(p.synthetic_latency_us(), 100);
        assert_eq!(p.timeouts(), 0);

        let exceeds = OpCtx { clock: 0, attempt: 0, timeout_us: 50 };
        assert_eq!(p.get(&exceeds, 1), Err(ReplicaError::Transient));
        assert_eq!(p.timeouts(), 1);
        assert_eq!(p.synthetic_latency_us(), 100, "timed-out latency not accumulated");
    }
}

//! `ocf` — the leader binary: experiments, the ingest pipeline, and a
//! line-protocol membership server.
//!
//! ```text
//! ocf exp <table1|fig2|fig3|sweep|safety|burst|cartesian|ablation|sharded|probe|pool|kernel|persist|adaptive|chaos|membership|all>
//!         [--scale F]           # workload scale, 1.0 = paper scale
//! ocf pipeline [--ops N] [--batch N] [--artifacts DIR] [--threads]
//!              [--shards N]     # >1 = sharded concurrent filter front-end
//!              [--backend NAME] # any FilterBuilder backend, trait-generic path
//!              [--workers N]    # persistent worker-pool mode (0 = auto);
//!              [--queue-depth N] [--chunk N]   # pool backpressure + task grain
//! ocf serve [--config FILE] [--set section.key=value ...]
//!           # filter backend from [filter] backend = "..." / --set filter.backend=...
//!           # pooled ingest shape from [pipeline] workers/queue_depth/chunk_size
//!           # [store] persist_dir = "DIR" (or --set store.persist_dir=DIR) serves a
//!           # crash-recoverable StorageNode: recovery at startup, `flush` command,
//!           # exact found/absent answers, recovery counters in banner + stats
//! ocf tune [--keys N] [--probes N]
//!           # probe-engine microbench: kernel × prefetch-depth grid + the
//!           # OCF_SIMD / OCF_PREFETCH_DEPTH exports to pin the winner
//! ocf info [--artifacts DIR]
//! ```
//!
//! (Argument parsing is hand-rolled — the offline environment has no
//! clap; see DESIGN.md §substitutions.)

use ocf::bench_harness;
use ocf::config::OcfFileConfig;
use ocf::exp::{self, Scale};
use ocf::filter::{FilterBuilder, MembershipFilter, Ocf};
use ocf::pipeline::{BatchPolicy, IngestPipeline, PoolConfig};
use ocf::runtime::{HashExecutor, PjrtEngine};
use ocf::workload::{KeyDist, MixGenerator, OpMix};
use std::io::{BufRead, Write};
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("exp") => cmd_exp(&args[1..]),
        Some("pipeline") => cmd_pipeline(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("tune") => cmd_tune(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "ocf — Optimized Cuckoo Filter coordinator\n\n\
         commands:\n  \
         exp <name|all> [--scale F]   regenerate paper tables/figures\n  \
         pipeline [--ops N] [--batch N] [--artifacts DIR] [--threads] [--shards N] [--backend NAME]\n           \
         [--workers N] [--queue-depth N] [--chunk N]   worker-pool ingest (0 = auto workers)\n  \
         serve [--config FILE] [--set section.key=value]   (--set store.persist_dir=DIR = durable node mode)\n  \
         tune [--keys N] [--probes N]   probe-kernel × prefetch-depth microbench\n  \
         info [--artifacts DIR]\n  \
         help"
    );
}

/// Pull `--flag value` out of an arg list.
fn flag_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1).cloned())
}

fn flag_present(args: &[String], flag: &str) -> bool {
    args.iter().any(|a| a == flag)
}

fn cmd_exp(args: &[String]) -> i32 {
    let name = match args.first() {
        Some(n) if !n.starts_with("--") => n.clone(),
        _ => {
            eprintln!("usage: ocf exp <name|all> [--scale F]");
            return 2;
        }
    };
    let scale = flag_value(args, "--scale")
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0);
    match exp::run(&name, Scale(scale)) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_pipeline(args: &[String]) -> i32 {
    let ops: usize = flag_value(args, "--ops")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000);
    let batch: usize = flag_value(args, "--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1024);
    let artifacts = flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    let threaded = flag_present(args, "--threads");
    let shards: usize = flag_value(args, "--shards")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    if let Some(raw) = flag_value(args, "--workers") {
        // Persistent worker-pool mode: --backend sharded (or none) runs
        // the native shard-group dispatch; any other backend is
        // mutex-wrapped and chunk-parallel.
        let workers = match raw.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                eprintln!("pipeline: --workers must be a non-negative integer (0 = auto), got '{raw}'");
                return 2;
            }
        };
        let pool = PoolConfig {
            workers,
            queue_depth: flag_value(args, "--queue-depth")
                .and_then(|s| s.parse().ok())
                .unwrap_or(PoolConfig::default().queue_depth),
            chunk: flag_value(args, "--chunk")
                .and_then(|s| s.parse().ok())
                .unwrap_or(PoolConfig::default().chunk),
        };
        if threaded {
            eprintln!("pipeline: --threads is ignored with --workers (the pool is the parallelism)");
        }
        return cmd_pipeline_pooled(
            flag_value(args, "--backend").as_deref(),
            ops,
            batch,
            shards,
            pool,
        );
    }

    if let Some(backend) = flag_value(args, "--backend") {
        // Trait-generic path: any builder backend through the batched
        // pipeline (native hashing inside the filter's engine).
        if flag_value(args, "--artifacts").is_some() {
            eprintln!("pipeline: --artifacts is ignored with --backend (trait path hashes natively)");
        }
        return cmd_pipeline_backend(&backend, ops, batch, shards);
    }

    if shards > 1 {
        if flag_value(args, "--artifacts").is_some() {
            eprintln!("pipeline: --artifacts is ignored with --shards (sharded path is native-hash)");
        }
        if threaded {
            eprintln!("pipeline: --threads is ignored with --shards (parallelism comes from the per-shard fan-out)");
        }
        return cmd_pipeline_sharded(ops, batch, shards);
    }

    let mut filter = Ocf::new(ocf::filter::OcfConfig::default());
    let executor = match PjrtEngine::load_dir(&artifacts) {
        Ok(Some(engine)) => {
            let engine = Arc::new(engine);
            eprintln!(
                "pipeline: XLA path via {} ({:?})",
                engine.platform(),
                engine.artifact_names()
            );
            HashExecutor::with_engine(engine, filter.hasher())
        }
        Ok(None) => {
            eprintln!("pipeline: no artifacts in '{artifacts}', native hash path");
            HashExecutor::native(filter.hasher())
        }
        Err(e) => {
            eprintln!("pipeline: artifact load failed ({e}), native hash path");
            HashExecutor::native(filter.hasher())
        }
    };
    let mut pipeline = IngestPipeline::new(
        BatchPolicy {
            max_batch: batch,
            ..BatchPolicy::default()
        },
        executor,
    );
    let mut gen = MixGenerator::new(
        KeyDist::uniform(1 << 40),
        OpMix::new(0.5, 0.4, 0.1),
        0x0CF_11FE,
    );
    let report = if threaded {
        let mut left = ops;
        pipeline.run_threaded(
            move || {
                if left == 0 {
                    None
                } else {
                    left -= 1;
                    Some(gen.next_op())
                }
            },
            &mut filter,
            64,
            batch,
        )
    } else {
        let ops_iter = (0..ops).map(move |_| gen.next_op());
        // executor-hashed Ocf path (XLA artifact when loaded)
        pipeline.run_hashed(ops_iter, &mut filter)
    };
    println!("{}", report.render());
    println!(
        "filter: len={} capacity={} occupancy={:.3} memory={} resizes={}",
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        ocf::util::fmt_bytes(filter.memory_bytes()),
        filter.stats().resizes(),
    );
    let _ = bench_harness::render_table; // referenced by benches
    0
}

/// Trait-generic pipeline: any [`FilterBuilder`] backend by name
/// through `IngestPipeline::run` (engine-backed filters use their
/// prefetch pipeline, baselines the default scalar batch impls).
fn cmd_pipeline_backend(backend: &str, ops: usize, batch: usize, shards: usize) -> i32 {
    let builder = match FilterBuilder::named(backend) {
        // --shards only overrides when given (> 1); "sharded" keeps
        // its own default shard count otherwise
        Ok(b) if shards > 1 => b.with_shards(shards),
        Ok(b) => b,
        Err(e) => {
            eprintln!("pipeline: {e}");
            return 2;
        }
    };
    let mut filter = match builder.build() {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pipeline: {e}");
            return 2;
        }
    };
    // The trait-generic path hashes inside the filter's own batched
    // engine; the executor is unused here, so build it from a bare
    // Hasher instead of a throwaway filter.
    let hasher = ocf::filter::Hasher::new(builder.ocf.seed, builder.ocf.fp_bits);
    let mut pipeline = IngestPipeline::new(
        BatchPolicy {
            max_batch: batch,
            ..BatchPolicy::default()
        },
        HashExecutor::native(hasher),
    );
    let mut gen = MixGenerator::new(
        KeyDist::uniform(1 << 40),
        OpMix::new(0.5, 0.4, 0.1),
        0x0CF_11FE,
    );
    let ops_iter = (0..ops).map(move |_| gen.next_op());
    let report = pipeline.run(ops_iter, &mut filter);
    println!("{}", report.render());
    println!(
        "filter[{}]: len={} capacity={} occupancy={:.3} memory={} resizes={}",
        filter.name(),
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        ocf::util::fmt_bytes(filter.memory_bytes()),
        filter.stats().resizes(),
    );
    0
}

/// Pipeline against the sharded concurrent front-end (native hash path;
/// shard routing needs the triple anyway, and the parallel apply stage
/// is the thing being exercised here).
fn cmd_pipeline_sharded(ops: usize, batch: usize, shards: usize) -> i32 {
    let filter = ocf::filter::ShardedOcf::with_shards(shards, ocf::filter::OcfConfig::default());
    let mut pipeline = IngestPipeline::new(
        BatchPolicy {
            max_batch: batch,
            ..BatchPolicy::default()
        },
        HashExecutor::native(filter.hasher()),
    );
    let mut gen = MixGenerator::new(
        KeyDist::uniform(1 << 40),
        OpMix::new(0.5, 0.4, 0.1),
        0x0CF_11FE,
    );
    let ops_iter = (0..ops).map(move |_| gen.next_op());
    let report = pipeline.run_sharded(ops_iter, &filter);
    println!("{}", report.render());
    println!(
        "sharded filter: shards={} len={} capacity={} occupancy={:.3} memory={} resizes={}",
        filter.shard_count(),
        filter.len(),
        filter.capacity(),
        filter.occupancy(),
        ocf::util::fmt_bytes(filter.memory_bytes()),
        filter.stats().resizes(),
    );
    0
}

/// Worker-pool pipeline (`--workers`): long-lived shard/chunk workers
/// with the producer hashing batch N+1 while batch N applies. The
/// sharded backend takes the native per-shard dispatch; any other
/// builder backend runs mutex-wrapped with chunk-parallel same-kind
/// runs.
fn cmd_pipeline_pooled(
    backend: Option<&str>,
    ops: usize,
    batch: usize,
    shards: usize,
    pool: PoolConfig,
) -> i32 {
    let policy = BatchPolicy {
        max_batch: batch,
        ..BatchPolicy::default()
    };
    let mut gen = MixGenerator::new(
        KeyDist::uniform(1 << 40),
        OpMix::new(0.5, 0.4, 0.1),
        0x0CF_11FE,
    );
    let ops_iter = (0..ops).map(move |_| gen.next_op());
    match backend {
        None | Some("sharded") => {
            // Native path: default the shard count to the worker count
            // so every worker owns at least one stripe.
            let nshards = if shards > 1 {
                shards
            } else {
                pool.effective_workers()
            };
            let filter =
                ocf::filter::ShardedOcf::with_shards(nshards, ocf::filter::OcfConfig::default());
            let mut pipeline =
                IngestPipeline::new(policy, HashExecutor::native(filter.hasher()));
            let report = pipeline.run_pooled(ops_iter, &filter, &pool);
            println!("{}", report.render());
            println!(
                "pooled sharded filter: {} | shards={} len={} occupancy={:.3} memory={} resizes={}",
                pool.describe(),
                filter.shard_count(),
                filter.len(),
                filter.occupancy(),
                ocf::util::fmt_bytes(filter.memory_bytes()),
                filter.stats().resizes(),
            );
            0
        }
        Some(name) => {
            let builder = match FilterBuilder::named(name) {
                Ok(b) if shards > 1 => b.with_shards(shards),
                Ok(b) => b,
                Err(e) => {
                    eprintln!("pipeline: {e}");
                    return 2;
                }
            };
            let inner = match builder.build() {
                Ok(f) => f,
                Err(e) => {
                    eprintln!("pipeline: {e}");
                    return 2;
                }
            };
            let filter = ocf::filter::MutexFilter::new(inner);
            let hasher = ocf::filter::Hasher::new(builder.ocf.seed, builder.ocf.fp_bits);
            let mut pipeline = IngestPipeline::new(policy, HashExecutor::native(hasher));
            let report = pipeline.run_pooled(ops_iter, &filter, &pool);
            println!("{}", report.render());
            let (name, len, occupancy, memory) = filter.with_inner(|f| {
                (f.name(), f.len(), f.occupancy(), f.memory_bytes())
            });
            println!(
                "pooled mutex<{}> filter: {} | len={} occupancy={:.3} memory={}",
                name,
                pool.describe(),
                len,
                occupancy,
                ocf::util::fmt_bytes(memory),
            );
            0
        }
    }
}

/// Explicit probe-engine tuning: run the kernel × prefetch-depth
/// microbench grid ([`ocf::filter::tune::microbench`]) and print the
/// winner plus the env exports that pin it (`OCF_TUNE=1` runs the same
/// sweep implicitly at first engine entry).
fn cmd_tune(args: &[String]) -> i32 {
    let keys: usize = flag_value(args, "--keys")
        .and_then(|s| s.parse().ok())
        .unwrap_or(ocf::filter::tune::DEFAULT_KEYS);
    let probes: usize = flag_value(args, "--probes")
        .and_then(|s| s.parse().ok())
        .unwrap_or(ocf::filter::tune::DEFAULT_PROBES);
    if keys == 0 || probes == 0 {
        eprintln!("tune: --keys and --probes must be positive");
        return 2;
    }
    let floor = 4 * ocf::filter::tune::DEPTH_GRID[ocf::filter::tune::DEPTH_GRID.len() - 1];
    let probes = if probes < floor {
        eprintln!(
            "tune: --probes {probes} raised to {floor} (deep grid cells need \
             batches longer than the pipeline depth to measure anything)"
        );
        floor
    } else {
        probes
    };
    let available: Vec<&str> = ocf::filter::kernel::available()
        .iter()
        .map(|k| k.name())
        .collect();
    eprintln!(
        "ocf tune: sweeping {{{}}} × depths {:?} ({keys} keys, {probes} probes/cell)",
        available.join("|"),
        ocf::filter::tune::DEPTH_GRID
    );
    let outcome = ocf::filter::tune::microbench(keys, probes);
    println!("{}", ocf::filter::tune::render(&outcome));
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let cfg_text = flag_value(args, "--config")
        .map(|p| std::fs::read_to_string(&p).unwrap_or_else(|e| {
            eprintln!("warning: cannot read config '{p}': {e}; using defaults");
            String::new()
        }))
        .unwrap_or_default();
    let overrides: Vec<String> = args
        .iter()
        .enumerate()
        .filter(|(_, a)| *a == "--set")
        .filter_map(|(i, _)| args.get(i + 1).cloned())
        .collect();
    let cfg = match OcfFileConfig::load(&cfg_text, &overrides) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    // Persistent node mode: with [store] persist_dir set the server is
    // a full StorageNode recovered from disk (memtable + SSTables +
    // mmap-served frozen filters), not a bare filter.
    if cfg.node.persist_dir.is_some() {
        return cmd_serve_node(cfg);
    }
    eprintln!(
        "ocf serve: filter={} capacity={} fp_feedback={} \
         (line protocol: put K | get K | del K | stats | quit)",
        cfg.filter.describe(),
        cfg.filter.ocf.initial_capacity,
        // bare-filter mode has no ground truth to prove an FP against,
        // so adaptive backends only learn here if an embedder reports
        if cfg.filter.describe().contains("adaptive") { "available" } else { "off" },
    );
    eprintln!(
        "ocf serve: [pipeline] batch={} {} (validated here; consumed by \
         `ocf pipeline --workers` and run_pooled embedders — this \
         line-protocol loop applies ops one at a time)",
        cfg.batch_size,
        cfg.pool().describe()
    );
    // Probe-engine dispatch: resolved once here (this is the "first
    // engine entry" an OCF_TUNE startup auto-tune hangs off).
    let engine = ocf::filter::kernel::engine_info();
    eprintln!(
        "ocf serve: probe engine kernel={} prefetch_depth={}{} \
         (override: OCF_SIMD=scalar|swar|sse2|avx2|neon, OCF_PREFETCH_DEPTH=1..64, \
         OCF_TUNE=1 auto-tunes both; see `ocf tune`)",
        engine.kernel,
        engine.prefetch_depth,
        if engine.tuned { " [auto-tuned]" } else { "" }
    );
    // Any backend by name, through the trait object (`[filter]
    // backend = "..."` / `--set filter.backend=...`).
    let mut filter = match cfg.filter.build() {
        Ok(f) => f,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let mut parts = line.split_whitespace();
        let reply = match (parts.next(), parts.next()) {
            (Some("put"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => match filter.insert(k) {
                    Ok(()) => "ok".to_string(),
                    Err(e) => format!("err {e}"),
                },
                Err(_) => "err bad-key".into(),
            },
            (Some("get"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => if filter.contains(k) { "maybe" } else { "absent" }.to_string(),
                Err(_) => "err bad-key".into(),
            },
            (Some("del"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => if filter.delete(k) { "ok" } else { "rejected" }.to_string(),
                Err(_) => "err bad-key".into(),
            },
            (Some("stats"), _) => format!(
                "len={} capacity={} occupancy={:.3} resizes={} \
                 fp_observed={} fp_remapped={} fp_suppressed={}",
                filter.len(),
                filter.capacity(),
                filter.occupancy(),
                filter.stats().resizes(),
                filter.stats().fp_observed,
                filter.stats().fp_remapped,
                filter.stats().fp_suppressed,
            ),
            (Some("quit"), _) => break,
            _ => "err unknown-command".into(),
        };
        if writeln!(out, "{reply}").is_err() {
            break;
        }
    }
    0
}

/// `ocf serve` with `[store] persist_dir`: a crash-recoverable storage
/// node. Recovery happens before the banner so the recovered/rebuilt
/// counts are visible at startup; `get` answers are exact
/// (found/absent), and `flush` forces the memtable durable on demand
/// (the crash-recovery CI smoke drives exactly this protocol).
fn cmd_serve_node(cfg: OcfFileConfig) -> i32 {
    use ocf::store::{FlushReason, StorageNode};
    let dir = cfg.node.persist_dir.clone().unwrap_or_default();
    let mut node = match StorageNode::recover(cfg.node.clone()) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("ocf serve: cannot open persist_dir '{dir}': {e}");
            return 2;
        }
    };
    eprintln!(
        "ocf serve: node mode, persist_dir={dir} filter={} fp_feedback={} wal={} fsync={} \
         degraded={} (line protocol: put K | get K | del K | flush | compact | stats | quit)",
        cfg.filter.describe(),
        // the node read path reports ground-truth FPs to the filter;
        // adaptive backends remap on report, the rest no-op it
        if cfg.filter.describe().contains("adaptive") { "adaptive" } else { "no-op" },
        if node.wal().is_some() { "on" } else { "off" },
        cfg.node.wal.fsync.describe(),
        // flips true (and writes start refusing, loudly) if a WAL
        // append ever hits ENOSPC — read-only degraded mode
        node.stats.degraded(),
    );
    eprintln!(
        "ocf serve: cluster policy: read={} write={} retry_budget={} timeout_us={} \
         breaker=threshold:{}/cooldown:{}/probes:{} handoff_capacity={} transfer_batch={}",
        cfg.read_consistency.as_str(),
        cfg.write_consistency.as_str(),
        cfg.resilience.retry_budget,
        cfg.resilience.timeout_us,
        cfg.resilience.breaker.threshold,
        cfg.resilience.breaker.cooldown,
        cfg.resilience.breaker.probes,
        cfg.resilience.handoff_capacity,
        cfg.resilience.transfer_batch,
    );
    eprintln!(
        "ocf serve: recovery: sstables={} filters_recovered={} filters_rebuilt={} \
         filter_recovery_rejected={} wal_replayed={} wal_torn_tail={} live_keys={}",
        node.sstable_count(),
        node.stats.filters_recovered(),
        node.stats.filters_rebuilt(),
        node.stats.filter_recovery_rejected(),
        node.stats.wal_replayed(),
        node.stats.wal_torn_tail(),
        node.live_keys(),
    );
    let engine = ocf::filter::kernel::engine_info();
    eprintln!(
        "ocf serve: probe engine kernel={} prefetch_depth={} (frozen filters probe \
         through the same dispatch, heap- or mmap-backed)",
        engine.kernel, engine.prefetch_depth,
    );
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    for line in stdin.lock().lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let mut parts = line.split_whitespace();
        let reply = match (parts.next(), parts.next()) {
            (Some("put"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => match node.put(k) {
                    Ok(()) => "ok".to_string(),
                    Err(e) => format!("err {e}"),
                },
                Err(_) => "err bad-key".into(),
            },
            (Some("get"), Some(k)) => match k.parse::<u64>() {
                // node answers are exact (filter + memtable + SSTables)
                Ok(k) => if node.get(k) { "found" } else { "absent" }.to_string(),
                Err(_) => "err bad-key".into(),
            },
            (Some("del"), Some(k)) => match k.parse::<u64>() {
                Ok(k) => if node.delete(k) { "ok" } else { "rejected" }.to_string(),
                Err(_) => "err bad-key".into(),
            },
            (Some("flush"), _) => {
                if node.memtable_len() == 0 {
                    "ok empty".to_string()
                } else {
                    node.flush(FlushReason::MemtableKeys);
                    format!("ok sstables={}", node.sstable_count())
                }
            }
            (Some("compact"), _) => {
                node.compact();
                format!("ok sstables={}", node.sstable_count())
            }
            (Some("stats"), _) => format!(
                "live_keys={} memtable={} sstables={} flushes={} compactions={} \
                 filters_recovered={} filters_rebuilt={} filter_recovery_rejected={} \
                 wal_appends={} wal_replayed={} wal_torn_tail={} wal_append_failed={} \
                 io_retries={} fp_observed={} fp_remapped={} fp_suppressed={} degraded={}",
                node.live_keys(),
                node.memtable_len(),
                node.sstable_count(),
                node.stats.flushes,
                node.stats.compactions,
                node.stats.filters_recovered(),
                node.stats.filters_rebuilt(),
                node.stats.filter_recovery_rejected(),
                node.stats.wal_appends(),
                node.stats.wal_replayed(),
                node.stats.wal_torn_tail(),
                node.stats.wal_append_failed(),
                node.stats.io_retries(),
                node.stats.fp_observed(),
                node.stats.fp_remapped(),
                node.fp_suppressed(),
                node.stats.degraded(),
            ),
            (Some("quit"), _) => break,
            _ => "err unknown-command".into(),
        };
        if writeln!(out, "{reply}").is_err() {
            break;
        }
    }
    0
}

fn cmd_info(args: &[String]) -> i32 {
    let artifacts = flag_value(args, "--artifacts").unwrap_or_else(|| "artifacts".into());
    println!("ocf {} — Optimized Cuckoo Filter", env!("CARGO_PKG_VERSION"));
    match PjrtEngine::load_dir(&artifacts) {
        Ok(Some(engine)) => {
            println!("pjrt platform: {}", engine.platform());
            println!("artifacts ({}):", artifacts);
            for name in engine.artifact_names() {
                println!("  {name}");
            }
        }
        Ok(None) => println!("no artifacts in '{artifacts}' (run `make artifacts`)"),
        Err(e) => println!("artifact load error: {e}"),
    }
    0
}

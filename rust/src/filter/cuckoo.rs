//! The traditional partial-key cuckoo filter (Fan et al., CoNEXT'14).
//!
//! This is both the substrate OCF wraps and the paper's main baseline.
//! It deliberately reproduces the two failure modes the paper calls out:
//!
//! 1. **Fills up** — fixed capacity; once max displacements are
//!    exhausted, inserts fail (`FilterError::Full`). With
//!    [`VictimPolicy::Drop`] the in-flight evicted fingerprint is lost,
//!    which manifests as a *false negative* for whichever resident key
//!    owned it — the paper: "We observed an occasional false negative
//!    when operating at this threshold [load > 0.9]".
//!    [`VictimPolicy::Stash`] instead parks it in a victim cache (what
//!    Fan's reference implementation does).
//! 2. **Unsafe deletes** — `delete` removes a matching fingerprint
//!    even if the key was never inserted, silently evicting another
//!    key's fingerprint (paper §IV). OCF fixes this with verified
//!    deletes; the raw filter exposes it so experiments can measure it.

use super::bucket::{BucketTable, FlatTable, SLOTS};
use super::fingerprint::{Hasher, HashTriple};
use super::metrics::FilterStats;
use super::session::ProbeSession;
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};
use crate::util::SplitMix64;
use std::collections::VecDeque;

/// Default software-pipeline depth of the batched probe engine: while
/// key `i` resolves, the primary bucket of key `i + PREFETCH_DEPTH` is
/// being prefetched (and alternate buckets of recent primary misses are
/// in flight). ~8 keeps that many independent cache misses outstanding —
/// about what one core's miss-handling registers sustain — without
/// thrashing L1. See `rust/src/filter/README.md` for tuning notes.
///
/// Engine entry points call [`prefetch_depth`] instead of this constant
/// so the depth can be retuned per process without a rebuild.
pub const PREFETCH_DEPTH: usize = 8;

static DEPTH_OVERRIDE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();

/// Parse + validate an `OCF_PREFETCH_DEPTH` value: accepted depths are
/// clamped into `1..=64` and rounded up to a power of two (the engine's
/// windowing math assumes nothing, but pow2 keeps depths comparable
/// across benches and avoids silly odd pipelines). `None` = invalid.
fn parse_depth(s: &str) -> Option<usize> {
    match s.trim().parse::<usize>() {
        Ok(d) if d >= 1 => Some(d.min(64).next_power_of_two().min(64)),
        _ => None,
    }
}

/// Effective probe-pipeline depth for this process: [`PREFETCH_DEPTH`]
/// unless the `OCF_PREFETCH_DEPTH` environment variable overrides it
/// (validated and power-of-two-clamped into `1..=64`; an unparsable
/// value falls back with a one-time stderr warning — env mistakes are
/// never swallowed silently), or — with the env unset and `OCF_TUNE`
/// set — the startup auto-tuner's winner ([`super::tune::auto_tune`]).
/// Read once and cached, so the engine's hot loops pay a single atomic
/// load. See `rust/src/filter/README.md` ("The prefetch depth knob").
#[inline]
pub fn prefetch_depth() -> usize {
    *DEPTH_OVERRIDE.get_or_init(|| match std::env::var("OCF_PREFETCH_DEPTH") {
        Ok(s) => parse_depth(&s).unwrap_or_else(|| {
            eprintln!(
                "OCF_PREFETCH_DEPTH='{s}' invalid (want an integer in 1..=64); \
                 using default {PREFETCH_DEPTH}"
            );
            PREFETCH_DEPTH
        }),
        Err(_) if super::tune::requested() => {
            let depth = super::tune::auto_tune().depth;
            super::tune::mark_applied();
            depth
        }
        Err(_) => PREFETCH_DEPTH,
    })
}

/// What to do with the evicted fingerprint when an insert exhausts its
/// displacement budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Park it in a one-slot victim cache, checked by `contains`
    /// (Fan et al. reference behaviour). Insert still reports `Full`.
    /// The caller's fingerprint stays resident even though the insert
    /// reported failure — callers that track keys authoritatively
    /// (OCF) want [`VictimPolicy::Rollback`] instead.
    Stash,
    /// Drop it (naive implementations; yields false negatives — the
    /// paper's observed failure mode at high load).
    Drop,
    /// Undo the whole eviction walk: a failed insert leaves the table
    /// bit-identical to its pre-call state (no resident caller
    /// fingerprint, no lost victim). This is the policy OCF uses so a
    /// keystore rollback after `Err(Full)` cannot strand a phantom
    /// fingerprint.
    Rollback,
}

/// Construction parameters for the raw cuckoo filter.
#[derive(Debug, Clone, Copy)]
pub struct CuckooParams {
    /// Requested slot capacity `c` (`nbuckets = ceil(c / SLOTS)`,
    /// exact — see `CuckooFilter::new`).
    pub capacity: usize,
    /// Fingerprint width in bits (paper §II.B "Fingerprint Size").
    pub fp_bits: u32,
    /// Max displacement steps before declaring the filter full
    /// (paper §II.B "Max Displacements"; Fan et al. use 500).
    pub max_displacements: u32,
    /// Hash seed for this instance.
    pub seed: u64,
    /// Victim handling on insert failure.
    pub victim_policy: VictimPolicy,
}

impl Default for CuckooParams {
    fn default() -> Self {
        Self {
            capacity: 1 << 16,
            fp_bits: 16,
            max_displacements: 500,
            seed: 0x0C_F0_0D,
            victim_policy: VictimPolicy::Stash,
        }
    }
}

/// Traditional cuckoo filter over a pluggable bucket table.
#[derive(Debug, Clone)]
pub struct CuckooFilter<T: BucketTable = FlatTable> {
    table: T,
    hasher: Hasher,
    len: usize,
    max_displacements: u32,
    victim_policy: VictimPolicy,
    /// Victim cache: (bucket_index, fingerprint) parked by a failed insert.
    victim: Option<(usize, u32)>,
    /// Deterministic eviction-victim chooser.
    evict_rng: SplitMix64,
    pub stats: FilterStats,
    params: CuckooParams,
}

impl<T: BucketTable> CuckooFilter<T> {
    pub fn new(params: CuckooParams) -> Self {
        Self::with_kernel(params, super::kernel::active())
    }

    /// [`CuckooFilter::new`] with an explicit probe kernel instead of
    /// the process-wide dispatch choice — the constructor the startup
    /// auto-tuner, the E12 kernel experiment and proptest P14 use to
    /// pin a variant per instance. All kernels are observationally
    /// identical (P14), so this never changes answers, only speed.
    pub fn with_kernel(params: CuckooParams, kernel: &'static super::kernel::ProbeKernel) -> Self {
        // Exact sizing: nbuckets = ceil(c / SLOTS), NOT rounded to a
        // power of two — OCF's resize policies hand down fine-grained
        // capacity targets (EOF: c + cα) and rounding would quantize
        // them back into doubling. Power-of-two sizes still get the
        // xor fast path in the hasher automatically.
        let nbuckets = crate::util::ceil_div(params.capacity.max(SLOTS), SLOTS);
        Self {
            table: T::with_buckets_kernel(nbuckets, params.fp_bits, kernel),
            hasher: Hasher::new(params.seed, params.fp_bits),
            len: 0,
            max_displacements: params.max_displacements,
            victim_policy: params.victim_policy,
            victim: None,
            evict_rng: SplitMix64::new(params.seed ^ 0xE71C_7ED0),
            stats: FilterStats::new(),
            params,
        }
    }

    /// Wrap an already-populated table as a probe-only filter: no
    /// victim cache, zero displacement budget, `len` as recorded by the
    /// producer. This is how frozen tables ([`super::frozen::FrozenTable`])
    /// get the real batch engine — `contains_triple`'s fused pair
    /// compare and the prefetch-pipelined `contains_triples_into` run
    /// unchanged over the read-only table. The caller must pass the
    /// `hasher` the table was built with (same seed and fingerprint
    /// width), or probes are meaningless.
    pub fn probe_only(table: T, hasher: Hasher, len: usize) -> Self {
        debug_assert_eq!(
            hasher.fp_mask.count_ones(),
            table.fp_bits(),
            "hasher fingerprint width must match the table's"
        );
        let params = CuckooParams {
            capacity: table.nbuckets() * SLOTS,
            fp_bits: table.fp_bits(),
            max_displacements: 0,
            seed: hasher.seed,
            victim_policy: VictimPolicy::Rollback,
        };
        Self {
            table,
            hasher,
            len,
            max_displacements: 0,
            victim_policy: VictimPolicy::Rollback,
            victim: None,
            evict_rng: SplitMix64::new(params.seed ^ 0xE71C_7ED0),
            stats: FilterStats::new(),
            params,
        }
    }

    pub fn params(&self) -> &CuckooParams {
        &self.params
    }

    /// The probe kernel this filter's table scans with.
    pub fn kernel(&self) -> &'static super::kernel::ProbeKernel {
        self.table.kernel()
    }

    /// Read-only view of the underlying bucket table (the
    /// kernel-differential tests feed its raw bucket views to every
    /// kernel's primitives).
    pub fn table(&self) -> &T {
        &self.table
    }

    pub fn hasher(&self) -> Hasher {
        self.hasher
    }

    pub fn nbuckets(&self) -> usize {
        self.table.nbuckets()
    }

    /// Serialize to the frozen layout consumed by the XLA probe kernel
    /// and by SSTable filters.
    pub fn to_frozen(&self) -> Vec<u32> {
        self.table.to_frozen()
    }

    /// Insert a pre-hashed triple. Exposed so OCF's rebuild and the
    /// batched ingest path (which gets triples from the XLA artifact)
    /// skip re-hashing.
    pub fn insert_triple(&mut self, t: HashTriple) -> Result<(), FilterError> {
        let nb = self.table.nbuckets();
        let i1 = Hasher::primary_index(t, nb);
        let i2 = Hasher::alt_index(i1, t.fp, nb);

        if self.table.try_insert(i1, t.fp) || self.table.try_insert(i2, t.fp) {
            self.len += 1;
            self.stats.inserts += 1;
            return Ok(());
        }

        // Random-walk eviction from a random candidate bucket.
        let mut b = if self.evict_rng.next_u64() & 1 == 0 { i1 } else { i2 };
        let mut fp = t.fp;
        // Under Rollback every swap is journaled as (bucket, slot,
        // evicted_fp) so a failed walk can be unwound; the other
        // policies skip the journal (and keep their lossy semantics).
        let rollback = self.victim_policy == VictimPolicy::Rollback;
        let mut walk: Vec<(usize, usize, u32)> = Vec::new();
        for _ in 0..self.max_displacements {
            let s = self.evict_rng.next_below(SLOTS as u64) as usize;
            let evicted = self.table.swap(b, s, fp);
            if rollback {
                walk.push((b, s, evicted));
            }
            fp = evicted;
            self.stats.kicks += 1;
            b = Hasher::alt_index(b, fp, nb);
            if self.table.try_insert(b, fp) {
                self.len += 1;
                self.stats.inserts += 1;
                return Ok(());
            }
        }

        // Displacement budget exhausted with fingerprint `fp` in hand.
        self.stats.insert_failures += 1;
        match self.victim_policy {
            VictimPolicy::Stash => {
                if self.victim.is_none() {
                    // The *evicted* fingerprint is parked; the caller's key
                    // effectively took its slot, so the filter still holds
                    // `len + 1` fingerprints worth of content.
                    self.victim = Some((b, fp));
                    self.len += 1;
                    self.stats.victim_stashes += 1;
                } else {
                    self.stats.dropped_fingerprints += 1;
                }
            }
            VictimPolicy::Drop => {
                // The caller's fingerprint landed in a bucket during the
                // eviction walk; `fp` (some earlier key's) is dropped.
                // Net stored count is unchanged, but that earlier key is
                // now a false negative.
                self.stats.dropped_fingerprints += 1;
            }
            VictimPolicy::Rollback => {
                // Unwind the walk newest-first (a random walk may visit
                // the same slot twice; reverse order nests correctly).
                // The final in-hand fingerprint goes home first, the
                // caller's fingerprint is dropped last — the table ends
                // bit-identical to its pre-call state.
                for &(wb, ws, evicted) in walk.iter().rev() {
                    self.table.set(wb, ws, evicted);
                }
            }
        }
        Err(FilterError::Full {
            kicks: self.max_displacements,
            occupancy: self.occupancy(),
        })
    }

    /// Membership of a pre-hashed triple.
    ///
    /// Scalar lookups probe the candidate pair *fused*
    /// ([`BucketTable::contains_pair`]): both bucket loads issue
    /// back-to-back (one wide compare on AVX2), so on big tables the
    /// two potential cache misses overlap instead of serializing on a
    /// primary miss — the latency-optimal shape for a single probe.
    /// (The batched engine keeps its lazy alternate instead: there,
    /// bandwidth wins — see [`CuckooFilter::contains_triples_into`].)
    #[inline]
    pub fn contains_triple(&self, t: HashTriple) -> bool {
        let nb = self.table.nbuckets();
        let i1 = Hasher::primary_index(t, nb);
        let i2 = Hasher::alt_index(i1, t.fp, nb);
        if self.table.contains_pair(i1, i2, t.fp) {
            return true;
        }
        match self.victim {
            Some((b, fp)) => fp == t.fp && (b == i1 || b == i2),
            None => false,
        }
    }

    /// Resolve the alternate-bucket half of a probe whose primary
    /// bucket missed (`i2` = alternate index, already prefetched).
    #[inline(always)]
    fn resolve_alt(&self, i2: usize, t: HashTriple) -> bool {
        if self.table.contains(i2, t.fp) {
            return true;
        }
        match self.victim {
            // the primary index is alt(alt) — the involution
            Some((b, fp)) => {
                fp == t.fp && (b == i2 || b == Hasher::alt_index(i2, t.fp, self.table.nbuckets()))
            }
            None => false,
        }
    }

    /// Batched membership over pre-hashed triples, appended to `out`
    /// positionally. This is the memory-level-parallel probe engine:
    ///
    /// 1. primary bucket indices are bulk-computed (tight vectorizable
    ///    loop, no table access);
    /// 2. a software pipeline walks the batch issuing a prefetch for
    ///    the primary bucket of key `i + PREFETCH_DEPTH` while probing
    ///    key `i`, so ~`PREFETCH_DEPTH` cache misses overlap instead of
    ///    serializing. Primary probes resolve **four keys per step**
    ///    through the kernel's multi-bucket gather compare
    ///    ([`BucketTable::contains4`] — two 256-bit compares on AVX2);
    /// 3. a primary miss prefetches its *alternate* bucket and parks
    ///    the key in a short queue; it resolves ~`PREFETCH_DEPTH`
    ///    iterations later, when the line has arrived. The alternate
    ///    bucket is never touched (or prefetched) for primary hits.
    pub fn contains_triples_into(&self, triples: &[HashTriple], out: &mut Vec<bool>) {
        // Engine entry: resolve the (env/tuner-overridable) pipeline
        // depth once per batch — see `prefetch_depth`.
        self.contains_triples_into_depth(triples, out, prefetch_depth());
    }

    /// [`CuckooFilter::contains_triples_into`] with an explicit
    /// pipeline depth — the entry the startup auto-tuner sweeps so
    /// measuring a candidate depth never touches the process-wide
    /// `OnceLock` it is about to seed. Results are depth-independent
    /// (depth only schedules prefetches).
    pub fn contains_triples_into_depth(
        &self,
        triples: &[HashTriple],
        out: &mut Vec<bool>,
        depth: usize,
    ) {
        let nb = self.table.nbuckets();
        let n = triples.len();
        let base = out.len();
        out.resize(base + n, false);
        let out = &mut out[base..];

        // Runs shorter than the pipeline depth get no overlap benefit;
        // resolve them scalar so short lookup runs (e.g. a mutation-
        // interleaved ingest batch) don't pay the scratch allocations.
        if n <= depth {
            for (o, &t) in out.iter_mut().zip(triples) {
                *o = self.contains_triple(t);
            }
            return;
        }

        // Stage 1: bulk index computation.
        let mut i1s: Vec<usize> = Vec::with_capacity(n);
        i1s.extend(triples.iter().map(|&t| Hasher::primary_index(t, nb)));

        // Warm the first window of primary buckets.
        for &i1 in i1s.iter().take(depth) {
            self.table.prefetch_bucket(i1);
        }

        // Stage 2: pipelined primary probes, four keys per gather;
        // misses park in `pending` (index into the batch, alternate
        // bucket) behind their alt prefetch and drain with ~depth of
        // slack. Identical answers to the one-key-at-a-time walk —
        // the gather only widens the compare.
        let mut pending: VecDeque<(usize, usize)> = VecDeque::with_capacity(depth + 1);
        let n4 = n - (n % 4);
        let mut i = 0;
        while i < n4 {
            for j in i..i + 4 {
                if let Some(&ahead) = i1s.get(j + depth) {
                    self.table.prefetch_bucket(ahead);
                }
            }
            let bs = [i1s[i], i1s[i + 1], i1s[i + 2], i1s[i + 3]];
            let fps = [
                triples[i].fp,
                triples[i + 1].fp,
                triples[i + 2].fp,
                triples[i + 3].fp,
            ];
            let hits = self.table.contains4(&bs, &fps);
            for j in 0..4 {
                let idx = i + j;
                if (hits >> j) & 1 != 0 {
                    out[idx] = true;
                } else {
                    let i2 = Hasher::alt_index(bs[j], fps[j], nb);
                    self.table.prefetch_bucket(i2);
                    pending.push_back((idx, i2));
                    if pending.len() > depth {
                        let (p, a) = pending.pop_front().unwrap();
                        out[p] = self.resolve_alt(a, triples[p]);
                    }
                }
            }
            i += 4;
        }
        // Tail (n % 4 keys): the one-key walk.
        for i in n4..n {
            if let Some(&ahead) = i1s.get(i + depth) {
                self.table.prefetch_bucket(ahead);
            }
            let t = triples[i];
            if self.table.contains(i1s[i], t.fp) {
                out[i] = true;
            } else {
                let i2 = Hasher::alt_index(i1s[i], t.fp, nb);
                self.table.prefetch_bucket(i2);
                pending.push_back((i, i2));
                if pending.len() > depth {
                    let (p, a) = pending.pop_front().unwrap();
                    out[p] = self.resolve_alt(a, triples[p]);
                }
            }
        }
        // Stage 3: drain the tail of in-flight alternates.
        for (j, a) in pending {
            out[j] = self.resolve_alt(a, triples[j]);
        }
    }

    /// Batched membership over pre-hashed triples (fresh vec).
    pub fn contains_triples(&self, triples: &[HashTriple]) -> Vec<bool> {
        let mut out = Vec::new();
        self.contains_triples_into(triples, &mut out);
        out
    }

    /// Prefetch the primary bucket of `t` (the insert pipeline issues
    /// these ahead of the matching [`CuckooFilter::insert_triple`]).
    #[inline(always)]
    pub fn prefetch_primary(&self, t: HashTriple) {
        self.table
            .prefetch_bucket(Hasher::primary_index(t, self.table.nbuckets()));
    }

    /// Batched unverified delete over pre-hashed triples, appended to
    /// `out` positionally. Deletes mutate, so (like inserts) only the
    /// fetch side is pipelined: the primary bucket of triple
    /// `i + PREFETCH_DEPTH` is prefetched while triple `i` applies;
    /// application order — and therefore victim-cache re-homing — is
    /// bit-identical to a scalar [`CuckooFilter::delete_triple`] loop.
    pub fn delete_triples_into(&mut self, triples: &[HashTriple], out: &mut Vec<bool>) {
        let depth = prefetch_depth();
        out.reserve(triples.len());
        for (i, &t) in triples.iter().enumerate() {
            if let Some(&ahead) = triples.get(i + depth) {
                self.prefetch_primary(ahead);
            }
            out.push(self.delete_triple(t));
        }
    }

    /// Unverified delete of a pre-hashed triple (the unsafe primitive).
    pub fn delete_triple(&mut self, t: HashTriple) -> bool {
        let nb = self.table.nbuckets();
        let i1 = Hasher::primary_index(t, nb);
        let i2 = Hasher::alt_index(i1, t.fp, nb);
        if self.table.remove(i1, t.fp) || self.table.remove(i2, t.fp) {
            self.len -= 1;
            self.stats.deletes += 1;
            // A freed slot lets the victim come home.
            if let Some((vb, vfp)) = self.victim {
                if self.table.try_insert(vb, vfp)
                    || self.table.try_insert(Hasher::alt_index(vb, vfp, nb), vfp)
                {
                    self.victim = None;
                }
            }
            return true;
        }
        if let Some((vb, vfp)) = self.victim {
            if vfp == t.fp && (vb == i1 || vb == i2) {
                self.victim = None;
                self.len -= 1;
                self.stats.deletes += 1;
                return true;
            }
        }
        self.stats.delete_rejects += 1;
        false
    }

    /// Iterate all stored fingerprints with their bucket (for analysis).
    pub fn iter_fingerprints(&self) -> impl Iterator<Item = (usize, u32)> + '_ {
        let nb = self.table.nbuckets();
        (0..nb)
            .flat_map(move |b| (0..SLOTS).map(move |s| (b, self.table.get(b, s))))
            .filter(|&(_, fp)| fp != 0)
            .chain(self.victim)
    }
}

// The raw table has no authoritative key store to verify a reported FP
// against, so it cannot adapt safely — no-op feedback default.
impl<T: BucketTable> FilterFeedback for CuckooFilter<T> {}

impl<T: BucketTable> MembershipFilter for CuckooFilter<T> {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        let t = self.hasher.hash_key(key);
        self.insert_triple(t)
    }

    fn contains(&self, key: u64) -> bool {
        // stats.lookups is bumped by callers that own &mut; contains is &self.
        self.contains_triple(self.hasher.hash_key(key))
    }

    fn delete(&mut self, key: u64) -> bool {
        self.delete_triple(self.hasher.hash_key(key))
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.table.nbuckets() * SLOTS
    }

    fn memory_bytes(&self) -> usize {
        self.table.memory_bytes()
    }

    fn name(&self) -> &'static str {
        "cuckoo"
    }

    fn stats(&self) -> FilterStats {
        self.stats.clone()
    }
}

/// The probe-engine overrides: bulk hashing lands in the session's
/// triple buffer (no per-call allocation), lookups run the
/// prefetch-pipelined [`CuckooFilter::contains_triples_into`], and
/// mutations pipeline their bucket fetches [`PREFETCH_DEPTH`] ahead.
/// All three are bit-identical to the scalar trait defaults (proptests
/// P11/P12).
impl<T: BucketTable> BatchedFilter for CuckooFilter<T> {
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        self.contains_triples_into(&session.triples, out);
    }

    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let triples = &session.triples;
        let depth = prefetch_depth();
        out.reserve(triples.len());
        for (i, &t) in triples.iter().enumerate() {
            if let Some(&ahead) = triples.get(i + depth) {
                self.prefetch_primary(ahead);
            }
            out.push(self.insert_triple(t));
        }
    }

    fn delete_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        self.delete_triples_into(&session.triples, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_depth_override_parsing() {
        // valid values round up to a power of two inside 1..=64
        assert_eq!(parse_depth("8"), Some(8));
        assert_eq!(parse_depth(" 4 "), Some(4));
        assert_eq!(parse_depth("1"), Some(1));
        assert_eq!(parse_depth("3"), Some(4));
        assert_eq!(parse_depth("33"), Some(64));
        assert_eq!(parse_depth("64"), Some(64));
        assert_eq!(parse_depth("4096"), Some(64), "clamped to 64");
        // invalid values are rejected (the engine keeps the default)
        assert_eq!(parse_depth("0"), None);
        assert_eq!(parse_depth(""), None);
        assert_eq!(parse_depth("-2"), None);
        assert_eq!(parse_depth("eight"), None);
        // unset env (the normal case in tests) yields the compile-time
        // default; the OnceLock caches so this is stable process-wide
        if std::env::var("OCF_PREFETCH_DEPTH").is_err() {
            assert_eq!(prefetch_depth(), PREFETCH_DEPTH);
        }
    }

    fn filter(cap: usize) -> CuckooFilter<FlatTable> {
        CuckooFilter::new(CuckooParams {
            capacity: cap,
            ..Default::default()
        })
    }

    #[test]
    fn insert_then_contains() {
        let mut f = filter(1 << 12);
        for k in 0..1000u64 {
            f.insert(k).unwrap();
        }
        for k in 0..1000u64 {
            assert!(f.contains(k), "key {k}");
        }
        assert_eq!(f.len(), 1000);
    }

    #[test]
    fn no_false_negatives_below_90_pct_load() {
        let cap = 1 << 12; // 4096 slots
        let mut f = filter(cap);
        let n = (cap as f64 * 0.9) as u64;
        let mut inserted = vec![];
        for k in 0..n {
            if f.insert(k).is_ok() {
                inserted.push(k);
            }
        }
        for &k in &inserted {
            assert!(f.contains(k), "false negative for {k}");
        }
    }

    #[test]
    fn false_positive_rate_sane() {
        let mut f = filter(1 << 14);
        for k in 0..8000u64 {
            f.insert(k).unwrap();
        }
        // held-out keys: fp rate should be around 2b/2^f ≈ 8*4096/2^16
        let fps = (1_000_000..1_100_000u64).filter(|&k| f.contains(k)).count();
        let rate = fps as f64 / 100_000.0;
        assert!(rate < 0.01, "fp rate {rate}");
    }

    #[test]
    fn fills_up_and_reports_full() {
        let mut f = filter(256);
        let mut failures = 0;
        for k in 0..400u64 {
            if f.insert(k).is_err() {
                failures += 1;
            }
        }
        assert!(failures > 0, "overfilled filter must reject");
        assert!(f.occupancy() > 0.9, "occupancy {}", f.occupancy());
    }

    #[test]
    fn drop_policy_plants_false_negatives() {
        // paper §II: naive victim handling near full load loses a
        // resident fingerprint — an observable false negative.
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: 256,
            victim_policy: VictimPolicy::Drop,
            ..Default::default()
        });
        let mut accepted = vec![];
        for k in 0..2000u64 {
            // keep hammering; Drop loses fingerprints on each failure
            if f.insert(k).is_ok() {
                accepted.push(k);
            }
        }
        assert!(f.stats.dropped_fingerprints > 0);
        let false_negs = accepted.iter().filter(|&&k| !f.contains(k)).count();
        assert!(
            false_negs > 0,
            "Drop policy at saturation must lose some resident key"
        );
    }

    #[test]
    fn stash_policy_keeps_victim_findable() {
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: 256,
            victim_policy: VictimPolicy::Stash,
            ..Default::default()
        });
        let mut accepted = vec![];
        for k in 0..400u64 {
            match f.insert(k) {
                Ok(()) => accepted.push(k),
                Err(_) => break, // stop at first failure: stash holds one victim
            }
        }
        for &k in &accepted {
            assert!(f.contains(k), "stash must prevent the false negative");
        }
    }

    #[test]
    fn unsafe_delete_removes_collider() {
        // Deleting a never-inserted key whose fingerprint collides
        // removes a resident key's fingerprint (paper §IV).
        let mut f = filter(1 << 10);
        for k in 0..700u64 {
            f.insert(k).unwrap();
        }
        // find a non-inserted key that the filter *thinks* it contains
        let collider = (10_000..10_000_000u64).find(|&k| f.contains(k));
        let collider = match collider {
            Some(c) => c,
            None => return, // astronomically unlikely with 700 keys
        };
        assert!(f.delete(collider), "collider delete succeeds (the bug)");
        let false_negs = (0..700u64).filter(|&k| !f.contains(k)).count();
        assert!(false_negs > 0, "a resident key must have been evicted");
    }

    #[test]
    fn delete_restores_space() {
        let mut f = filter(1 << 10);
        for k in 0..600u64 {
            f.insert(k).unwrap();
        }
        for k in 0..600u64 {
            assert!(f.delete(k), "key {k}");
        }
        assert_eq!(f.len(), 0);
        for k in 0..600u64 {
            f.insert(k).unwrap();
        }
    }

    #[test]
    fn delete_absent_rejected() {
        let mut f = filter(1 << 10);
        f.insert(1).unwrap();
        // an absent key with a non-colliding fingerprint must be rejected
        let miss = (100..10_000u64).find(|&k| !f.contains(k)).unwrap();
        assert!(!f.delete(miss));
        assert_eq!(f.stats.delete_rejects, 1);
    }

    #[test]
    fn insert_triple_matches_insert() {
        let mut a = filter(1 << 10);
        let mut b = filter(1 << 10);
        let h = a.hasher();
        for k in 0..500u64 {
            a.insert(k).unwrap();
            b.insert_triple(h.hash_key(k)).unwrap();
        }
        for k in 0..500u64 {
            assert_eq!(a.contains(k), b.contains(k));
        }
        assert_eq!(a.to_frozen(), b.to_frozen());
    }

    #[test]
    fn frozen_roundtrip_has_len_fingerprints() {
        let mut f = filter(1 << 10);
        for k in 0..300u64 {
            f.insert(k).unwrap();
        }
        let frozen = f.to_frozen();
        let occupied = frozen.iter().filter(|&&x| x != 0).count();
        assert_eq!(occupied, 300);
        assert_eq!(f.iter_fingerprints().count(), 300);
    }

    #[test]
    fn packed_backend_equivalent() {
        let params = CuckooParams {
            capacity: 1 << 12,
            ..Default::default()
        };
        let mut flat = CuckooFilter::<FlatTable>::new(params);
        let mut packed = CuckooFilter::<crate::filter::PackedTable>::new(params);
        for k in 0..2000u64 {
            assert_eq!(flat.insert(k).is_ok(), packed.insert(k).is_ok());
        }
        for k in 0..4000u64 {
            assert_eq!(flat.contains(k), packed.contains(k), "key {k}");
        }
    }

    #[test]
    fn rollback_failed_insert_is_a_noop() {
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: 256,
            victim_policy: VictimPolicy::Rollback,
            ..Default::default()
        });
        let mut accepted = vec![];
        let mut failures = 0;
        for k in 0..2000u64 {
            let before_table = f.to_frozen();
            let before_len = f.len();
            match f.insert(k) {
                Ok(()) => accepted.push(k),
                Err(_) => {
                    failures += 1;
                    assert_eq!(
                        f.to_frozen(),
                        before_table,
                        "failed insert of {k} must leave the table bit-identical"
                    );
                    assert_eq!(f.len(), before_len);
                }
            }
            assert_eq!(
                f.len(),
                f.iter_fingerprints().count(),
                "len/table divergence after key {k}"
            );
        }
        assert!(failures > 0, "saturation must produce failures");
        // Rollback loses nothing: every accepted key stays findable.
        for &k in &accepted {
            assert!(f.contains(k), "false negative for accepted key {k}");
        }
        assert_eq!(f.stats.dropped_fingerprints, 0);
        assert_eq!(f.stats.victim_stashes, 0);
    }

    #[test]
    fn rollback_then_delete_restores_space() {
        // after a storm of failures the table must still be fully
        // functional: delete everything, reinsert cleanly
        let mut f = CuckooFilter::<FlatTable>::new(CuckooParams {
            capacity: 256,
            victim_policy: VictimPolicy::Rollback,
            ..Default::default()
        });
        let mut accepted = vec![];
        for k in 0..2000u64 {
            if f.insert(k).is_ok() {
                accepted.push(k);
            }
        }
        for &k in &accepted {
            assert!(f.delete(k), "{k}");
        }
        assert_eq!(f.len(), 0);
        assert_eq!(f.iter_fingerprints().count(), 0);
        for k in 0..100u64 {
            f.insert(k).unwrap();
        }
    }

    #[test]
    fn batched_contains_matches_scalar() {
        // positive + negative + victim-stash coverage, both backends
        fn check<T: BucketTable>(policy: VictimPolicy) {
            let mut f = CuckooFilter::<T>::new(CuckooParams {
                capacity: 512,
                victim_policy: policy,
                ..Default::default()
            });
            for k in 0..600u64 {
                let _ = f.insert(k); // saturate → stash/rollback paths
            }
            let probes: Vec<u64> = (0..600u64).chain(1_000_000..1_000_600).collect();
            let batched = f.contains_batch(&probes);
            for (&k, &b) in probes.iter().zip(&batched) {
                assert_eq!(b, f.contains(k), "key {k}");
            }
            // triple-level path agrees too, and _into appends
            let h = f.hasher();
            let triples: Vec<HashTriple> = probes.iter().map(|&k| h.hash_key(k)).collect();
            let mut out = vec![true]; // pre-existing content survives
            f.contains_triples_into(&triples, &mut out);
            assert_eq!(out.len(), probes.len() + 1);
            assert!(out[0]);
            assert_eq!(&out[1..], &batched[..]);
        }
        check::<FlatTable>(VictimPolicy::Stash);
        check::<FlatTable>(VictimPolicy::Rollback);
        check::<crate::filter::PackedTable>(VictimPolicy::Stash);
        check::<crate::filter::PackedTable>(VictimPolicy::Rollback);
    }

    #[test]
    fn batched_insert_matches_scalar_bit_identical() {
        let params = CuckooParams {
            capacity: 1000, // non-pow2: exercises the Lemire index path
            victim_policy: VictimPolicy::Rollback,
            ..Default::default()
        };
        let mut a = CuckooFilter::<FlatTable>::new(params);
        let mut b = CuckooFilter::<FlatTable>::new(params);
        let keys: Vec<u64> = (0..1200u64).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
        let batched = a.insert_batch(&keys);
        let scalar: Vec<_> = keys.iter().map(|&k| b.insert(k)).collect();
        assert_eq!(batched.len(), scalar.len());
        for (i, (x, y)) in batched.iter().zip(&scalar).enumerate() {
            assert_eq!(x.is_ok(), y.is_ok(), "key #{i}");
        }
        assert_eq!(a.to_frozen(), b.to_frozen(), "tables must be bit-identical");
        assert_eq!(a.len(), b.len());
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn batched_contains_empty_and_tiny() {
        let f = filter(64);
        assert!(f.contains_batch(&[]).is_empty());
        // batches smaller than the pipeline depth still resolve fully
        let mut f = filter(64);
        f.insert(1).unwrap();
        f.insert(2).unwrap();
        let got = f.contains_batch(&[1, 2, 3]);
        assert_eq!(got, vec![true, true, f.contains(3)]);
    }

    #[test]
    fn kicks_counted() {
        let mut f = filter(512);
        for k in 0..450u64 {
            let _ = f.insert(k);
        }
        assert!(f.stats.kicks > 0, "high load must cause displacements");
    }
}

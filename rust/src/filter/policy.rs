//! Resize-policy abstraction shared by PRE and EOF.
//!
//! A policy observes every filter mutation (with a *logical clock* —
//! one tick per operation — rather than wallclock, so experiments are
//! deterministic; paper-reconstruction: the paper's "rate" is
//! mutations per unit time, and op-ticks preserve exactly the ratio
//! semantics Algorithm 1 needs while making runs reproducible) and may
//! demand a resize to a new slot capacity.

/// A filter mutation visible to the resize policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterEvent {
    Insert,
    Delete,
    /// An insert that failed with `Full` — an emergency signal that
    /// forces a grow decision regardless of thresholds.
    InsertFull,
}

/// Occupancy snapshot handed to the policy with each event.
#[derive(Debug, Clone, Copy)]
pub struct Occupancy {
    /// Stored items `s`.
    pub len: usize,
    /// Slot capacity `c`.
    pub capacity: usize,
}

impl Occupancy {
    /// `O = s / c` (paper §II.C).
    #[inline]
    pub fn ratio(&self) -> f64 {
        if self.capacity == 0 {
            0.0
        } else {
            self.len as f64 / self.capacity as f64
        }
    }
}

/// A demanded resize.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResizeDecision {
    /// New slot capacity `c` (the filter rounds buckets to a power of 2).
    pub new_capacity: usize,
    /// Whether this is a grow (for stats attribution).
    pub grow: bool,
}

/// Resize controller interface.
pub trait ResizePolicy: std::fmt::Debug {
    /// Observe one mutation; optionally demand a resize. `tick` is the
    /// logical time (monotone operation counter, maintained by the
    /// filter wrapper).
    fn on_event(&mut self, event: FilterEvent, occ: Occupancy, tick: u64)
        -> Option<ResizeDecision>;

    /// Called after the wrapper actually performed a resize (the
    /// achieved capacity may differ from the demanded one due to
    /// power-of-two rounding / clamps).
    fn on_resized(&mut self, achieved_capacity: usize, tick: u64);

    /// Display name for reports.
    fn name(&self) -> &'static str;
}

/// A no-op policy: never resizes (turns `Ocf` into a plain cuckoo
/// filter — used for the "traditional" arm of the experiments so all
/// arms share one code path).
#[derive(Debug, Clone, Default)]
pub struct StaticPolicy;

impl ResizePolicy for StaticPolicy {
    fn on_event(&mut self, _: FilterEvent, _: Occupancy, _: u64) -> Option<ResizeDecision> {
        None
    }

    fn on_resized(&mut self, _: usize, _: u64) {}

    fn name(&self) -> &'static str {
        "static"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_ratio() {
        let o = Occupancy { len: 3, capacity: 4 };
        assert!((o.ratio() - 0.75).abs() < 1e-12);
        let z = Occupancy { len: 0, capacity: 0 };
        assert_eq!(z.ratio(), 0.0);
    }

    #[test]
    fn static_policy_never_resizes() {
        let mut p = StaticPolicy;
        for tick in 0..100 {
            let occ = Occupancy { len: tick as usize, capacity: 16 };
            assert!(p.on_event(FilterEvent::Insert, occ, tick).is_none());
            assert!(p.on_event(FilterEvent::InsertFull, occ, tick).is_none());
        }
    }
}

//! `ProbeSession` — caller-owned scratch for the zero-allocation
//! batched filter APIs.
//!
//! Every `*_batch_into` method on [`BatchedFilter`](super::BatchedFilter)
//! and [`ConcurrentFilter`](super::ConcurrentFilter) takes a
//! `&mut ProbeSession` alongside the output vector. The session owns the
//! intermediate buffers a batched probe needs — the bulk-hashed triples,
//! and (for the sharded front-end) the per-shard gather/scatter scratch —
//! so a hot loop that reuses one session across batches performs **zero
//! allocations per call** once the buffers have grown to the steady-state
//! batch size. This is what ended the per-call `Vec` allocations the PR-2
//! engine paid in `Ocf::contains_batch` and friends.
//!
//! ```
//! use ocf::filter::{BatchedFilter, Ocf, OcfConfig, ProbeSession};
//!
//! let mut f = Ocf::new(OcfConfig::default());
//! let mut session = ProbeSession::new();
//! let mut hits = Vec::new();
//! for chunk in (0..100_000u64).collect::<Vec<_>>().chunks(4096) {
//!     let mut results = Vec::new();
//!     f.insert_batch_into(chunk, &mut session, &mut results);
//!     hits.clear();
//!     f.contains_batch_into(chunk, &mut session, &mut hits);
//!     assert!(hits.iter().all(|&h| h)); // no false negatives
//! }
//! ```
//!
//! The contents of a session between calls are **unspecified scratch**:
//! callers must never read state out of it, and any filter may clobber
//! any buffer. Sessions are cheap to create (`Vec::new` does not
//! allocate), so the allocating convenience wrappers
//! (`contains_batch(&keys) -> Vec<bool>` etc.) just make a throwaway one.

use super::fingerprint::HashTriple;
use super::FilterError;

/// Reusable scratch for one probing call-site. See the module docs.
#[derive(Debug, Default)]
pub struct ProbeSession {
    /// Bulk-hash output: `triples[i]` is the hash triple of `keys[i]`
    /// for the batch currently being processed.
    pub triples: Vec<HashTriple>,
    /// Per-shard gather/scatter scratch used by the sharded front-end.
    pub shard: ShardScratch,
}

impl ProbeSession {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the triple buffer for an expected batch size (optional;
    /// buffers grow on first use either way).
    pub fn with_capacity(batch: usize) -> Self {
        Self {
            triples: Vec::with_capacity(batch),
            shard: ShardScratch::default(),
        }
    }

    /// Heap bytes currently held by the session's buffers (diagnostic).
    pub fn memory_bytes(&self) -> usize {
        self.triples.capacity() * std::mem::size_of::<HashTriple>()
            + self.shard.memory_bytes()
    }
}

/// Scratch for the sharded front-end's group-by-shard batch plan:
/// group index lists plus the contiguous per-shard key/triple/result
/// buffers that are gathered, applied under one lock, and scattered
/// back to input positions.
#[derive(Debug, Default)]
pub struct ShardScratch {
    /// `groups[s]` lists the batch positions owned by shard `s`, in
    /// input order. The outer vec is resized to the shard count; inner
    /// vecs are cleared, not dropped, so their capacity is reused.
    pub groups: Vec<Vec<usize>>,
    /// Contiguous keys of the shard group currently being applied.
    pub keys: Vec<u64>,
    /// Contiguous triples of the shard group currently being applied.
    pub triples: Vec<HashTriple>,
    /// Per-group boolean results (contains/delete) before scatter.
    pub bools: Vec<bool>,
    /// Per-group insert results before scatter.
    pub results: Vec<Result<(), FilterError>>,
}

impl ShardScratch {
    /// Heap bytes currently held (diagnostic).
    pub fn memory_bytes(&self) -> usize {
        let groups: usize = self
            .groups
            .iter()
            .map(|g| g.capacity() * std::mem::size_of::<usize>())
            .sum();
        groups
            + self.groups.capacity() * std::mem::size_of::<Vec<usize>>()
            + self.keys.capacity() * 8
            + self.triples.capacity() * std::mem::size_of::<HashTriple>()
            + self.bools.capacity()
            + self.results.capacity() * std::mem::size_of::<Result<(), FilterError>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_session_is_empty_and_cheap() {
        let s = ProbeSession::new();
        assert_eq!(s.triples.len(), 0);
        assert_eq!(s.memory_bytes(), 0, "Vec::new must not allocate");
    }

    #[test]
    fn with_capacity_presizes_triples() {
        let s = ProbeSession::with_capacity(1024);
        assert!(s.triples.capacity() >= 1024);
        assert!(s.memory_bytes() > 0);
    }
}

//! PRE — the Primitive mode of OCF (paper §II.A.1, §II.C).
//!
//! Static-threshold resizing:
//!
//! * `O > O_max` → capacity doubles (`c = 2c`).
//! * `O < O_min` → capacity shrinks by a tenth (`c = c - c/10`) —
//!   *not* halved; the paper is explicit that halving would overshoot.
//!
//! The paper's caveat (§II.A.1): beyond ~1M keys, delete storms shrink
//! the filter linearly (10% steps) while occupancy stays above the safe
//! limit — PRE has no memory of the rate that got it there, so it keeps
//! re-triggering. We reproduce that behaviour faithfully; the guard
//! rails (never shrink below `len / safe_load`, floor capacity) are
//! safety clamps the wrapper applies to *any* policy, and are what keeps
//! "breaking the implementation" (false negatives) out of the library
//! while still letting experiments show PRE's thrash.

use super::policy::{FilterEvent, Occupancy, ResizeDecision, ResizePolicy};

/// Static-threshold resize policy.
#[derive(Debug, Clone)]
pub struct PrePolicy {
    /// Shrink threshold `O_min` (paper default 0.2).
    pub o_min: f64,
    /// Grow threshold `O_max` (paper default 0.85 — below the 0.9
    /// failure load the paper observed, leaving eviction headroom).
    pub o_max: f64,
    /// Never shrink below this capacity.
    pub min_capacity: usize,
}

impl Default for PrePolicy {
    fn default() -> Self {
        Self {
            o_min: 0.2,
            o_max: 0.85,
            min_capacity: 1024,
        }
    }
}

impl PrePolicy {
    pub fn new(o_min: f64, o_max: f64, min_capacity: usize) -> Self {
        assert!(
            0.0 <= o_min && o_min < o_max && o_max <= 1.0,
            "need 0 <= o_min < o_max <= 1, got [{o_min}, {o_max}]"
        );
        Self {
            o_min,
            o_max,
            min_capacity,
        }
    }
}

impl ResizePolicy for PrePolicy {
    fn on_event(
        &mut self,
        event: FilterEvent,
        occ: Occupancy,
        _tick: u64,
    ) -> Option<ResizeDecision> {
        let o = occ.ratio();
        match event {
            FilterEvent::Insert | FilterEvent::InsertFull => {
                // InsertFull forces growth even if thresholds say no —
                // the table hit its displacement limit early (clustered
                // load), so staying put would wedge the filter.
                if o > self.o_max || event == FilterEvent::InsertFull {
                    return Some(ResizeDecision {
                        new_capacity: occ.capacity * 2, // paper: "the bucket is doubled"
                        grow: true,
                    });
                }
            }
            FilterEvent::Delete => {
                if o < self.o_min && occ.capacity > self.min_capacity {
                    // paper: "the new size is calculated by c = (c - c/10)"
                    let c = occ.capacity - occ.capacity / 10;
                    if c >= self.min_capacity && c < occ.capacity {
                        return Some(ResizeDecision {
                            new_capacity: c,
                            grow: false,
                        });
                    }
                }
            }
        }
        None
    }

    fn on_resized(&mut self, _achieved: usize, _tick: u64) {}

    fn name(&self) -> &'static str {
        "pre"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn occ(len: usize, cap: usize) -> Occupancy {
        Occupancy { len, capacity: cap }
    }

    #[test]
    fn grows_by_doubling_above_o_max() {
        let mut p = PrePolicy::default();
        let d = p
            .on_event(FilterEvent::Insert, occ(870, 1000), 0)
            .expect("0.87 > 0.85 must grow");
        assert!(d.grow);
        assert_eq!(d.new_capacity, 2000);
    }

    #[test]
    fn no_resize_in_band() {
        let mut p = PrePolicy::default();
        assert!(p.on_event(FilterEvent::Insert, occ(500, 1000), 0).is_none());
        assert!(p.on_event(FilterEvent::Delete, occ(500, 1000), 0).is_none());
        // boundary: exactly O_max does not grow (strict >)
        assert!(p.on_event(FilterEvent::Insert, occ(850, 1000), 0).is_none());
    }

    #[test]
    fn shrinks_by_tenth_below_o_min() {
        let mut p = PrePolicy::new(0.2, 0.85, 100);
        let d = p
            .on_event(FilterEvent::Delete, occ(100, 1000), 0)
            .expect("0.1 < 0.2 must shrink");
        assert!(!d.grow);
        assert_eq!(d.new_capacity, 900); // c - c/10
    }

    #[test]
    fn shrink_respects_floor() {
        let mut p = PrePolicy::new(0.2, 0.85, 1000);
        assert!(
            p.on_event(FilterEvent::Delete, occ(10, 1000), 0).is_none(),
            "at the floor, no shrink"
        );
        // just above the floor but target would cross it → refuse
        assert!(p.on_event(FilterEvent::Delete, occ(10, 1100), 0).is_none());
    }

    #[test]
    fn insert_full_forces_growth_even_below_threshold() {
        let mut p = PrePolicy::default();
        let d = p
            .on_event(FilterEvent::InsertFull, occ(500, 1000), 0)
            .expect("Full must force grow");
        assert!(d.grow);
        assert_eq!(d.new_capacity, 2000);
    }

    #[test]
    fn repeated_shrink_is_linear_not_geometric() {
        // the paper's §II.A.1 criticism: 10% steps, slow under delete storms
        let mut p = PrePolicy::new(0.2, 0.85, 100);
        let mut cap = 10_000usize;
        let mut steps = 0;
        while let Some(d) = p.on_event(FilterEvent::Delete, occ(100, cap), steps) {
            cap = d.new_capacity;
            steps += 1;
            if steps > 100 {
                break;
            }
        }
        // halving would take ~4 steps to reach 500; 10% steps take ~22
        assert!(steps > 15, "took {steps} steps (linear-ish shrink expected)");
    }

    #[test]
    #[should_panic(expected = "o_min < o_max")]
    fn bad_thresholds_rejected() {
        PrePolicy::new(0.9, 0.2, 10);
    }
}

//! Scalable Bloom Filter (Almeida, Baquero, Preguiça & Hutchison 2007 —
//! the paper's reference [1]).
//!
//! The classic answer to "bloom filters must know n in advance": a
//! series of plain bloom slices. When the current slice reaches its
//! design fill, a new slice is added with `growth`× the capacity and a
//! `tightening`× smaller error budget, so the compound FPR converges to
//! `fpr0 / (1 - tightening)`.
//!
//! Included as the dynamic-sizing baseline OCF actually competes with:
//! it grows but (like all blooms) cannot delete, which is the axis the
//! paper's burst experiments exercise.

use super::bloom::BloomFilter;
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};

/// Growth/tightening parameters from the SBF paper.
#[derive(Debug, Clone, Copy)]
pub struct SbfParams {
    /// Capacity of the first slice.
    pub initial_capacity: usize,
    /// Compound target false-positive rate.
    pub fpr: f64,
    /// Slice-capacity growth factor (paper: s = 2 for smooth growth).
    pub growth: usize,
    /// Error tightening ratio r (paper recommends 0.8–0.9).
    pub tightening: f64,
}

impl Default for SbfParams {
    fn default() -> Self {
        Self {
            initial_capacity: 1024,
            fpr: 0.01,
            growth: 2,
            tightening: 0.85,
        }
    }
}

/// A growing series of bloom slices.
#[derive(Debug, Clone)]
pub struct ScalableBloomFilter {
    slices: Vec<(BloomFilter, usize)>, // (slice, design capacity)
    params: SbfParams,
    seed: u64,
    len: usize,
}

impl ScalableBloomFilter {
    pub fn new(params: SbfParams, seed: u64) -> Self {
        let mut s = Self {
            slices: Vec::new(),
            params,
            seed,
            len: 0,
        };
        s.push_slice();
        s
    }

    pub fn slice_count(&self) -> usize {
        self.slices.len()
    }

    fn push_slice(&mut self) {
        let i = self.slices.len();
        let cap = self.params.initial_capacity * self.params.growth.pow(i as u32);
        // slice error budget: fpr0 * (1-r) * r^i keeps the compound sum ≤ fpr
        let fpr_i = self.params.fpr * (1.0 - self.params.tightening)
            * self.params.tightening.powi(i as i32);
        let fpr_i = fpr_i.max(1e-9);
        let slice = BloomFilter::new(cap, fpr_i, self.seed.wrapping_add(i as u64));
        self.slices.push((slice, cap));
    }
}

impl FilterFeedback for ScalableBloomFilter {}

impl MembershipFilter for ScalableBloomFilter {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        {
            let (last, cap) = self.slices.last().unwrap();
            if last.len() >= *cap {
                self.push_slice();
            }
        }
        let (last, _) = self.slices.last_mut().unwrap();
        last.insert(key)?;
        self.len += 1;
        Ok(())
    }

    fn contains(&self, key: u64) -> bool {
        self.slices.iter().any(|(s, _)| s.contains(key))
    }

    /// Still a bloom: no deletes.
    fn delete(&mut self, _key: u64) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.slices.iter().map(|(_, c)| *c).sum()
    }

    fn memory_bytes(&self) -> usize {
        self.slices.iter().map(|(s, _)| s.memory_bytes()).sum()
    }

    fn name(&self) -> &'static str {
        "scalable-bloom"
    }
}

/// Default (scalar) batch implementations — the baseline rides every
/// batched consumer with zero filter-specific code.
impl BatchedFilter for ScalableBloomFilter {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_past_initial_capacity() {
        let mut f = ScalableBloomFilter::new(SbfParams::default(), 3);
        for k in 0..50_000u64 {
            f.insert(k).unwrap();
        }
        assert!(f.slice_count() > 1, "slices={}", f.slice_count());
        for k in 0..50_000u64 {
            assert!(f.contains(k), "{k}");
        }
    }

    #[test]
    fn compound_fpr_stays_near_target() {
        let mut f = ScalableBloomFilter::new(
            SbfParams {
                initial_capacity: 2048,
                fpr: 0.01,
                ..Default::default()
            },
            11,
        );
        for k in 0..40_000u64 {
            f.insert(k).unwrap();
        }
        let fps = (10_000_000..10_100_000u64)
            .filter(|&k| f.contains(k))
            .count();
        let rate = fps as f64 / 100_000.0;
        assert!(rate < 0.03, "compound fpr {rate} vs target 0.01");
    }

    #[test]
    fn slice_capacities_grow_geometrically() {
        let mut f = ScalableBloomFilter::new(
            SbfParams {
                initial_capacity: 100,
                growth: 2,
                ..Default::default()
            },
            5,
        );
        for k in 0..2000u64 {
            f.insert(k).unwrap();
        }
        let caps: Vec<usize> = f.slices.iter().map(|(_, c)| *c).collect();
        for w in caps.windows(2) {
            assert_eq!(w[1], w[0] * 2);
        }
    }

    #[test]
    fn delete_unsupported() {
        let mut f = ScalableBloomFilter::new(SbfParams::default(), 1);
        f.insert(9).unwrap();
        assert!(!f.delete(9));
        assert!(f.contains(9));
    }
}

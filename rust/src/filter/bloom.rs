//! Bloom filter baselines.
//!
//! * [`BloomFilter`] — the classic k-hash bloom filter Cassandra uses
//!   for SSTable membership (paper §I.B). No deletes — the limitation
//!   the paper leads with (§II: "A limitation of the conventional bloom
//!   filters is that it does not support deletes").
//! * [`CountingBloomFilter`] — the standard delete-capable extension
//!   (4-bit counters); included because the paper notes "the Hash Table
//!   based approach makes it less space-efficient" — experiments can
//!   quantify that 4× blowup directly.
//!
//! Both use double hashing `h_i = h1 + i·h2` (Kirsch–Mitzenmacher) from
//! the crate's `mix64`, so no extra hash family is needed.

use super::fingerprint::mix64;
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};

/// Compute (m bits, k hashes) for `n` expected items at `fpr` target.
pub fn optimal_params(n: usize, fpr: f64) -> (usize, u32) {
    assert!(n > 0 && fpr > 0.0 && fpr < 1.0);
    let ln2 = std::f64::consts::LN_2;
    let m = (-(n as f64) * fpr.ln() / (ln2 * ln2)).ceil() as usize;
    let k = ((m as f64 / n as f64) * ln2).round().max(1.0) as u32;
    (m.max(64), k)
}

#[inline(always)]
fn hash_pair(key: u64, seed: u64) -> (u64, u64) {
    let h1 = mix64(key ^ seed);
    let h2 = mix64(h1) | 1; // odd stride
    (h1, h2)
}

/// Classic bloom filter (no deletes).
#[derive(Debug, Clone)]
pub struct BloomFilter {
    bits: Vec<u64>,
    m: usize,
    k: u32,
    seed: u64,
    len: usize,
}

impl BloomFilter {
    /// Sized for `n` expected items at target false-positive rate `fpr`.
    pub fn new(n: usize, fpr: f64, seed: u64) -> Self {
        let (m, k) = optimal_params(n, fpr);
        Self::with_params(m, k, seed)
    }

    pub fn with_params(m: usize, k: u32, seed: u64) -> Self {
        assert!(m >= 64 && k >= 1);
        Self {
            bits: vec![0u64; (m + 63) / 64],
            m,
            k,
            seed,
            len: 0,
        }
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn m(&self) -> usize {
        self.m
    }

    #[inline(always)]
    fn set_bit(&mut self, i: usize) {
        self.bits[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline(always)]
    fn get_bit(&self, i: usize) -> bool {
        self.bits[i >> 6] & (1u64 << (i & 63)) != 0
    }

    /// Fraction of set bits (saturation diagnostic).
    pub fn fill_ratio(&self) -> f64 {
        let set: u64 = self.bits.iter().map(|w| w.count_ones() as u64).sum();
        set as f64 / self.m as f64
    }
}

// Bloom filters cannot adapt (no per-slot identity to remap) — no-op
// feedback default.
impl FilterFeedback for BloomFilter {}

impl MembershipFilter for BloomFilter {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        let (h1, h2) = hash_pair(key, self.seed);
        for i in 0..self.k as u64 {
            let idx = (h1.wrapping_add(i.wrapping_mul(h2)) % self.m as u64) as usize;
            self.set_bit(idx);
        }
        self.len += 1;
        Ok(())
    }

    fn contains(&self, key: u64) -> bool {
        let (h1, h2) = hash_pair(key, self.seed);
        (0..self.k as u64).all(|i| {
            let idx = (h1.wrapping_add(i.wrapping_mul(h2)) % self.m as u64) as usize;
            self.get_bit(idx)
        })
    }

    /// Bloom filters cannot delete — always false (the paper's point).
    fn delete(&mut self, _key: u64) -> bool {
        false
    }

    fn len(&self) -> usize {
        self.len
    }

    /// "Capacity" for occupancy comparisons: bits (saturation proxy).
    fn capacity(&self) -> usize {
        self.m
    }

    fn memory_bytes(&self) -> usize {
        self.bits.len() * 8
    }

    fn name(&self) -> &'static str {
        "bloom"
    }
}

/// Batch APIs come for free from the trait's scalar defaults — this is
/// the capability-trait payoff: every batched consumer (store
/// `get_batch`, pipeline, cluster fan-out) accepts a bloom baseline
/// with zero bloom-specific code.
impl BatchedFilter for BloomFilter {}

/// Counting bloom filter: 4-bit saturating counters → delete support
/// at 4× the bit-bloom footprint.
#[derive(Debug, Clone)]
pub struct CountingBloomFilter {
    /// two counters per byte
    counters: Vec<u8>,
    m: usize,
    k: u32,
    seed: u64,
    len: usize,
}

impl CountingBloomFilter {
    pub fn new(n: usize, fpr: f64, seed: u64) -> Self {
        let (m, k) = optimal_params(n, fpr);
        Self {
            counters: vec![0u8; (m + 1) / 2],
            m,
            k,
            seed,
            len: 0,
        }
    }

    #[inline(always)]
    fn get_ctr(&self, i: usize) -> u8 {
        let b = self.counters[i >> 1];
        if i & 1 == 0 {
            b & 0x0F
        } else {
            b >> 4
        }
    }

    #[inline(always)]
    fn set_ctr(&mut self, i: usize, v: u8) {
        debug_assert!(v <= 0x0F);
        let b = &mut self.counters[i >> 1];
        if i & 1 == 0 {
            *b = (*b & 0xF0) | v;
        } else {
            *b = (*b & 0x0F) | (v << 4);
        }
    }

    #[inline(always)]
    fn idx(&self, h1: u64, h2: u64, i: u64) -> usize {
        (h1.wrapping_add(i.wrapping_mul(h2)) % self.m as u64) as usize
    }
}

impl FilterFeedback for CountingBloomFilter {}

impl MembershipFilter for CountingBloomFilter {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        let (h1, h2) = hash_pair(key, self.seed);
        for i in 0..self.k as u64 {
            let idx = self.idx(h1, h2, i);
            let c = self.get_ctr(idx);
            if c < 0x0F {
                self.set_ctr(idx, c + 1); // saturate at 15 (standard CBF)
            }
        }
        self.len += 1;
        Ok(())
    }

    fn contains(&self, key: u64) -> bool {
        let (h1, h2) = hash_pair(key, self.seed);
        (0..self.k as u64).all(|i| self.get_ctr(self.idx(h1, h2, i)) > 0)
    }

    fn delete(&mut self, key: u64) -> bool {
        if !self.contains(key) {
            return false;
        }
        let (h1, h2) = hash_pair(key, self.seed);
        for i in 0..self.k as u64 {
            let idx = self.idx(h1, h2, i);
            let c = self.get_ctr(idx);
            if c > 0 && c < 0x0F {
                self.set_ctr(idx, c - 1); // saturated counters stay (standard)
            }
        }
        self.len = self.len.saturating_sub(1);
        true
    }

    fn len(&self) -> usize {
        self.len
    }

    fn capacity(&self) -> usize {
        self.m
    }

    fn memory_bytes(&self) -> usize {
        self.counters.len()
    }

    fn name(&self) -> &'static str {
        "counting-bloom"
    }
}

/// Default (scalar) batch implementations — see [`BloomFilter`]'s.
impl BatchedFilter for CountingBloomFilter {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_params_sane() {
        let (m, k) = optimal_params(1000, 0.01);
        // textbook: ~9.59 bits/key, k≈7
        assert!((9000..11000).contains(&m), "m={m}");
        assert!((6..=8).contains(&k), "k={k}");
    }

    #[test]
    fn bloom_no_false_negatives() {
        let mut f = BloomFilter::new(10_000, 0.01, 7);
        for k in 0..10_000u64 {
            f.insert(k).unwrap();
        }
        for k in 0..10_000u64 {
            assert!(f.contains(k), "{k}");
        }
    }

    #[test]
    fn bloom_fpr_near_target() {
        let mut f = BloomFilter::new(10_000, 0.01, 7);
        for k in 0..10_000u64 {
            f.insert(k).unwrap();
        }
        let fps = (1_000_000..1_100_000u64).filter(|&k| f.contains(k)).count();
        let rate = fps as f64 / 100_000.0;
        assert!(rate < 0.02, "fpr {rate} (target 0.01)");
        assert!(rate > 0.001, "suspiciously low fpr {rate}");
    }

    #[test]
    fn bloom_delete_unsupported() {
        let mut f = BloomFilter::new(100, 0.01, 7);
        f.insert(5).unwrap();
        assert!(!f.delete(5), "bloom cannot delete");
        assert!(f.contains(5));
    }

    #[test]
    fn counting_bloom_supports_delete() {
        let mut f = CountingBloomFilter::new(10_000, 0.01, 7);
        for k in 0..5000u64 {
            f.insert(k).unwrap();
        }
        for k in 0..5000u64 {
            assert!(f.contains(k));
        }
        for k in 0..2500u64 {
            assert!(f.delete(k), "{k}");
        }
        for k in 2500..5000u64 {
            assert!(f.contains(k), "{k} must survive others' deletes");
        }
        assert_eq!(f.len(), 2500);
    }

    #[test]
    fn counting_bloom_4x_bit_bloom_memory() {
        let b = BloomFilter::new(10_000, 0.01, 7);
        let c = CountingBloomFilter::new(10_000, 0.01, 7);
        let ratio = c.memory_bytes() as f64 / b.memory_bytes() as f64;
        assert!((3.5..=4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn counting_bloom_delete_absent_rejected() {
        let mut f = CountingBloomFilter::new(1000, 0.01, 7);
        f.insert(1).unwrap();
        let miss = (100..100_000u64).find(|&k| !f.contains(k)).unwrap();
        assert!(!f.delete(miss));
    }

    #[test]
    fn default_batch_apis_match_scalar() {
        // the free batch surface: identical answers, positional results
        let mut f = BloomFilter::new(5_000, 0.01, 7);
        let keys: Vec<u64> = (0..3000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        let probes: Vec<u64> = (0..6000).collect();
        let got = f.contains_batch(&probes);
        for (&k, &b) in probes.iter().zip(&got) {
            assert_eq!(b, f.contains(k), "key {k}");
        }
        // bloom can't delete: batched deletes all report false
        assert!(f.delete_batch(&keys).iter().all(|&d| !d));

        let mut c = CountingBloomFilter::new(5_000, 0.01, 7);
        for r in c.insert_batch(&keys) {
            r.unwrap();
        }
        assert!(c.delete_batch(&keys).iter().all(|&d| d));
        assert_eq!(c.len(), 0);
    }

    #[test]
    fn fill_ratio_grows() {
        let mut f = BloomFilter::new(1000, 0.01, 7);
        let r0 = f.fill_ratio();
        for k in 0..1000u64 {
            f.insert(k).unwrap();
        }
        assert!(f.fill_ratio() > r0);
        assert!(f.fill_ratio() < 0.6, "optimal fill ≈ 0.5");
    }
}

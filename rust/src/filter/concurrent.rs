//! `ConcurrentFilter` — the shared-reference capability: filters that
//! many threads can drive through `&self`.
//!
//! [`ShardedOcf`](super::ShardedOcf) implements it natively (lock
//! stripes per shard, batched ops grouped by shard and applied under
//! one lock acquisition each). Any [`BatchedFilter`] can join the
//! concurrent world through the [`MutexFilter`] adapter — a single
//! coarse lock, so it serializes writers, but it makes every backend
//! (bloom included) valid anywhere a `ConcurrentFilter` is expected;
//! the builder's [`build_concurrent`](super::FilterBuilder::build_concurrent)
//! uses it for every non-sharded backend.
//!
//! Method names mirror [`MembershipFilter`](super::MembershipFilter)/
//! [`BatchedFilter`] on purpose: generic code reads identically over
//! either world, only the
//! receiver mutability changes. (A type implementing both families —
//! `ShardedOcf` — keeps same-named *inherent* methods, so concrete
//! call sites never hit trait-method ambiguity.)

use super::metrics::FilterStats;
use super::session::ProbeSession;
use super::{BatchedFilter, FilterError};
use std::sync::Mutex;

/// A membership filter safe to share across threads: every operation,
/// including mutation, takes `&self`. Object-safe; `Send + Sync` is a
/// supertrait so `Box<dyn ConcurrentFilter>` can cross threads.
pub trait ConcurrentFilter: Send + Sync {
    /// Add a key (interior locking).
    fn insert(&self, key: u64) -> Result<(), FilterError>;

    /// Membership test (may be a false positive, never a false
    /// negative for a resident key).
    fn contains(&self, key: u64) -> bool;

    /// Remove a key; returns whether something was removed.
    fn delete(&self, key: u64) -> bool;

    /// Stored items (aggregated across any internal shards).
    fn len(&self) -> usize;

    /// Slot capacity (aggregated).
    fn capacity(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Occupancy `len / capacity`.
    fn occupancy(&self) -> f64 {
        if self.capacity() == 0 {
            0.0
        } else {
            self.len() as f64 / self.capacity() as f64
        }
    }

    /// Filter heap bytes (excludes keystores).
    fn memory_bytes(&self) -> usize;

    /// Merged operation counters.
    fn stats(&self) -> FilterStats {
        FilterStats::new()
    }

    /// Short display name ("sharded-ocf", "mutex<bloom>", ...).
    fn name(&self) -> &'static str;

    /// Exact membership via an authoritative key store, when present
    /// (see [`MembershipFilter::contains_exact`](super::MembershipFilter::contains_exact)).
    fn contains_exact(&self, key: u64) -> Option<bool> {
        let _ = key;
        None
    }

    /// Report a ground-truth false positive (see
    /// [`FilterFeedback`](super::FilterFeedback)); adaptive backends
    /// remap the offending entry, everything else no-ops.
    fn report_false_positive(&self, key: u64) -> bool {
        let _ = key;
        false
    }

    // ---- batched forms (defaults: scalar loops) ----

    /// Batched membership appended positionally to `out`.
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.contains(k)));
    }

    /// Batched insert appended positionally to `out`.
    fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.insert(k)));
    }

    /// Batched delete appended positionally to `out`.
    fn delete_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        let _ = session;
        out.extend(keys.iter().map(|&k| self.delete(k)));
    }

    /// [`ConcurrentFilter::contains_batch_into`] into a fresh vec.
    fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.contains_batch_into(keys, &mut session, &mut out);
        out
    }

    /// [`ConcurrentFilter::insert_batch_into`] into a fresh vec.
    fn insert_batch(&self, keys: &[u64]) -> Vec<Result<(), FilterError>> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.insert_batch_into(keys, &mut session, &mut out);
        out
    }

    /// [`ConcurrentFilter::delete_batch_into`] into a fresh vec.
    fn delete_batch(&self, keys: &[u64]) -> Vec<bool> {
        let mut session = ProbeSession::new();
        let mut out = Vec::with_capacity(keys.len());
        self.delete_batch_into(keys, &mut session, &mut out);
        out
    }
}

impl<C: ConcurrentFilter + ?Sized> ConcurrentFilter for Box<C> {
    fn insert(&self, key: u64) -> Result<(), FilterError> {
        (**self).insert(key)
    }
    fn contains(&self, key: u64) -> bool {
        (**self).contains(key)
    }
    fn delete(&self, key: u64) -> bool {
        (**self).delete(key)
    }
    fn len(&self) -> usize {
        (**self).len()
    }
    fn capacity(&self) -> usize {
        (**self).capacity()
    }
    fn is_empty(&self) -> bool {
        (**self).is_empty()
    }
    fn occupancy(&self) -> f64 {
        (**self).occupancy()
    }
    fn memory_bytes(&self) -> usize {
        (**self).memory_bytes()
    }
    fn stats(&self) -> FilterStats {
        (**self).stats()
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn contains_exact(&self, key: u64) -> Option<bool> {
        (**self).contains_exact(key)
    }
    fn report_false_positive(&self, key: u64) -> bool {
        (**self).report_false_positive(key)
    }
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        (**self).contains_batch_into(keys, session, out)
    }
    fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        (**self).insert_batch_into(keys, session, out)
    }
    fn delete_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        (**self).delete_batch_into(keys, session, out)
    }
}

/// Coarse-lock adapter: any [`BatchedFilter`] behind one `Mutex`.
///
/// Writers serialize, but batched calls amortize the lock the same way
/// the sharded front-end amortizes its stripes — one acquisition per
/// batch, with the engine (when the inner filter has one) running under
/// the lock. This is the "always works" arm of the concurrent world;
/// use [`ShardedOcf`](super::ShardedOcf) when write scaling matters.
#[derive(Debug, Default)]
pub struct MutexFilter<F> {
    inner: Mutex<F>,
}

impl<F: BatchedFilter + Send> MutexFilter<F> {
    pub fn new(inner: F) -> Self {
        Self {
            inner: Mutex::new(inner),
        }
    }

    /// Consume the adapter, returning the inner filter.
    pub fn into_inner(self) -> F {
        self.inner.into_inner().unwrap()
    }

    /// Run `f` with exclusive access to the inner filter under one lock
    /// acquisition.
    pub fn with_inner<R>(&self, f: impl FnOnce(&mut F) -> R) -> R {
        let mut guard = self.inner.lock().unwrap();
        f(&mut guard)
    }
}

impl<F: BatchedFilter + Send> ConcurrentFilter for MutexFilter<F> {
    fn insert(&self, key: u64) -> Result<(), FilterError> {
        self.inner.lock().unwrap().insert(key)
    }
    fn contains(&self, key: u64) -> bool {
        self.inner.lock().unwrap().contains(key)
    }
    fn delete(&self, key: u64) -> bool {
        self.inner.lock().unwrap().delete(key)
    }
    fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
    fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity()
    }
    fn occupancy(&self) -> f64 {
        self.inner.lock().unwrap().occupancy()
    }
    fn memory_bytes(&self) -> usize {
        self.inner.lock().unwrap().memory_bytes()
    }
    fn stats(&self) -> FilterStats {
        self.inner.lock().unwrap().stats()
    }
    fn name(&self) -> &'static str {
        "mutex"
    }
    fn contains_exact(&self, key: u64) -> Option<bool> {
        self.inner.lock().unwrap().contains_exact(key)
    }
    fn report_false_positive(&self, key: u64) -> bool {
        self.inner.lock().unwrap().report_false_positive(key)
    }
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        self.inner
            .lock()
            .unwrap()
            .contains_batch_into(keys, session, out)
    }
    fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        self.inner
            .lock()
            .unwrap()
            .insert_batch_into(keys, session, out)
    }
    fn delete_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        self.inner
            .lock()
            .unwrap()
            .delete_batch_into(keys, session, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::filter::{Mode, Ocf, OcfConfig};
    use std::sync::Arc;

    fn mutexed() -> MutexFilter<Ocf> {
        MutexFilter::new(Ocf::new(OcfConfig {
            mode: Mode::Eof,
            initial_capacity: 2048,
            ..OcfConfig::default()
        }))
    }

    #[test]
    fn mutex_adapter_roundtrip() {
        let f = mutexed();
        let keys: Vec<u64> = (0..5000).collect();
        for r in ConcurrentFilter::insert_batch(&f, &keys) {
            r.unwrap();
        }
        assert_eq!(ConcurrentFilter::len(&f), 5000);
        assert!(ConcurrentFilter::contains_batch(&f, &keys)
            .iter()
            .all(|&b| b));
        assert_eq!(f.contains_exact(17), Some(true));
        assert_eq!(f.contains_exact(1 << 40), Some(false));
        let deleted = ConcurrentFilter::delete_batch(&f, &keys);
        assert!(deleted.iter().all(|&d| d));
        assert!(ConcurrentFilter::is_empty(&f));
        assert_eq!(ConcurrentFilter::stats(&f).deletes, 5000);
    }

    #[test]
    fn mutex_adapter_concurrent_writers() {
        let f = Arc::new(mutexed());
        let nthreads = 4u64;
        let per = 5_000u64;
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let f = Arc::clone(&f);
                s.spawn(move || {
                    let keys: Vec<u64> = (t * per..(t + 1) * per).collect();
                    for r in ConcurrentFilter::insert_batch(&*f, &keys) {
                        r.unwrap();
                    }
                });
            }
        });
        assert_eq!(ConcurrentFilter::len(&*f), (nthreads * per) as usize);
    }

    #[test]
    fn boxed_concurrent_filter_delegates() {
        let f: Box<dyn ConcurrentFilter> = Box::new(mutexed());
        f.insert(9).unwrap();
        assert!(f.contains(9));
        assert_eq!(f.contains_exact(9), Some(true));
        assert!(f.delete(9));
        assert_eq!(f.len(), 0);
    }
}

//! The partial-key cuckoo hash family — bit-exact twin of
//! `python/compile/kernels/ref.py` (and therefore of the AOT HLO
//! artifacts the runtime executes).
//!
//! Contract (verified by `rust/tests/runtime_integration.rs` against the
//! XLA-executed artifact, and by known-answer vectors mirrored in
//! `python/tests/test_hash_kernel.py`):
//!
//! ```text
//! h        = mix64(key ^ seed)            // SplitMix64 next()
//! fp       = hi32(h) & fp_mask            // 0 remapped to 1 (EMPTY)
//! idx_hash = lo32(h)                      // caller masks with nbuckets-1
//! fp_hash  = mix32(fp)                    // murmur3 fmix32
//! i1       = idx_hash & (nbuckets-1)
//! i2       = (i1 ^ fp_hash) & (nbuckets-1)
//! ```
//!
//! The alternate index is derived from the fingerprint alone, so from
//! *either* bucket the partner is `i ^ (fp_hash & mask)` — the property
//! cuckoo displacement depends on (Fan et al., CoNEXT'14).

use crate::util::rng::GOLDEN_GAMMA;

/// SplitMix64 finalizer (one `next()` step seeded with `z`).
#[inline(always)]
pub fn mix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(GOLDEN_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// murmur3 fmix32 finalizer.
#[inline(always)]
pub fn mix32(z: u32) -> u32 {
    let mut z = (z ^ (z >> 16)).wrapping_mul(0x85EB_CA6B);
    z = (z ^ (z >> 13)).wrapping_mul(0xC2B2_AE35);
    z ^ (z >> 16)
}

/// The per-key hash triple consumed by table operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashTriple {
    /// Fingerprint (never 0; 0 is the EMPTY slot marker).
    pub fp: u32,
    /// Low 32 bits of the 64-bit hash; mask to get the primary bucket.
    pub idx_hash: u32,
    /// `mix32(fp)`; XOR-displacement for the alternate bucket.
    pub fp_hash: u32,
}

/// A seeded hasher for one filter instance.
///
/// `fp_mask` is `(1 << fp_bits) - 1`; fingerprints are stored unpacked
/// as `u32` but only `fp_bits` of entropy is used, which is what
/// determines the false-positive rate (paper §II.B "Fingerprint Size").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hasher {
    pub seed: u64,
    pub fp_mask: u32,
}

impl Hasher {
    pub fn new(seed: u64, fp_bits: u32) -> Self {
        assert!(
            (1..=32).contains(&fp_bits),
            "fp_bits must be in 1..=32, got {fp_bits}"
        );
        let fp_mask = if fp_bits == 32 {
            u32::MAX
        } else {
            (1u32 << fp_bits) - 1
        };
        Self { seed, fp_mask }
    }

    /// Hash one key. Bit-exact with `ref.hash_batch_ref` / the Pallas
    /// kernel / the AOT artifact.
    #[inline(always)]
    pub fn hash_key(&self, key: u64) -> HashTriple {
        let h = mix64(key ^ self.seed);
        let raw_fp = (h >> 32) as u32 & self.fp_mask;
        // branchless 0 → 1 remap (keeps the bulk loop vectorizable)
        let fp = raw_fp | (raw_fp == 0) as u32;
        HashTriple {
            fp,
            idx_hash: h as u32,
            fp_hash: mix32(fp),
        }
    }

    /// Bulk triple hashing: hash a whole batch in one tight, branch-free
    /// loop so the mix rounds vectorize and hashing is decoupled from
    /// probing (the probe engine consumes the triples with its own
    /// prefetch pipeline). Bit-exact with [`Hasher::hash_key`] per key.
    pub fn hash_batch(&self, keys: &[u64]) -> Vec<HashTriple> {
        let mut out = Vec::with_capacity(keys.len());
        self.hash_batch_into(keys, &mut out);
        out
    }

    /// [`Hasher::hash_batch`] appending into a caller-owned buffer
    /// (lets hot loops reuse one allocation across batches).
    pub fn hash_batch_into(&self, keys: &[u64], out: &mut Vec<HashTriple>) {
        out.reserve(keys.len());
        for &k in keys {
            out.push(self.hash_key(k));
        }
    }

    /// Primary bucket for a triple in a table of `nbuckets`.
    ///
    /// Power-of-two tables use the mask fast path (bit-identical with
    /// the AOT `hash_probe` artifact and the frozen SSTable layout);
    /// arbitrary sizes — which OCF's resize controller needs so EOF's
    /// fine-grained `c + cα` targets aren't quantized back into PRE's
    /// doubling staircase — use modulo.
    #[inline(always)]
    pub fn primary_index(t: HashTriple, nbuckets: usize) -> usize {
        if nbuckets.is_power_of_two() {
            (t.idx_hash as usize) & (nbuckets - 1)
        } else {
            // Lemire multiply-shift reduction — a mul+shift instead of
            // the div unit (perf log: +46% on insert+delete, see
            // EXPERIMENTS.md §Perf step 2)
            ((t.idx_hash as u64 * nbuckets as u64) >> 32) as usize
        }
    }

    /// Alternate bucket given either bucket index and the fingerprint.
    ///
    /// Both mappings are involutions (`alt(alt(i)) == i` — the property
    /// cuckoo displacement requires): XOR for power-of-two tables
    /// (Fan et al.), and `i' = (d - i) mod nb` with the displacement
    /// anchor `d = reduce(mix32(fp))` for arbitrary sizes (any fixed
    /// `d ∈ [0, nb)` derived from the fingerprint alone gives an
    /// involution; multiply-shift keeps it div-free).
    #[inline(always)]
    pub fn alt_index(i: usize, fp: u32, nbuckets: usize) -> usize {
        let h = mix32(fp);
        if nbuckets.is_power_of_two() {
            (i ^ h as usize) & (nbuckets - 1)
        } else {
            debug_assert!(i < nbuckets);
            let d = ((h as u64 * nbuckets as u64) >> 32) as usize;
            // (d - i) mod nb via one conditional add — no division
            if d >= i {
                d - i
            } else {
                d + nbuckets - i
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_splitmix_vectors() {
        // Mirror of python/tests/test_hash_kernel.py known answers.
        assert_eq!(mix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(mix64(GOLDEN_GAMMA.wrapping_mul(1)), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(mix64(GOLDEN_GAMMA.wrapping_mul(2)), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn mix32_zero_fixed_point() {
        assert_eq!(mix32(0), 0);
        assert_ne!(mix32(1), 1);
    }

    #[test]
    fn fingerprint_never_zero() {
        // With a 1-bit mask half of raw fingerprints are 0 — all must remap.
        let h = Hasher::new(0, 1);
        for key in 0..4096u64 {
            assert_eq!(h.hash_key(key).fp, 1);
        }
        let h16 = Hasher::new(0, 16);
        for key in 0..65_536u64 {
            assert_ne!(h16.hash_key(key).fp, 0);
        }
    }

    #[test]
    fn fp_respects_mask() {
        for bits in [4u32, 8, 12, 16, 24, 32] {
            let h = Hasher::new(99, bits);
            for key in 0..1000u64 {
                let fp = h.hash_key(key).fp;
                if bits < 32 {
                    assert!(fp < (1 << bits), "bits={bits} fp={fp}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "fp_bits")]
    fn zero_bits_rejected() {
        Hasher::new(0, 0);
    }

    #[test]
    fn alt_index_is_involution() {
        // alt(alt(i)) == i — the displacement property cuckoo needs —
        // for BOTH the pow2 (xor) and arbitrary (mod-subtract) mappings.
        let h = Hasher::new(7, 16);
        for nb in [1usize << 12, 4096 + 1, 3000, 7, 1, 2, 12345] {
            for key in 0..3_000u64 {
                let t = h.hash_key(key);
                let i1 = Hasher::primary_index(t, nb);
                assert!(i1 < nb);
                let i2 = Hasher::alt_index(i1, t.fp, nb);
                assert!(i2 < nb, "nb={nb} i2={i2}");
                assert_eq!(Hasher::alt_index(i2, t.fp, nb), i1, "nb={nb} key={key}");
            }
        }
    }

    #[test]
    fn hash_batch_bit_exact_with_scalar() {
        for bits in [1u32, 4, 16, 32] {
            let h = Hasher::new(0xBEE5 + bits as u64, bits);
            let keys: Vec<u64> = (0..10_000u64).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
            let batch = h.hash_batch(&keys);
            assert_eq!(batch.len(), keys.len());
            for (k, t) in keys.iter().zip(&batch) {
                assert_eq!(*t, h.hash_key(*k), "bits={bits} key={k}");
            }
            // _into appends after existing content
            let mut buf = vec![h.hash_key(42)];
            h.hash_batch_into(&keys[..5], &mut buf);
            assert_eq!(buf.len(), 6);
            assert_eq!(buf[1..], batch[..5]);
        }
    }

    #[test]
    fn fp_hash_matches_mix32_of_fp() {
        let h = Hasher::new(3, 16);
        for key in 0..1000u64 {
            let t = h.hash_key(key);
            assert_eq!(t.fp_hash, mix32(t.fp));
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let a = Hasher::new(1, 16);
        let b = Hasher::new(2, 16);
        let same = (0..10_000u64)
            .filter(|&k| a.hash_key(k).fp == b.hash_key(k).fp)
            .count();
        // collisions at ~2^-16 rate; 10k trials should see almost none
        assert!(same < 50, "same={same}");
    }

    #[test]
    fn index_distribution_roughly_uniform() {
        let h = Hasher::new(11, 16);
        let nb = 256;
        let mut counts = vec![0usize; nb];
        let n = 100_000u64;
        for key in 0..n {
            counts[Hasher::primary_index(h.hash_key(key), nb)] += 1;
        }
        let expect = n as f64 / nb as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.25, "bucket {i}: count {c} vs expect {expect}");
        }
    }
}

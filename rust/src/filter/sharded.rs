//! `ShardedOcf` — the concurrent OCF front-end.
//!
//! The paper's target deployment (§I: bursty traffic against
//! distributed data stores) needs a filter that many request threads
//! can hit at once. A single [`Ocf`] is single-writer by construction
//! (resizes rebuild the whole table), so instead of threading locks
//! through the hot single-threaded path, this front-end runs **N
//! independent `Ocf` shards**, each behind its own lock stripe, in the
//! spirit of Cuckoo-GPU's partitioned batch probes:
//!
//! * a key's shard is chosen from a finalizer of its hash triple
//!   ([`ShardedOcf::shard_of`]), so a batch hashed ONCE by the XLA/native
//!   executor can be routed without re-hashing;
//! * batched APIs ([`ShardedOcf::insert_batch`],
//!   [`ShardedOcf::contains_batch`], [`ShardedOcf::delete_batch`])
//!   group the batch by shard and apply each shard's group under a
//!   **single lock acquisition** — M threads driving batches scale to
//!   min(M, N) because disjoint shards never contend;
//! * each shard keeps the full OCF machinery (resize policy, verified
//!   deletes, keystore) over 1/N of the keyspace, so every
//!   state-consistency invariant of [`Ocf`] holds per shard and
//!   therefore globally.
//!
//! Shard choice must be decorrelated from the in-shard bucket mapping:
//! see the `filter` module docs ("Sharding design") for why the raw
//! high bits of `idx_hash` would skew non-power-of-two tables and how
//! `mix32(idx_hash ^ fp)` avoids it.

use super::concurrent::ConcurrentFilter;
use super::fingerprint::{mix32, Hasher, HashTriple};
use super::metrics::FilterStats;
use super::ocf::{Ocf, OcfConfig};
use super::session::{ProbeSession, ShardScratch};
use super::{BatchedFilter, FilterError, FilterFeedback, MembershipFilter};
use std::sync::Mutex;

/// Configuration for the sharded front-end.
#[derive(Debug, Clone, Copy)]
pub struct ShardedOcfConfig {
    /// Number of shards (rounded up to a power of two, min 1). Aim for
    /// the number of writer threads; more shards = less contention but
    /// more per-shard fixed overhead.
    pub shards: usize,
    /// Template for every shard. Capacities are split across shards;
    /// seed and fingerprint parameters are shared so all shards agree
    /// on one [`Hasher`] (a batch is hashed exactly once).
    pub base: OcfConfig,
}

impl Default for ShardedOcfConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            base: OcfConfig::default(),
        }
    }
}

/// N independent OCF shards behind per-shard lock stripes.
#[derive(Debug)]
pub struct ShardedOcf {
    shards: Vec<Mutex<Ocf>>,
    shard_bits: u32,
    hasher: Hasher,
}

impl ShardedOcf {
    pub fn new(cfg: ShardedOcfConfig) -> Self {
        Self::with_shards(cfg.shards, cfg.base)
    }

    /// Build `n` shards (rounded up to a power of two) from a template
    /// config whose capacities are divided across shards.
    pub fn with_shards(n: usize, base: OcfConfig) -> Self {
        let n = n.max(1).next_power_of_two();
        let shard_cfg = OcfConfig {
            initial_capacity: crate::util::ceil_div(base.initial_capacity, n).max(64),
            min_capacity: crate::util::ceil_div(base.min_capacity, n).max(64),
            max_capacity: base.max_capacity.map(|m| crate::util::ceil_div(m, n).max(64)),
            ..base
        };
        let shards: Vec<Mutex<Ocf>> = (0..n).map(|_| Mutex::new(Ocf::new(shard_cfg))).collect();
        let hasher = shards[0].lock().unwrap().hasher();
        Self {
            shards,
            shard_bits: n.trailing_zeros(),
            hasher,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The hasher shared by every shard; a triple produced by it is
    /// valid against any shard.
    pub fn hasher(&self) -> Hasher {
        self.hasher
    }

    /// The probe kernel every shard's table scans with (shards are
    /// built from one template in one process, so the dispatch choice
    /// is uniform; see [`super::kernel::active`]).
    pub fn kernel(&self) -> &'static super::kernel::ProbeKernel {
        self.shards[0].lock().unwrap().kernel()
    }

    /// Shard index for a pre-hashed triple: high bits of a finalizer
    /// over the triple (NOT raw `idx_hash` bits, which the in-shard
    /// bucket mappings consume — see module docs).
    #[inline(always)]
    pub fn shard_of(&self, t: HashTriple) -> usize {
        if self.shard_bits == 0 {
            0
        } else {
            (mix32(t.idx_hash ^ t.fp) >> (32 - self.shard_bits)) as usize
        }
    }

    /// Run `f` with exclusive access to shard `sid` under a single lock
    /// acquisition — the worker-facing primitive both of the pipeline's
    /// parallel apply stages (the scoped per-batch fan-out of
    /// `run_sharded` and the persistent pool workers of `run_pooled`)
    /// build their per-shard tasks on.
    pub fn with_shard<R>(&self, sid: usize, f: impl FnOnce(&mut Ocf) -> R) -> R {
        let mut guard = self.shards[sid].lock().unwrap();
        f(&mut guard)
    }

    /// Group triple indices by shard into a reusable buffer:
    /// `groups[s]` lists the positions in `triples` owned by shard `s`,
    /// in input order. Inner vectors are cleared, not dropped, so their
    /// capacity survives across batches (the zero-allocation plan the
    /// session-based batch APIs ride).
    pub fn group_by_shard_into(&self, triples: &[HashTriple], groups: &mut Vec<Vec<usize>>) {
        groups.resize_with(self.shards.len(), Vec::new);
        for g in groups.iter_mut() {
            g.clear();
        }
        for (i, t) in triples.iter().enumerate() {
            groups[self.shard_of(*t)].push(i);
        }
    }

    /// [`ShardedOcf::group_by_shard_into`] into a fresh vec (the
    /// pipeline's parallel apply stages share this exact routing, so a
    /// batch planned outside the filter lands on the same shards the
    /// batched APIs would pick).
    pub fn group_by_shard(&self, triples: &[HashTriple]) -> Vec<Vec<usize>> {
        let mut groups = Vec::new();
        self.group_by_shard_into(triples, &mut groups);
        groups
    }

    // ---- single-key convenience (shared-reference: locks internally) ----

    pub fn insert_one(&self, key: u64) -> Result<(), FilterError> {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.insert_hashed(key, t))
    }

    pub fn contains_one(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.contains_triple(t))
    }

    pub fn delete_one(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.delete_hashed(key, t))
    }

    /// Exact (non-probabilistic) membership via the owning shard's
    /// authoritative key store.
    pub fn contains_exact(&self, key: u64) -> bool {
        let t = self.hasher.hash_key(key);
        self.with_shard(self.shard_of(t), |s| s.contains_exact(key))
    }

    // ---- batched APIs: hash once, group by shard, one lock per shard ----
    //
    // The `_into` forms take the scratch explicitly ([`ShardScratch`] /
    // [`ProbeSession`]) and append to caller-owned outputs — zero
    // allocations per call once buffers reach steady state. The
    // Vec-returning forms are convenience wrappers over them.

    /// Insert a batch; results are positionally aligned with `keys`.
    pub fn insert_batch(&self, keys: &[u64]) -> Vec<Result<(), FilterError>> {
        let triples = self.hasher.hash_batch(keys);
        self.insert_batch_hashed(keys, &triples)
    }

    /// [`ShardedOcf::insert_batch`] with hashing landing in the
    /// session's triple buffer.
    pub fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.insert_batch_hashed_into(keys, triples, shard, out);
    }

    /// Insert a pre-hashed batch (`triples[i]` MUST be the hash of
    /// `keys[i]` under [`ShardedOcf::hasher`]). Each shard's group is
    /// gathered contiguously and applied through the prefetch-pipelined
    /// [`Ocf::insert_batch_hashed`] engine under one lock acquisition.
    pub fn insert_batch_hashed(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
    ) -> Vec<Result<(), FilterError>> {
        let mut scratch = ShardScratch::default();
        let mut out = Vec::with_capacity(keys.len());
        self.insert_batch_hashed_into(keys, triples, &mut scratch, &mut out);
        out
    }

    /// [`ShardedOcf::insert_batch_hashed`] appending into caller-owned
    /// scratch + output.
    pub fn insert_batch_hashed_into(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        out.resize(base + keys.len(), Ok(()));
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.keys.clear();
            scratch.triples.clear();
            for &i in group {
                scratch.keys.push(keys[i]);
                scratch.triples.push(triples[i]);
            }
            scratch.results.clear();
            let mut shard = self.shards[sid].lock().unwrap();
            shard.insert_batch_hashed_into(&scratch.keys, &scratch.triples, &mut scratch.results);
            drop(shard);
            for (&i, r) in group.iter().zip(scratch.results.drain(..)) {
                out[i] = r;
            }
        }
    }

    /// Batched membership; results aligned with `keys`.
    pub fn contains_batch(&self, keys: &[u64]) -> Vec<bool> {
        let triples = self.hasher.hash_batch(keys);
        self.contains_batch_hashed(&triples)
    }

    /// [`ShardedOcf::contains_batch`] with hashing landing in the
    /// session's triple buffer.
    pub fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.contains_batch_hashed_into(triples, shard, out);
    }

    /// Batched membership over pre-hashed triples. Each shard's group
    /// is gathered contiguously and resolved by the prefetch-pipelined
    /// probe engine ([`Ocf::contains_triples_into`]) under one lock
    /// acquisition, then scattered back to input positions.
    pub fn contains_batch_hashed(&self, triples: &[HashTriple]) -> Vec<bool> {
        let mut scratch = ShardScratch::default();
        let mut out = Vec::with_capacity(triples.len());
        self.contains_batch_hashed_into(triples, &mut scratch, &mut out);
        out
    }

    /// [`ShardedOcf::contains_batch_hashed`] appending into caller-owned
    /// scratch + output.
    pub fn contains_batch_hashed_into(
        &self,
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<bool>,
    ) {
        let base = out.len();
        out.resize(base + triples.len(), false);
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.triples.clear();
            scratch.triples.extend(group.iter().map(|&i| triples[i]));
            scratch.bools.clear();
            let shard = self.shards[sid].lock().unwrap();
            shard.contains_triples_into(&scratch.triples, &mut scratch.bools);
            drop(shard);
            for (&i, &r) in group.iter().zip(&scratch.bools) {
                out[i] = r;
            }
        }
    }

    /// Batched verified delete; results aligned with `keys`.
    pub fn delete_batch(&self, keys: &[u64]) -> Vec<bool> {
        let triples = self.hasher.hash_batch(keys);
        self.delete_batch_hashed(keys, &triples)
    }

    /// [`ShardedOcf::delete_batch`] with hashing landing in the
    /// session's triple buffer.
    pub fn delete_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        session.triples.clear();
        self.hasher.hash_batch_into(keys, &mut session.triples);
        let ProbeSession { triples, shard } = session;
        self.delete_batch_hashed_into(keys, triples, shard, out);
    }

    /// Batched verified delete over a pre-hashed batch. Like inserts,
    /// each shard's group is gathered contiguously and applied through
    /// the prefetch-pipelined [`Ocf::delete_batch_hashed`] engine under
    /// a single lock acquisition (a delete storm overlaps its bucket
    /// fetches instead of serializing per-key probes).
    pub fn delete_batch_hashed(&self, keys: &[u64], triples: &[HashTriple]) -> Vec<bool> {
        let mut scratch = ShardScratch::default();
        let mut out = Vec::with_capacity(keys.len());
        self.delete_batch_hashed_into(keys, triples, &mut scratch, &mut out);
        out
    }

    /// [`ShardedOcf::delete_batch_hashed`] appending into caller-owned
    /// scratch + output.
    pub fn delete_batch_hashed_into(
        &self,
        keys: &[u64],
        triples: &[HashTriple],
        scratch: &mut ShardScratch,
        out: &mut Vec<bool>,
    ) {
        assert_eq!(keys.len(), triples.len(), "keys/triples length mismatch");
        let base = out.len();
        out.resize(base + keys.len(), false);
        let out = &mut out[base..];
        self.group_by_shard_into(triples, &mut scratch.groups);
        for (sid, group) in scratch.groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            scratch.keys.clear();
            scratch.triples.clear();
            for &i in group {
                scratch.keys.push(keys[i]);
                scratch.triples.push(triples[i]);
            }
            scratch.bools.clear();
            let mut shard = self.shards[sid].lock().unwrap();
            shard.delete_batch_hashed_into(&scratch.keys, &scratch.triples, &mut scratch.bools);
            drop(shard);
            for (&i, &r) in group.iter().zip(&scratch.bools) {
                out[i] = r;
            }
        }
    }

    // ---- merged views across shards ----

    /// Total stored keys across shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total slot capacity across shards.
    pub fn capacity(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().capacity())
            .sum()
    }

    /// Aggregate occupancy `len / capacity` across shards.
    pub fn occupancy(&self) -> f64 {
        let (mut len, mut cap) = (0usize, 0usize);
        for s in &self.shards {
            let g = s.lock().unwrap();
            len += g.len();
            cap += g.capacity();
        }
        if cap == 0 {
            0.0
        } else {
            len as f64 / cap as f64
        }
    }

    /// Filter bytes across shards (excludes keystores, matching
    /// [`Ocf::keystore_bytes`]'s accounting split).
    pub fn memory_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().memory_bytes())
            .sum()
    }

    /// Keystore bytes across shards.
    pub fn keystore_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().keystore_bytes())
            .sum()
    }

    /// Merged stats across shards.
    pub fn stats(&self) -> FilterStats {
        let mut out = FilterStats::new();
        for s in &self.shards {
            out.merge(&s.lock().unwrap().stats());
        }
        out
    }

    /// Per-shard lengths (occupancy-balance visibility for tests and
    /// the throughput bench).
    pub fn shard_lens(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().len())
            .collect()
    }
}

// Plain-`Ocf` shards carry no adaptation sidecar — no-op feedback
// (use [`crate::filter::ShardedAdaptiveOcf`] for the adaptive variant).
impl FilterFeedback for ShardedOcf {}

/// `&mut self` implies exclusive access, so the single-writer trait
/// family is trivially satisfiable by the concurrent front-end — this
/// is what lets the builder hand a `ShardedOcf` to any
/// [`BatchedFilter`] consumer (e.g. a sharded node filter inside
/// `StorageNode`). All methods delegate to the same-named inherent
/// (`&self`) operations.
impl MembershipFilter for ShardedOcf {
    fn insert(&mut self, key: u64) -> Result<(), FilterError> {
        self.insert_one(key)
    }

    fn contains(&self, key: u64) -> bool {
        self.contains_one(key)
    }

    fn delete(&mut self, key: u64) -> bool {
        self.delete_one(key)
    }

    fn len(&self) -> usize {
        ShardedOcf::len(self)
    }

    fn capacity(&self) -> usize {
        ShardedOcf::capacity(self)
    }

    fn occupancy(&self) -> f64 {
        ShardedOcf::occupancy(self)
    }

    fn is_empty(&self) -> bool {
        ShardedOcf::is_empty(self)
    }

    fn memory_bytes(&self) -> usize {
        ShardedOcf::memory_bytes(self)
    }

    fn name(&self) -> &'static str {
        "sharded-ocf"
    }

    fn contains_exact(&self, key: u64) -> Option<bool> {
        Some(ShardedOcf::contains_exact(self, key))
    }

    fn exact_len(&self) -> Option<usize> {
        Some(ShardedOcf::len(self))
    }

    fn keystore_bytes(&self) -> usize {
        ShardedOcf::keystore_bytes(self)
    }

    fn stats(&self) -> FilterStats {
        ShardedOcf::stats(self)
    }
}

impl BatchedFilter for ShardedOcf {
    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        ShardedOcf::contains_batch_into(self, keys, session, out)
    }

    fn insert_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        ShardedOcf::insert_batch_into(self, keys, session, out)
    }

    fn delete_batch_into(
        &mut self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        ShardedOcf::delete_batch_into(self, keys, session, out)
    }
}

/// The native shared-reference surface: every operation locks only the
/// owning shard's stripe (batched forms: one acquisition per shard
/// group), so M threads scale to min(M, shards).
impl ConcurrentFilter for ShardedOcf {
    fn insert(&self, key: u64) -> Result<(), FilterError> {
        self.insert_one(key)
    }

    fn contains(&self, key: u64) -> bool {
        self.contains_one(key)
    }

    fn delete(&self, key: u64) -> bool {
        self.delete_one(key)
    }

    fn len(&self) -> usize {
        ShardedOcf::len(self)
    }

    fn capacity(&self) -> usize {
        ShardedOcf::capacity(self)
    }

    fn occupancy(&self) -> f64 {
        ShardedOcf::occupancy(self)
    }

    fn memory_bytes(&self) -> usize {
        ShardedOcf::memory_bytes(self)
    }

    fn stats(&self) -> FilterStats {
        ShardedOcf::stats(self)
    }

    fn name(&self) -> &'static str {
        "sharded-ocf"
    }

    fn contains_exact(&self, key: u64) -> Option<bool> {
        Some(ShardedOcf::contains_exact(self, key))
    }

    fn contains_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        ShardedOcf::contains_batch_into(self, keys, session, out)
    }

    fn insert_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<Result<(), FilterError>>,
    ) {
        ShardedOcf::insert_batch_into(self, keys, session, out)
    }

    fn delete_batch_into(
        &self,
        keys: &[u64],
        session: &mut ProbeSession,
        out: &mut Vec<bool>,
    ) {
        ShardedOcf::delete_batch_into(self, keys, session, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sharded(n: usize) -> ShardedOcf {
        ShardedOcf::with_shards(
            n,
            OcfConfig {
                initial_capacity: 4096,
                min_capacity: 1024,
                ..OcfConfig::default()
            },
        )
    }

    #[test]
    fn shard_count_rounds_to_pow2() {
        assert_eq!(sharded(1).shard_count(), 1);
        assert_eq!(sharded(3).shard_count(), 4);
        assert_eq!(sharded(8).shard_count(), 8);
    }

    #[test]
    fn batch_roundtrip() {
        let f = sharded(4);
        let keys: Vec<u64> = (0..10_000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        assert_eq!(f.len(), 10_000);
        assert!(f.contains_batch(&keys).iter().all(|&b| b));
        let absent: Vec<u64> = (1_000_000..1_001_000).collect();
        let hits = f.contains_batch(&absent).iter().filter(|&&b| b).count();
        assert!(hits < 50, "false-positive burst: {hits}");
    }

    #[test]
    fn batch_results_positionally_aligned() {
        let f = sharded(4);
        for r in f.insert_batch(&[10, 20, 30]) {
            r.unwrap();
        }
        let probe = vec![10u64, 999_999, 20, 888_888, 30];
        let got = f.contains_batch(&probe);
        assert!(got[0] && got[2] && got[4]);
        let deleted = f.delete_batch(&probe);
        assert_eq!(deleted, vec![true, false, true, false, true]);
        assert_eq!(f.len(), 0);
    }

    #[test]
    fn batch_matches_single_key_path() {
        let f = sharded(8);
        let g = sharded(8);
        let keys: Vec<u64> = (0..5000).map(|i| i * 2654435761).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        for &k in &keys {
            g.insert_one(k).unwrap();
        }
        assert_eq!(f.len(), g.len());
        assert_eq!(f.shard_lens(), g.shard_lens());
        for &k in &keys {
            assert_eq!(f.contains_one(k), g.contains_one(k), "{k}");
        }
    }

    #[test]
    fn shards_spread_keys() {
        let f = sharded(8);
        let keys: Vec<u64> = (0..80_000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        let lens = f.shard_lens();
        assert_eq!(lens.iter().sum::<usize>(), 80_000);
        let expect = 80_000 / 8;
        for (i, &l) in lens.iter().enumerate() {
            let dev = (l as f64 - expect as f64).abs() / expect as f64;
            assert!(dev < 0.15, "shard {i} holds {l}, expect ~{expect}");
        }
    }

    #[test]
    fn grows_under_burst_and_keeps_everything() {
        // each shard starts small and must resize independently
        let f = ShardedOcf::with_shards(
            4,
            OcfConfig {
                initial_capacity: 1024,
                min_capacity: 256,
                ..OcfConfig::default()
            },
        );
        let keys: Vec<u64> = (0..100_000).collect();
        for chunk in keys.chunks(4096) {
            for r in f.insert_batch(chunk) {
                r.unwrap();
            }
        }
        assert_eq!(f.len(), 100_000);
        assert!(f.stats().resizes() > 0);
        for probe in keys.iter().step_by(97) {
            assert!(f.contains_one(*probe), "{probe}");
        }
        // aggregate occupancy stays inside every shard's safe band
        assert!(f.occupancy() <= 0.9 + 1e-9);
    }

    #[test]
    fn verified_delete_preserved_per_shard() {
        let f = sharded(4);
        let keys: Vec<u64> = (0..2000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        // hostile deletes of never-inserted keys must all be rejected
        let hostile: Vec<u64> = (5_000_000..5_002_000).collect();
        assert!(f.delete_batch(&hostile).iter().all(|&d| !d));
        assert_eq!(f.len(), 2000);
        assert!(f.contains_batch(&keys).iter().all(|&b| b));
    }

    #[test]
    fn stats_merge_across_shards() {
        let f = sharded(4);
        let keys: Vec<u64> = (0..3000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        let del: Vec<u64> = (0..1000).collect();
        f.delete_batch(&del);
        let s = f.stats();
        assert_eq!(s.inserts, 3000);
        assert_eq!(s.deletes, 1000);
        assert_eq!(f.len(), 2000);
    }

    #[test]
    fn agrees_with_unsharded_ocf_semantics() {
        // one shard == plain OCF behaviour
        let f = sharded(1);
        let mut plain = Ocf::new(OcfConfig {
            initial_capacity: 4096,
            min_capacity: 1024,
            ..OcfConfig::default()
        });
        let keys: Vec<u64> = (0..20_000).collect();
        for r in f.insert_batch(&keys) {
            r.unwrap();
        }
        for &k in &keys {
            plain.insert(k).unwrap();
        }
        assert_eq!(f.len(), plain.len());
        for k in (0..40_000u64).step_by(7) {
            assert_eq!(f.contains_one(k), plain.contains(k), "{k}");
        }
    }

    #[test]
    fn concurrent_disjoint_writers_smoke() {
        use std::sync::Arc;
        let f = Arc::new(sharded(8));
        let nthreads = 8u64;
        let per = 10_000u64;
        std::thread::scope(|s| {
            for t in 0..nthreads {
                let f = Arc::clone(&f);
                s.spawn(move || {
                    let keys: Vec<u64> = (t * per..(t + 1) * per).collect();
                    for chunk in keys.chunks(1024) {
                        for r in f.insert_batch(chunk) {
                            r.unwrap();
                        }
                    }
                });
            }
        });
        assert_eq!(f.len(), (nthreads * per) as usize);
        let all: Vec<u64> = (0..nthreads * per).collect();
        assert!(f.contains_batch(&all).iter().all(|&b| b));
    }
}
